"""Fault-tolerant checkpointing: atomic, versioned, retention, elastic reload.

Layout per step::

    <dir>/step_000123/
        arrays.npz        flattened pytree leaves ("/"-joined key paths)
        meta.json         step, per-leaf manifest (shape/dtype/crc32), metadata
    <dir>/step_000123.DONE  (commit marker — written last, rename-atomic)

Restart picks the newest *committed* step, so a host dying mid-write can never
corrupt restore (the torn directory is ignored and garbage-collected).
``meta.json`` carries a per-leaf **manifest** — name, shape, dtype and crc32
of every stored array — and :func:`restore` validates the payload against it
before unflattening, raising :class:`CheckpointIntegrityError` (a named
``ValueError``) on any mismatch instead of a cryptic downstream reshape
failure. Bit-rot on one leaf is therefore detected *and localizable*:
``restore(..., strict=False)`` drops the bad leaves and reports them in
``meta["corrupt_keys"]`` so callers with per-leaf fallback paths (the serving
durability layer re-prefills a corrupted row from its prompt) can salvage the
rest of the checkpoint.

Elastic rescale: arrays are saved host-complete (device_get), so restoring
onto a *different* mesh is just ``jax.device_put(tree, new_shardings)`` —
exercised by ``tests/test_fault_tolerance.py``.

At 1000+-node scale the same layout shards per-host (each host writes its
addressable shards, ``arrays-<host>.npz``); the single-host container writes
one file, and the multi-host branch is keyed off ``jax.process_count()``.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager",
           "CheckpointIntegrityError"]

_DONE = ".DONE"


class CheckpointIntegrityError(ValueError):
    """A stored leaf contradicts the checkpoint's own manifest.

    Raised by :func:`restore` when an array is missing, has a different
    shape/dtype than ``meta.json`` recorded at save time, or fails its
    crc32 — i.e. the checkpoint directory was corrupted *after* commit
    (bit-rot, truncated file, manual tampering). Distinct from the
    structural errors a *healthy* checkpoint can raise against a
    mismatched ``tree_like`` (``KeyError`` / plain ``ValueError``)."""


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save(directory: str, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
    """Atomic checkpoint write; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                    "crc32": _crc(v)} for k, v in flat.items()}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(flat),
                   "manifest": manifest,
                   "metadata": metadata or {},
                   "process_count": jax.process_count()}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # commit marker last — restore only trusts marked steps
    with open(final + _DONE, "w") as f:
        f.write(name)
    return final


def _committed_steps(directory: str) -> list[int]:
    """Committed steps, oldest→newest. A marker whose directory has already
    vanished (a concurrent ``_gc`` between listdir and our read) does not
    count — the marker is removed *first* on collection, so marker+dir
    present together means the payload is complete."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for n in os.listdir(directory):
        if n.startswith("step_") and n.endswith(_DONE):
            if os.path.isdir(os.path.join(directory, n[:-len(_DONE)])):
                steps.append(int(n[len("step_"):-len(_DONE)]))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = _committed_steps(directory)
    return steps[-1] if steps else None


def _load_step(path: str) -> tuple[dict, dict]:
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return flat, meta


def _validate(flat: dict, meta: dict, strict: bool) -> list[str]:
    """Check every leaf against the manifest; returns the corrupt keys.

    ``strict`` raises :class:`CheckpointIntegrityError` on the first
    problem; non-strict collects the bad keys (and removes them from
    ``flat``) so the caller can salvage the healthy remainder."""
    manifest = meta.get("manifest")
    if manifest is None:             # pre-manifest checkpoint: nothing to check
        return []
    bad: list[str] = []

    def flag(key, why):
        if strict:
            raise CheckpointIntegrityError(f"checkpoint leaf {key!r}: {why}")
        bad.append(key)

    for key, spec in manifest.items():
        if key not in flat:
            flag(key, "missing from arrays.npz")
            continue
        arr = flat[key]
        if list(arr.shape) != list(spec["shape"]):
            flag(key, f"shape {list(arr.shape)} != manifest {spec['shape']}")
        elif str(arr.dtype) != spec["dtype"]:
            flag(key, f"dtype {arr.dtype} != manifest {spec['dtype']}")
        elif _crc(arr) != spec["crc32"]:
            flag(key, "crc32 mismatch (bit-rot or truncated write)")
    for key in sorted(set(flat) - set(manifest)):
        flag(key, "not in manifest")
    for key in bad:
        flat.pop(key, None)
    return bad


def _unflatten_keys(flat: dict) -> dict:
    """Rebuild a nested dict from the "/"-joined key paths (the
    ``tree_like=None`` restore mode — durability checkpoints have
    data-dependent structure, so there is no static template to match)."""
    tree: dict = {}
    for key, arr in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return tree


def restore(directory: str, tree_like: Any = None, step: Optional[int] = None,
            shardings: Any = None, strict: bool = True) -> tuple[Any, dict]:
    """Restore a committed checkpoint; returns ``(tree, meta)``.

    With ``tree_like`` the payload is validated against that structure and
    unflattened into it (missing leaf → ``KeyError``, shape mismatch →
    ``ValueError`` — template errors, not corruption). With
    ``tree_like=None`` the nested dict is rebuilt from the stored key paths
    (leaves stay host ``np.ndarray``\\ s). Either way the per-leaf manifest
    is verified first: a corrupted leaf raises
    :class:`CheckpointIntegrityError` (``strict=True``) or is dropped and
    listed in ``meta["corrupt_keys"]`` (``strict=False``).

    ``shardings`` (optional pytree of NamedSharding / device) re-places every
    leaf — this is the elastic-rescale path: a checkpoint from a 4-device mesh
    restores cleanly onto 8 devices (or 1).

    When ``step`` is ``None`` the newest committed step is used; if it
    vanishes between selection and read (a concurrent retention ``_gc``),
    restore falls back to the next older committed step.
    """
    if step is not None:
        flat, meta = _load_step(os.path.join(directory, f"step_{step:09d}"))
    else:
        steps = _committed_steps(directory)
        flat = meta = None
        for s in reversed(steps):
            try:
                flat, meta = _load_step(
                    os.path.join(directory, f"step_{s:09d}"))
                break
            except FileNotFoundError:
                continue             # _gc won the race for this step
        if flat is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    meta = dict(meta)
    meta["corrupt_keys"] = _validate(flat, meta, strict)

    if tree_like is None:
        tree = _unflatten_keys(flat)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, meta

    paths_and_leaves, tdef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for p, like in paths_and_leaves:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", getattr(q, "name", q)))) for q in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {like.shape}")
        leaves.append(arr.astype(like.dtype))
    tree = tdef.unflatten(leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, meta


class CheckpointManager:
    """Keep-N retention + torn-write garbage collection."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
        path = save(self.directory, step, tree, metadata)
        self._gc()
        return path

    def restore(self, tree_like: Any = None, step: Optional[int] = None,
                shardings=None, strict: bool = True):
        return restore(self.directory, tree_like, step, shardings,
                       strict=strict)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        committed = sorted(
            n[:-len(_DONE)] for n in os.listdir(self.directory)
            if n.startswith("step_") and n.endswith(_DONE))
        for n in committed[:-self.keep] if self.keep else []:
            # marker FIRST: a concurrent latest_step/restore that listed the
            # marker before this removal either still finds the payload
            # intact (no rmtree yet) or, finding it gone, falls back to the
            # next older committed step — never a half-deleted read.
            try:
                os.remove(os.path.join(self.directory, n + _DONE))
            except FileNotFoundError:
                pass
            shutil.rmtree(os.path.join(self.directory, n),
                          ignore_errors=True)
        # torn writes (no commit marker)
        for n in os.listdir(self.directory):
            full = os.path.join(self.directory, n)
            if n.endswith(".tmp"):
                shutil.rmtree(full, ignore_errors=True)
            elif (n.startswith("step_") and os.path.isdir(full)
                  and not os.path.exists(full + _DONE)):
                shutil.rmtree(full, ignore_errors=True)

"""Fault-tolerant checkpointing: atomic, versioned, retention, elastic reload.

Layout per step::

    <dir>/step_000123/
        arrays.npz        flattened pytree leaves ("/"-joined key paths)
        meta.json         step, leaf treedef manifest, user metadata
    <dir>/step_000123.DONE  (commit marker — written last, rename-atomic)

Restart picks the newest *committed* step, so a host dying mid-write can never
corrupt restore (the torn directory is ignored and garbage-collected).
Elastic rescale: arrays are saved host-complete (device_get), so restoring
onto a *different* mesh is just ``jax.device_put(tree, new_shardings)`` —
exercised by ``tests/test_fault_tolerance.py``.

At 1000+-node scale the same layout shards per-host (each host writes its
addressable shards, ``arrays-<host>.npz``); the single-host container writes
one file, and the multi-host branch is keyed off ``jax.process_count()``.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_DONE = ".DONE"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(directory: str, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
    """Atomic checkpoint write; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(flat),
                   "metadata": metadata or {},
                   "process_count": jax.process_count()}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # commit marker last — restore only trusts marked steps
    with open(final + _DONE, "w") as f:
        f.write(name)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(n[len("step_"):-len(_DONE)])
             for n in os.listdir(directory)
             if n.startswith("step_") and n.endswith(_DONE)]
    return max(steps) if steps else None


def restore(directory: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like``.

    ``shardings`` (optional pytree of NamedSharding / device) re-places every
    leaf — this is the elastic-rescale path: a checkpoint from a 4-device mesh
    restores cleanly onto 8 devices (or 1).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)

    paths_and_leaves, tdef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for p, like in paths_and_leaves:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", getattr(q, "name", q)))) for q in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {like.shape}")
        leaves.append(arr.astype(like.dtype))
    tree = tdef.unflatten(leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, meta


class CheckpointManager:
    """Keep-N retention + torn-write garbage collection."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
        path = save(self.directory, step, tree, metadata)
        self._gc()
        return path

    def restore(self, tree_like: Any, step: Optional[int] = None, shardings=None):
        return restore(self.directory, tree_like, step, shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        committed = sorted(
            n[:-len(_DONE)] for n in os.listdir(self.directory)
            if n.startswith("step_") and n.endswith(_DONE))
        for n in committed[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, n), ignore_errors=True)
            os.remove(os.path.join(self.directory, n + _DONE))
        # torn writes (no commit marker)
        for n in os.listdir(self.directory):
            full = os.path.join(self.directory, n)
            if n.endswith(".tmp"):
                shutil.rmtree(full, ignore_errors=True)
            elif (n.startswith("step_") and os.path.isdir(full)
                  and not os.path.exists(full + _DONE)):
                shutil.rmtree(full, ignore_errors=True)

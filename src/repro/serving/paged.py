"""Host-side paged-KV bookkeeping: block allocator + shared-prefix registry.

The device side of the paged KV cache (:class:`repro.models.attention.
PagedKVCache`) is deliberately dumb — a pool of blocks and per-row block
tables that are plain int32 *data*. Everything that decides **which** physical
block backs which logical block lives here, on the host, between decode
segments:

* :class:`BlockAllocator` — a free list with reference counts *and a
  retired-block LRU*. A block with ``refcount > 1`` is shared (several live
  rows map it); at refcount 0 it either returns to the plain free list or —
  when a registered prefix still wants its content — parks in the **LRU
  cached list**: still holding its bytes, immediately reclaimable under
  allocation pressure (oldest first, with an ``on_reclaim`` callback so the
  registry drops entries whose backing just vanished), and *resurrectable*
  by a later admission that hash-matches the retired prompt
  (:meth:`activate`). Retired prefixes are therefore never hard pool
  pressure: ``alloc`` sees ``free + lru`` capacity. The allocator never
  touches the device; exhaustion surfaces as ``alloc()`` returning ``None``,
  which the scheduler turns into queue backpressure (or a preemption
  decision) instead of corrupting a live row. Releasing an already-free
  block raises ``RuntimeError`` — loudly, not as a strippable ``assert`` —
  because a silent double-release would corrupt the refcounts of whatever
  request owns the block next.
* :class:`PrefixRegistry` — content-addressed prefix reuse. Prompts are
  hashed at *block granularity* (the hash of a prefix covers every token in
  it, so two prompts map the same entry iff their first ``k·block_size``
  tokens are identical), and a hit lets admission skip re-running the
  backbone over the prefix and (at kv16) map the already-resident blocks
  instead of re-storing them — **even after the owning row retired**, as
  long as real allocation pressure has not reclaimed the LRU-cached blocks.
  Entries snapshot the full-precision prefix K/V masters + raw max-|K|/|V|
  so a shared admission can replay *exactly* the attention reads and int-KV
  scale calibration a cold prefill would have done — what keeps shared
  admission token-identical to cold.

This mirrors the paper's decoupling of logical computation from physical
resource binding (the MDC/NN2CAM datapath-merging discipline): the traced
program never changes; only the binding tables do.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Optional

import numpy as np

__all__ = ["BlockAllocator", "PrefixRegistry", "PrefixEntry", "RowSnapshot",
           "prefix_keys"]


@dataclasses.dataclass
class RowSnapshot:
    """Everything a preempted row needs to resume **bit-exactly**.

    Captured by :meth:`ContinuousScheduler.evict_row` the moment a victim
    is suspended — after which its blocks flow back into the allocator (the
    LRU free-list for registered prefixes, the free list for the rest) and
    its slot refills. ``master_k``/``master_v`` (``[L, n_done, Hkv, hd]``
    float32) are ALL ``n_done`` KV positions the row had written,
    dequantized from its pool blocks under its then-current scales — at
    bf16 the float32 upcast round-trips, and for int KV the value whose
    re-quantization under the same scale reproduces the stored ints
    bit-for-bit. The resume wave replays them as the *whole* continuation
    prefix with an **empty suffix**: the restore is pure data movement
    through the existing continuation-prefill executable — nothing is
    recomputed, so the restored row is byte-identical to the suspended one
    by construction, not by floating-point luck (the repo's recompute-based
    continuation paths are exact only up to bf16 master rounding).
    ``last_tok`` is the last token the row *emitted* (already delivered):
    with an empty suffix the wave's argmax is meaningless, so the
    scheduler re-points the decode carry at the recorded value — together
    with ``pos = n_done`` that is exactly the carry an uninterrupted row
    holds. ``pid`` pins the wave to the profile of the row's last
    pre-eviction step (billing bookkeeping only — with an empty suffix no
    profile-dependent compute lands in the cache). ``k_amax``/``v_amax``
    (``[L, Hkv]``, int-KV only) are best-effort scale preimages
    (:func:`repro.models.transformer.amax_for_scale`, ``strict=False``)
    that land the restore recalibration on — or within a few ulp of —
    the suspended scales; ``k_scale``/``v_scale`` carry the exact
    suspended scales, forced over the restored row afterwards (see the
    field comment below).
    """

    rid: int
    n_done: int
    last_tok: int
    pid: int
    master_k: Any
    master_v: Any
    k_amax: Any
    v_amax: Any
    # Exact suspended scale rows ([L, Hkv] f32, int-KV only). The amax
    # preimage above is best-effort (``amax_for_scale(..., strict=False)``):
    # XLA's reciprocal-multiply lowering of /qmax can emit scales true f32
    # division never produces, so no preimage exists for the restore wave's
    # recalibration to hit. Re-quantization is insensitive to the resulting
    # few-ulp scale drift (``round(i·(1±ε)) == i`` for ``|i| ≤ qmax``) — the
    # ints land bit-exact regardless — and the scheduler then FORCES these
    # rows over the restored slot's scales, closing the loop by assignment.
    k_scale: Any = None
    v_scale: Any = None


def prefix_keys(tokens: np.ndarray, block_size: int) -> list[bytes]:
    """Block-aligned prefix hashes of a prompt, longest first.

    Key ``j`` (1-based) identifies tokens ``[0, j*block_size)`` via a
    *chained* digest — block ``j``'s hash is seeded with key ``j−1`` (the
    vLLM scheme), so hashing the whole chain is O(prompt) rather than
    O(prompt²/block) and two prompts share a key iff their whole prefix
    matches. Only prefixes *strictly shorter* than the prompt are keyed —
    a shared admission must keep at least one suffix token, whose logits
    seed the first generated token. Hashed once at enqueue; matched
    against the registry at admission.
    """
    t = np.ascontiguousarray(np.asarray(tokens, np.int32))
    j_max = (len(t) - 1) // block_size
    keys = []
    h = b""
    for j in range(1, j_max + 1):
        h = hashlib.sha1(
            h + t[(j - 1) * block_size:j * block_size].tobytes()).digest()
        keys.append(h)
    keys.reverse()
    return keys


class BlockAllocator:
    """Refcounted free list + retired-block LRU over the physical pool.

    ``alloc`` hands out blocks at refcount 1 (the owning row); ``retain``
    adds references (each additional sharer); ``release`` drops one
    reference per block and sends fully-released blocks to the free list —
    or, for ids named in its ``cache`` set, to the LRU cached list, where
    their content stays resurrectable (:meth:`activate`) until allocation
    pressure reclaims them oldest-first. All O(1)-per-block host operations
    — the device pool is never read or written here.
    """

    def __init__(self, n_blocks: int, block_size: int):
        """``n_blocks`` physical blocks of ``block_size`` tokens, all free."""
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self._free: list[int] = list(range(self.n_blocks - 1, -1, -1))
        self._ref = np.zeros(self.n_blocks, np.int32)
        self._lru: dict[int, None] = {}      # insertion order = oldest first
        # called with each block id the moment pressure reclaims it from the
        # LRU (before the id is handed to its new owner) — the registry
        # hooks this to drop entries whose backing content just vanished
        self.on_reclaim: Optional[Callable[[int], None]] = None
        self.reclaimed_blocks = 0

    @property
    def free_blocks(self) -> int:
        """Blocks with neither a reference nor cached content."""
        return len(self._free)

    @property
    def lru_blocks(self) -> int:
        """Retired blocks parked in the LRU: content still resurrectable,
        capacity still allocatable — cached, not used, not quite free."""
        return len(self._lru)

    @property
    def available_blocks(self) -> int:
        """What ``alloc`` can satisfy: free blocks plus reclaimable LRU."""
        return len(self._free) + len(self._lru)

    @property
    def used_blocks(self) -> int:
        """Blocks with at least one live reference — derived from the
        refcounts themselves (the ground truth), not from the free-list
        length, so occupancy stats cannot drift from the reference state."""
        return int((self._ref > 0).sum())

    def refcounts(self) -> np.ndarray:
        """Copy of the per-block reference counts (occupancy reporting)."""
        return self._ref.copy()

    def alloc(self, n: int) -> Optional[list[int]]:
        """Take ``n`` blocks (refcount 1 each); ``None`` if fewer than ``n``
        are free-or-cached — the caller's backpressure signal, never a
        partial allocation. Free blocks go first; only then does pressure
        reclaim LRU-cached content, oldest first, announcing each casualty
        through ``on_reclaim`` so prefix entries backed by it die with it.
        """
        if n > len(self._free) + len(self._lru):
            return None
        ids: list[int] = []
        # free and LRU are re-consulted every draw: reclaiming one block can
        # kill an entry whose OTHER blocks then move LRU → free (uncache of
        # newly-orphaned companions), and those must be preferred over
        # reclaiming more cached content. free+lru is conserved by that
        # move, so the up-front capacity check stays sufficient.
        while len(ids) < n:
            if self._free:
                ids.append(self._free.pop())
                continue
            bid = next(iter(self._lru))              # oldest cached block
            del self._lru[bid]
            if self.on_reclaim is not None:
                self.on_reclaim(bid)
            self.reclaimed_blocks += 1
            ids.append(bid)
        for b in ids:
            self._ref[b] = 1
        return ids

    def retain(self, ids) -> None:
        """Add one reference to each live block (an extra sharer)."""
        for b in ids:
            if self._ref[b] <= 0:
                raise RuntimeError(f"retain of free block {b}")
            self._ref[b] += 1

    def activate(self, ids) -> bool:
        """All-or-nothing claim of possibly-retired blocks: live blocks gain
        a reference, LRU-cached blocks resurrect at refcount 1. ``False``
        (and no state change) if any id was already reclaimed — the
        registry-hit-on-retired-blocks path's validity check."""
        for b in ids:
            if self._ref[b] <= 0 and b not in self._lru:
                return False
        for b in ids:
            if self._ref[b] > 0:
                self._ref[b] += 1
            else:
                del self._lru[b]
                self._ref[b] = 1
        return True

    def release(self, ids, cache=()) -> None:
        """Drop one reference per block. Fully-released blocks become free —
        or park in the LRU cached list when named in ``cache`` (a registered
        prefix still wants their content). Releasing an id that is already
        free (including the same id twice in one call) raises
        ``RuntimeError`` instead of silently corrupting the refcount of the
        block's next owner."""
        for b in ids:
            if self._ref[b] <= 0:
                raise RuntimeError(
                    f"double release of block {b} (refcount already 0)")
            self._ref[b] -= 1
            if self._ref[b] == 0:
                if b in cache:
                    self._lru[int(b)] = None         # MRU end
                else:
                    self._free.append(int(b))

    def uncache(self, ids) -> None:
        """Drop cached content claims (a registry entry died): LRU-parked
        ids move to the plain free list; live or already-free ids no-op."""
        for b in ids:
            if b in self._lru:
                del self._lru[b]
                self._free.append(int(b))

    def check(self, expected: Optional[np.ndarray] = None) -> None:
        """Invariant auditor: raise ``RuntimeError`` on any bookkeeping rot.

        Checked invariants (the ground truth every paged-serving property
        rests on):

        * refcounts are never negative;
        * the free list holds no duplicates and no id also parked in the
          LRU;
        * free-listed and LRU-cached blocks hold zero references;
        * live (``ref > 0``) / LRU-cached / free **partition** the pool
          exactly — in particular, a block with refcount 0 that sits in
          neither list is a *leak* and fails here;
        * with ``expected`` (a per-block refcount array derived from
          external bookkeeping — the scheduler's block tables plus the
          registry's sharer counts), the allocator's refcounts must match
          it element-for-element.

        O(pool) pure host work: cheap enough for a ``--paranoid`` serve
        loop to run after every step, and for property tests to run after
        every single operation.
        """
        ref = self._ref
        neg = np.nonzero(ref < 0)[0]
        if neg.size:
            raise RuntimeError(f"negative refcount on blocks {neg.tolist()}")
        free = [int(b) for b in self._free]
        if len(set(free)) != len(free):
            raise RuntimeError("duplicate ids on the free list")
        fs, ls = set(free), {int(b) for b in self._lru}
        both = fs & ls
        if both:
            raise RuntimeError(f"blocks {sorted(both)} free AND LRU-cached")
        held = [b for b in fs | ls if ref[b] != 0]
        if held:
            raise RuntimeError(
                f"free/LRU blocks {sorted(held)} hold references")
        live = {int(b) for b in np.nonzero(ref > 0)[0]}
        missing = set(range(self.n_blocks)) - live - fs - ls
        if missing:
            raise RuntimeError(
                f"leaked blocks {sorted(missing)}: refcount 0 but on "
                f"neither the free list nor the LRU")
        if len(live) + len(fs) + len(ls) != self.n_blocks:
            raise RuntimeError("live/LRU/free do not partition the pool")
        if expected is not None:
            exp = np.asarray(expected)
            if exp.shape != ref.shape or not np.array_equal(exp, ref):
                bad = np.nonzero(np.asarray(exp) != ref)[0]
                raise RuntimeError(
                    f"refcounts disagree with external bookkeeping on "
                    f"blocks {bad.tolist()[:16]} "
                    f"(allocator={ref[bad][:16].tolist()}, "
                    f"expected={exp[bad][:16].tolist()})")


@dataclasses.dataclass
class PrefixEntry:
    """One registered block-aligned prefix.

    ``block_ids`` are the pool blocks holding the prefix KV (kv16 only —
    int-KV rows carry per-row scales, so their blocks are not bit-shareable
    across rows and shared admissions requantize from the masters instead).
    They are a *soft* claim: while any sharer is live the blocks carry
    references; after the last sharer retires they park in the allocator's
    LRU, where a later hit resurrects them — and real allocation pressure
    reclaims them, killing the entry. ``master_k``/``master_v`` (per layer
    ``[L, n_tokens, Hkv, hd]``, full precision) and ``k_amax``/``v_amax``
    (``[L, Hkv]`` raw max-abs over the prefix) let a shared admission
    reproduce the cold path exactly. ``sharers`` counts live rows currently
    mapping ``block_ids``; an entry is capacity-evictable only at zero.
    """

    key: bytes
    n_tokens: int
    block_ids: Optional[list[int]]
    master_k: Any
    master_v: Any
    k_amax: Any
    v_amax: Any
    sharers: int = 0
    hits: int = 0


class PrefixRegistry:
    """LRU registry of reusable prompt prefixes.

    ``capacity`` bounds host+device memory held by masters. Block-backed
    (kv16) entries hold their blocks softly through the allocator's
    retired-block LRU: registration pins nothing, retirement parks, real
    pressure reclaims (the allocator's ``on_reclaim`` callback drops the
    affected entries the moment their backing goes). Lookup order is
    longest-prefix-first over the hashes computed at enqueue
    (:func:`prefix_keys`).
    """

    def __init__(self, allocator: BlockAllocator, capacity: int = 8):
        """Registry over ``allocator``'s pool, holding ≤ ``capacity`` entries."""
        self.alloc = allocator
        self.capacity = int(capacity)
        self._entries: dict[bytes, PrefixEntry] = {}   # insertion = LRU order
        self._by_block: dict[int, set[bytes]] = {}     # bid -> entry keys
        self.hits = 0
        self.misses = 0
        self.invalidated = 0           # entries killed by block reclaim
        allocator.on_reclaim = self._block_reclaimed

    def __len__(self) -> int:
        return len(self._entries)

    def contains(self, key: bytes) -> bool:
        """Membership test that does NOT touch LRU recency or hit counters."""
        return key in self._entries

    def lookup(self, keys: list[bytes]) -> Optional[PrefixEntry]:
        """Longest registered prefix among ``keys`` (ordered longest-first).

        Pure read: hit/miss counters and LRU recency move only when an
        admission actually commits (:meth:`record_admission`) — a request
        re-looked-up on every scheduler tick while backpressured must not
        inflate the stats or churn the eviction order. Block-backed entries
        are always resident when returned: reclaim invalidates eagerly.
        """
        for key in keys:
            e = self._entries.get(key)
            if e is not None:
                return e
        return None

    def record_admission(self, entry: Optional[PrefixEntry]) -> None:
        """Count one committed admission: a hit (refreshing the entry's LRU
        recency) when ``entry`` was reused, a miss for a cold admission."""
        if entry is None:
            self.misses += 1
            return
        if entry.key in self._entries:
            self._entries.pop(entry.key)
            self._entries[entry.key] = entry           # refresh recency
        entry.hits += 1
        self.hits += 1

    def register(self, key: bytes, n_tokens: int,
                 block_ids: Optional[list[int]],
                 master_k, master_v, k_amax, v_amax) -> Optional[PrefixEntry]:
        """Record a prefix for reuse (no-op if already registered).

        ``block_ids`` are claimed *softly*: no refcount moves here — the
        owning row's live references keep them resident now, and its
        retirement parks them in the allocator LRU (the scheduler passes
        :meth:`covered` ids to ``release``). Over-capacity registration
        evicts the least recently used idle entry first; if every entry is
        in live use the new one is simply not registered.
        """
        if key in self._entries:
            return self._entries[key]
        while len(self._entries) >= self.capacity:
            if not self._evict_one():
                return None
        e = PrefixEntry(key=key, n_tokens=n_tokens,
                        block_ids=None if block_ids is None
                        else list(block_ids),
                        master_k=master_k, master_v=master_v,
                        k_amax=k_amax, v_amax=v_amax)
        self._entries[key] = e
        for b in (e.block_ids or ()):
            self._by_block.setdefault(int(b), set()).add(key)
        return e

    def register_chain(self, keys: list[bytes], j_max: int, blocks,
                       mk, mv, share_blocks: Optional[bool] = None) -> None:
        """Offer every key of one prompt's block-aligned prefix chain,
        longest first — key ``i`` of ``keys`` covers ``(j_max − i)``
        blocks. Every key is offered (``register`` no-ops on present ones)
        because LRU/reclaim eviction removes single entries, so a present
        long key does NOT imply its shorter companions survived. At kv16
        (``mk is None``) each entry claims the row's leading blocks softly
        — the pool's bf16 blocks double as the masters, nothing else is
        stored. At int KV precisions entries share the ONE master buffer
        ``mk``/``mv`` (already truncated to ``j_max`` blocks) and snapshot
        per-length raw amax — O(chain), not O(chain²), memory.

        ``share_blocks`` marks the pool blocks bit-shareable (bf16 pool;
        int8 rows are quantized on the owner's per-row grid and are not).
        It defaults to ``mk is None`` — the classic two modes — and
        ``share_blocks=True`` *with* masters is the ``kv16_masters`` mode:
        entries keep the CoW block claim AND the full-precision masters,
        so shared admissions still map instead of re-store while the
        prefix compute replays the raw activations (structural
        bit-exactness + exact durable snapshots).
        """
        if j_max < 1 or not keys:
            return
        if share_blocks is None:
            share_blocks = mk is None
        import jax.numpy as jnp
        bs = self.alloc.block_size
        for i, key in enumerate(keys):           # longest first
            if self.contains(key):
                continue
            n_blk = j_max - i
            n_tok = n_blk * bs
            bids = blocks[:n_blk] if share_blocks else None
            if mk is None:                       # kv16: pool blocks = masters
                self.register(key, n_tok, bids, None, None, None, None)
            else:
                ka = jnp.max(jnp.abs(mk[:, :n_tok]), axis=(1, 3))
                va = jnp.max(jnp.abs(mv[:, :n_tok]), axis=(1, 3))
                self.register(key, n_tok, bids, mk, mv, ka, va)

    def acquire(self, entry: PrefixEntry) -> None:
        """A row starts mapping the entry's blocks: live blocks gain a
        reference, retired-but-cached ones resurrect from the LRU. Entries
        handed out by :meth:`lookup` are resident by construction (eager
        invalidation), so activation cannot fail."""
        entry.sharers += 1
        if entry.block_ids is not None:
            ok = self.alloc.activate(entry.block_ids)
            if not ok:                           # unreachable by contract
                raise RuntimeError(
                    f"registry entry {entry.key.hex()[:8]} outlived its "
                    f"blocks — reclaim invalidation failed")

    def release(self, entry: PrefixEntry) -> None:
        """A sharing row retired; its block references drop — and blocks
        reaching refcount 0 park in the allocator LRU (the entry still
        wants them) instead of the free list."""
        entry.sharers -= 1
        assert entry.sharers >= 0
        if entry.block_ids is not None:
            self.alloc.release(entry.block_ids,
                               cache=self.covered(entry.block_ids))

    def add_expected_refs(self, out: np.ndarray) -> None:
        """Accumulate the per-block references the registry's live sharers
        account for (``sharers`` per entry block — each :meth:`acquire`
        activated every ``block_ids`` member once) into ``out``. One half
        of the :meth:`BlockAllocator.check` cross-audit; the scheduler adds
        the other half from its slot block tables."""
        for e in self._entries.values():
            if e.block_ids is not None and e.sharers:
                for b in e.block_ids:
                    out[int(b)] += e.sharers

    def covered(self, ids) -> set:
        """The subset of ``ids`` some registered entry still claims — the
        ``cache`` set for :meth:`BlockAllocator.release`: covered blocks
        park in the LRU at refcount 0, uncovered ones go straight free."""
        return {int(b) for b in ids if int(b) in self._by_block}

    def _unindex(self, e: PrefixEntry) -> None:
        """Remove an entry's block claims; blocks left wholly unclaimed
        lose their LRU parking spot (content nobody can ever hit again)."""
        orphans = []
        for b in (e.block_ids or ()):
            keys = self._by_block.get(int(b))
            if keys is None:
                continue
            keys.discard(e.key)
            if not keys:
                del self._by_block[int(b)]
                orphans.append(int(b))
        if orphans:
            self.alloc.uncache(orphans)

    def _block_reclaimed(self, bid: int) -> None:
        """Allocator callback: pressure reclaimed a cached block — every
        entry backed by it is now unreproducible and dies with it. Entries
        with live sharers are unreachable here (their blocks carry
        references and cannot sit in the LRU)."""
        for key in list(self._by_block.get(int(bid), ())):
            e = self._entries.pop(key, None)
            if e is None:
                continue
            assert e.sharers == 0, "live-shared entry backed by LRU block"
            self.invalidated += 1
            # the reclaimed id itself is being handed out by alloc();
            # only the entry's *other* blocks need their claims dropped
            e.block_ids = [b for b in e.block_ids if int(b) != int(bid)]
            self._unindex(e)
        self._by_block.pop(int(bid), None)

    def _evict_one(self) -> bool:
        for key, e in self._entries.items():
            if e.sharers == 0:
                self._entries.pop(key)
                self._unindex(e)
                return True
        return False

    def nbytes(self) -> int:
        """Device bytes pinned by prefix masters (counted by the bench as
        part of the paged KV footprint). Chain entries share one master
        buffer, so bytes are counted per unique array, not per entry."""
        total = 0
        seen: set[int] = set()
        for e in self._entries.values():
            for arr in (e.master_k, e.master_v, e.k_amax, e.v_amax):
                if arr is not None and id(arr) not in seen:
                    seen.add(id(arr))            # kv16 stores no masters at
                    total += int(arr.nbytes)     # all — pool blocks double
        return total                             # as the masters there

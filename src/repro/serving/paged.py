"""Host-side paged-KV bookkeeping: block allocator + shared-prefix registry.

The device side of the paged KV cache (:class:`repro.models.attention.
PagedKVCache`) is deliberately dumb — a pool of blocks and per-row block
tables that are plain int32 *data*. Everything that decides **which** physical
block backs which logical block lives here, on the host, between decode
segments:

* :class:`BlockAllocator` — a free list with reference counts. A block with
  ``refcount > 1`` is shared (a registered prefix and/or several live rows map
  it); it returns to the free list only when the last reference drops. The
  allocator never touches the device: exhaustion surfaces as ``alloc()``
  returning ``None``, which the scheduler turns into queue backpressure
  instead of corrupting a live row.
* :class:`PrefixRegistry` — content-addressed prefix reuse. Prompts are
  hashed at *block granularity* (the hash of a prefix covers every token in
  it, so two prompts map the same entry iff their first ``k·block_size``
  tokens are identical), and a hit lets admission skip re-running the
  backbone over the prefix and (at kv16) map the already-resident blocks
  instead of re-storing them. Entries snapshot the full-precision prefix K/V
  masters + raw max-|K|/|V| so a shared admission can replay *exactly* the
  attention reads and int-KV scale calibration a cold prefill would have
  done — what keeps shared admission token-identical to cold.

This mirrors the paper's decoupling of logical computation from physical
resource binding (the MDC/NN2CAM datapath-merging discipline): the traced
program never changes; only the binding tables do.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Optional

import numpy as np

__all__ = ["BlockAllocator", "PrefixRegistry", "PrefixEntry", "prefix_keys"]


def prefix_keys(tokens: np.ndarray, block_size: int) -> list[bytes]:
    """Block-aligned prefix hashes of a prompt, longest first.

    Key ``j`` (1-based) identifies tokens ``[0, j*block_size)`` via a
    *chained* digest — block ``j``'s hash is seeded with key ``j−1`` (the
    vLLM scheme), so hashing the whole chain is O(prompt) rather than
    O(prompt²/block) and two prompts share a key iff their whole prefix
    matches. Only prefixes *strictly shorter* than the prompt are keyed —
    a shared admission must keep at least one suffix token, whose logits
    seed the first generated token. Hashed once at enqueue; matched
    against the registry at admission.
    """
    t = np.ascontiguousarray(np.asarray(tokens, np.int32))
    j_max = (len(t) - 1) // block_size
    keys = []
    h = b""
    for j in range(1, j_max + 1):
        h = hashlib.sha1(
            h + t[(j - 1) * block_size:j * block_size].tobytes()).digest()
        keys.append(h)
    keys.reverse()
    return keys


class BlockAllocator:
    """Refcounted free list over the physical block pool.

    ``alloc`` hands out blocks at refcount 1 (the owning row); ``retain``
    adds references (a registry pin, each additional sharer); ``release``
    drops one reference per block and returns fully-released blocks to the
    free list. All O(1)-per-block host operations — the device pool is never
    read or written here.
    """

    def __init__(self, n_blocks: int, block_size: int):
        """``n_blocks`` physical blocks of ``block_size`` tokens, all free."""
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self._free: list[int] = list(range(self.n_blocks - 1, -1, -1))
        self._ref = np.zeros(self.n_blocks, np.int32)

    @property
    def free_blocks(self) -> int:
        """Blocks immediately available to ``alloc``."""
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Blocks with at least one live reference — derived from the
        refcounts themselves (the ground truth), not from the free-list
        length, so occupancy stats cannot drift from the reference state."""
        return int((self._ref > 0).sum())

    def refcounts(self) -> np.ndarray:
        """Copy of the per-block reference counts (occupancy reporting)."""
        return self._ref.copy()

    def alloc(self, n: int) -> Optional[list[int]]:
        """Take ``n`` blocks (refcount 1 each); ``None`` if fewer are free —
        the caller's backpressure signal, never a partial allocation."""
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        self._ref[ids] = 1
        return ids

    def retain(self, ids) -> None:
        """Add one reference to each block (registry pin / extra sharer)."""
        for b in ids:
            assert self._ref[b] > 0, f"retain of free block {b}"
            self._ref[b] += 1

    def release(self, ids) -> None:
        """Drop one reference per block; fully-released blocks become free."""
        for b in ids:
            assert self._ref[b] > 0, f"release of free block {b}"
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._free.append(int(b))


@dataclasses.dataclass
class PrefixEntry:
    """One registered block-aligned prefix.

    ``block_ids`` are the pool blocks holding the prefix KV (kv16 only —
    int-KV rows carry per-row scales, so their blocks are not bit-shareable
    across rows and shared admissions requantize from the masters instead).
    ``master_k``/``master_v`` (per layer ``[L, n_tokens, Hkv, hd]``, full
    precision) and ``k_amax``/``v_amax`` (``[L, Hkv]`` raw max-abs over the
    prefix) let a shared admission reproduce the cold path exactly.
    ``sharers`` counts live rows currently mapping ``block_ids``; an entry is
    evictable only at zero.
    """

    key: bytes
    n_tokens: int
    block_ids: Optional[list[int]]
    master_k: Any
    master_v: Any
    k_amax: Any
    v_amax: Any
    sharers: int = 0
    hits: int = 0


class PrefixRegistry:
    """LRU registry of reusable prompt prefixes.

    ``capacity`` bounds host+device memory held by masters; when the
    allocator runs dry, :meth:`evict_for` additionally drops idle entries to
    hand their pinned blocks back. Lookup order is longest-prefix-first over
    the hashes computed at enqueue (:func:`prefix_keys`).
    """

    def __init__(self, allocator: BlockAllocator, capacity: int = 8):
        """Registry over ``allocator``'s pool, holding ≤ ``capacity`` entries."""
        self.alloc = allocator
        self.capacity = int(capacity)
        self._entries: dict[bytes, PrefixEntry] = {}   # insertion = LRU order
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def contains(self, key: bytes) -> bool:
        """Membership test that does NOT touch LRU recency or hit counters."""
        return key in self._entries

    def lookup(self, keys: list[bytes]) -> Optional[PrefixEntry]:
        """Longest registered prefix among ``keys`` (ordered longest-first).

        Pure read: hit/miss counters and LRU recency move only when an
        admission actually commits (:meth:`record_admission`) — a request
        re-looked-up on every scheduler tick while backpressured must not
        inflate the stats or churn the eviction order.
        """
        for key in keys:
            e = self._entries.get(key)
            if e is not None:
                return e
        return None

    def record_admission(self, entry: Optional[PrefixEntry]) -> None:
        """Count one committed admission: a hit (refreshing the entry's LRU
        recency) when ``entry`` was reused, a miss for a cold admission."""
        if entry is None:
            self.misses += 1
            return
        if entry.key in self._entries:
            self._entries.pop(entry.key)
            self._entries[entry.key] = entry           # refresh recency
        entry.hits += 1
        self.hits += 1

    def register(self, key: bytes, n_tokens: int,
                 block_ids: Optional[list[int]],
                 master_k, master_v, k_amax, v_amax) -> Optional[PrefixEntry]:
        """Pin a prefix for reuse (no-op if already registered).

        ``block_ids`` get one extra reference so they outlive the owning
        row's retirement. Over-capacity registration evicts the least
        recently used idle entry first; if every entry is in live use the
        new one is simply not registered.
        """
        if key in self._entries:
            return self._entries[key]
        while len(self._entries) >= self.capacity:
            if not self._evict_one():
                return None
        if block_ids is not None:
            self.alloc.retain(block_ids)
        e = PrefixEntry(key=key, n_tokens=n_tokens,
                        block_ids=None if block_ids is None
                        else list(block_ids),
                        master_k=master_k, master_v=master_v,
                        k_amax=k_amax, v_amax=v_amax)
        self._entries[key] = e
        return e

    def acquire(self, entry: PrefixEntry) -> None:
        """A row starts mapping the entry's blocks (kv16: refcount them)."""
        entry.sharers += 1
        if entry.block_ids is not None:
            self.alloc.retain(entry.block_ids)

    def release(self, entry: PrefixEntry) -> None:
        """A sharing row retired; drop its references."""
        entry.sharers -= 1
        assert entry.sharers >= 0
        if entry.block_ids is not None:
            self.alloc.release(entry.block_ids)

    def _evict_one(self) -> bool:
        for key, e in self._entries.items():
            if e.sharers == 0:
                self._entries.pop(key)
                if e.block_ids is not None:
                    self.alloc.release(e.block_ids)
                return True
        return False

    def evict_for(self, n_needed: int) -> None:
        """Free idle entries (LRU first) until ``n_needed`` blocks are
        allocatable or nothing evictable remains."""
        while self.alloc.free_blocks < n_needed and self._evict_one():
            pass

    def pinned_counts(self, n_blocks: int) -> np.ndarray:
        """Per-block registry pin counts (one pin per entry retaining the
        block). The occupancy-reporting counterpart of
        :meth:`BlockAllocator.refcounts`: a block whose refcount equals its
        pin count is held *only* by registered prefixes — resident pool
        pressure that survives its last sharer's retirement, never free
        capacity. Kept here so both sides of the one-retain-per-entry
        invariant live in one module."""
        pin = np.zeros(n_blocks, np.int32)
        for e in self._entries.values():
            if e.block_ids is not None:
                for b in e.block_ids:
                    pin[b] += 1
        return pin

    def nbytes(self) -> int:
        """Device bytes pinned by prefix masters (counted by the bench as
        part of the paged KV footprint). Chain entries share one master
        buffer, so bytes are counted per unique array, not per entry."""
        total = 0
        seen: set[int] = set()
        for e in self._entries.values():
            for arr in (e.master_k, e.master_v, e.k_amax, e.v_amax):
                if arr is not None and id(arr) not in seen:
                    seen.add(id(arr))            # kv16 stores no masters at
                    total += int(arr.nbytes)     # all — pool blocks double
        return total                             # as the masters there

"""Continuous-batching scheduler over the fused decode scan.

The paper's runtime (§4.4, Fig. 4) is an adaptive inference engine that keeps
serving under a shifting energy budget — which presumes the serving layer
keeps the device *busy* under real, heterogeneous traffic. Static grouped
``serve()`` can't: a group must finish entirely before the next one starts, so
every finished row burns decode steps as dead padding and every queued request
waits for the whole group. This module replaces that with continuous batching:

**Slot pool.** The scheduler owns a fixed ``[max_batch]`` row pool whose
decode state (last token, position, KV/SSM caches) lives on device and is
threaded through *donated* jit boundaries — the pool buffers are updated in
place, never copied. A request occupies one row from admission to retirement;
free rows idle with ``remaining == 0`` (the done-mask freezes them, and MoE
capacity dispatch drops them via ``row_valid``).

**Segment quantum.** Decode runs in fixed-size segments of
:func:`repro.models.transformer.decode_segment` — ``quantum`` scan steps per
dispatch, all shapes static in ``(max_batch, quantum)``, so every segment of
the server's lifetime reuses ONE compiled executable no matter which rows are
live. The quantum is the admission latency knob: between segments, retired
rows are refilled from the FIFO queue by an *admission wave* — one ragged
prefill of every waiting request (rows bucketed to a power of two, prompts
left-padded to a power-of-two length bucket with ``prompt_len`` riding as
data → compile count log² rather than one executable per shape) whose
first tokens are argmaxed on device and whose cache rows are scattered into
the free slots, all inside a single donated dispatch. Token blocks come back
*asynchronously*: retirement and admission decisions need only host-side
``remaining`` counts, so the engine loop dispatches the next segment before
materializing the previous one's tokens (``_flush(keep=1)``) and host-side
scheduling overlaps device compute.

**Paged KV.** By default the pool's attention cache is *paged* (a global
block pool + per-row block tables — :mod:`repro.serving.paged` holds the
host-side allocator and shared-prefix registry, ``docs/serving.md`` the full
design): a row holds only the blocks its ``prompt + max_new`` actually
touch instead of a whole ``[slots]`` reservation, hash-matched prompt
prefixes are admitted with a suffix-only prefill against blocks that are
mapped rather than recomputed and re-stored, and a dry allocator turns into
FIFO queue backpressure rather than corruption.

**Why re-planning per segment keeps the ledger exact.** The
:class:`ProfileManager` policy is deterministic given its energy ledger, so
profile ids can be precomputed as data — but only as far ahead as the set of
live rows is known. A whole-generation schedule would bill rows that finish
(or get admitted) mid-flight. Planning exactly one segment ahead, with
:meth:`ProfileManager.plan_schedule_ragged` over the *actual* per-row
remaining budgets, bills step ``i`` for precisely the rows live at step ``i``
— the same ledger evolution as a per-step select/account oracle (admission
prefills are billed like the stepwise engine bills prefill: one inference).
Every billing event is recorded in :attr:`ContinuousScheduler.events` so the
tests can replay the ledger against that oracle.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from .engine import AdaptiveServer, Request, _next_pow2
from .paged import BlockAllocator, PrefixRegistry, prefix_keys

__all__ = ["ContinuousScheduler"]


class ContinuousScheduler:
    """FIFO continuous batching on an :class:`AdaptiveServer`'s slot pool.

    ``quantum`` = decode steps per segment (admission latency vs dispatch
    overhead); ``prefill_bucket`` = minimum power-of-two prompt padding.

    With ``ServingConfig.paged_kv`` (the default for attention stacks) the
    pool's KV state is *paged*: a global pool of fixed-size blocks plus
    per-row block tables (:class:`repro.models.attention.PagedKVCache`).
    Admission allocates exactly the blocks a request will touch
    (``ceil((prompt + max_new) / block_size)``, capped at the row's logical
    table) from a refcounted :class:`~repro.serving.paged.BlockAllocator`;
    retirement returns them. When the allocator cannot satisfy the FIFO
    head, admission simply stops for this wave — queue backpressure, never
    corruption of a live row — and resumes as rows retire (a request that
    could never fit the whole pool is rejected at :meth:`submit`). With
    ``prefix_cache``, prompts are block-hashed at enqueue and matched
    against a :class:`~repro.serving.paged.PrefixRegistry` at admission:
    hits skip the prefix prefill entirely and (at kv16) map the registered
    blocks copy-on-write instead of re-storing them.
    """

    def __init__(self, server: AdaptiveServer, quantum: int = 8,
                 prefill_bucket: int = 8, record_events: bool = True):
        """Build a scheduler (pool state + host bookkeeping) on ``server``.

        The jitted executables live on the server and are shared; the
        donated device pool (tok/pos/caches) and all queue/allocator/
        registry state are per-scheduler, so schedulers can be torn down
        and rebuilt without recompiling anything.
        """
        self.srv = server
        self.quantum = int(quantum)
        self.bucket_min = int(prefill_bucket)
        # events/admission_log power the ledger-oracle and FIFO tests; a
        # long-lived server should pass record_events=False (they grow with
        # every segment step). Per-request state (prompt, result) is evicted
        # by poll_completed(); run() keeps results for its return value.
        self.record_events = record_events
        cfg, scfg = server.cfg, server.scfg
        nslots = self.n_slots = scfg.max_batch
        self.paged = bool(scfg.paged_kv) and cfg.has_attn
        # device-resident pool state (donated through every jit below)
        if self.paged:
            self.block_size = server.block_size
            self.n_lblk = server.n_lblk
            nb = (scfg.pool_blocks if scfg.pool_blocks is not None
                  else nslots * self.n_lblk)
            self._caches = T.init_paged_caches(
                cfg, nslots, scfg.slots, kv_bits=scfg.kv_bits,
                block_size=self.block_size, pool_blocks=nb)
            self.allocator = BlockAllocator(nb, self.block_size)
            self.registry = (
                PrefixRegistry(self.allocator,
                               capacity=scfg.prefix_capacity)
                if server.prefix_sharing else None)
            self._slot_blocks: list = [None] * nslots  # (private_ids, entry)
            self._prefix_keys: dict[int, list[bytes]] = {}
            self.peak_used_blocks = 0
            # chunked prefill: long cold prompts prefill in block-aligned
            # chunks that interleave with decode segments instead of one
            # monolithic admission wave. A mid-admission row occupies its
            # slot + blocks but is not yet live (remaining == 0); its state
            # lives here until the final chunk lands.
            self.chunk = server.chunk_tokens
            self._chunk_state: dict[int, dict] = {}    # slot -> progress
        else:
            self._caches = T.init_caches(cfg, nslots, scfg.slots,
                                         kv_bits=scfg.kv_bits)
            self.allocator = None
            self.registry = None
        self._tok = jnp.zeros((nslots,), jnp.int32)
        self._pos = jnp.zeros((nslots,), jnp.int32)
        # host bookkeeping
        self.remaining = np.zeros((nslots,), np.int64)   # tokens left to emit
        self.slot_req: list[Optional[int]] = [None] * nslots
        self._slot_crit = np.zeros((nslots,), bool)
        self.queue: deque[int] = deque()                 # FIFO pending rids
        self._reqs: dict[int, Request] = {}
        self.results: dict[int, dict] = {}
        self._n = 0
        self.admission_log: list[int] = []               # rids, admission order
        self.events: list[tuple[int, int, bool]] = []    # (pid, n_rows, crit)
        self._done: list[int] = []                       # completions, in order
        self._inflight: list[dict] = []                  # dispatched, unsynced
        # the jitted segment/admit executables live on the server, so
        # schedulers can be torn down and rebuilt without recompiling
        self._segment = server._segment
        self._admit = server._admit
        self._admit_paged = server._admit_paged
        self._admit_shared = server._admit_shared
        self._clear = server._clear_rows

    # ------------------------------------------------------------- paged util
    def _blocks_needed(self, prompt_len: int, max_new: int) -> int:
        """Physical blocks a request touches over its whole lifetime:
        prompt positions + every decode write, capped at the row's logical
        table (sliding-window rings reuse their blocks by design)."""
        return min(self.n_lblk,
                   -(-(prompt_len + max_new) // self.block_size))

    def paged_stats(self) -> dict:
        """Block-pool occupancy + prefix-registry counters (bench JSON).

        Occupancy is **refcount-accurate**: ``used_blocks`` derives from the
        allocator's per-block reference counts (not the free-list length)
        and splits into ``live_blocks`` (at least one live-row reference)
        vs ``registry_only_blocks`` (blocks a registered prefix keeps
        resident after their last sharer retired — still pool pressure,
        not free capacity, which is what the bench's saving assertion must
        measure).
        """
        if not self.paged:
            return {"paged": False,
                    "kv_bytes": T.cache_bytes(self._caches)}
        ref = self.allocator.refcounts()
        pin = (self.registry.pinned_counts(self.allocator.n_blocks)
               if self.registry is not None else np.zeros_like(ref))
        used = int((ref > 0).sum())
        registry_only = int(((ref > 0) & (ref <= pin)).sum())
        out = {
            "paged": True,
            "block_size": self.block_size,
            "pool_blocks": self.allocator.n_blocks,
            "used_blocks": used,
            "live_blocks": used - registry_only,
            "registry_only_blocks": registry_only,
            "peak_used_blocks": self.peak_used_blocks,
            # deliberately the free-LIST length, while used_blocks derives
            # from refcounts: used + free == pool is then a real cross-check
            # between the two bookkeeping structures (the bench asserts it),
            # not an arithmetic identity
            "free_blocks": self.allocator.free_blocks,
            "kv_bytes": T.cache_bytes(self._caches),
            "registry_bytes": 0,
        }
        if self.registry is not None:
            out.update(registry_entries=len(self.registry),
                       registry_hits=self.registry.hits,
                       registry_misses=self.registry.misses,
                       registry_bytes=self.registry.nbytes())
        return out

    # ------------------------------------------------------------------ queue
    def submit(self, request: Request) -> int:
        """Enqueue a request (FIFO). Returns its request id.

        Paged pools validate the request up front: one that could never fit
        (more blocks than the whole pool provisions, or — when prefix
        sharing is active — ``prompt + max_new ≥`` the virtual row length,
        which would let its post-retirement ring position wrap onto a
        potentially shared block) raises ``ValueError`` here, cleanly,
        rather than corrupting live rows later. Transient fullness is *not*
        an error: the request queues and admission backpressure holds it
        until blocks free up.
        """
        if self.paged and request.max_new > 0:
            plen = len(request.tokens)
            cfg = self.srv.cfg
            if not cfg.sliding_window and self.registry is not None \
                    and plen + request.max_new >= self.srv.slots_p:
                raise ValueError(
                    f"request needs {plen + request.max_new} KV slots but a "
                    f"prefix-sharing paged pool caps rows at "
                    f"{self.srv.slots_p - 1} (slots={self.srv.scfg.slots})")
            need = self._blocks_needed(plen, request.max_new)
            if need > self.allocator.n_blocks:
                raise ValueError(
                    f"request needs {need} KV blocks but the pool has only "
                    f"{self.allocator.n_blocks} "
                    f"(block_size={self.block_size})")
        rid = self._n
        self._n += 1
        self._reqs[rid] = request
        if request.max_new <= 0:        # nothing to generate: done on arrival
            self.results[rid] = {"tokens": [], "profile_trace": []}
            self._done.append(rid)
            return rid
        if self.paged and self.registry is not None:
            # hash block-aligned prefixes once, at enqueue; admission just
            # dictionary-matches them against the registry
            self._prefix_keys[rid] = prefix_keys(
                np.asarray(request.tokens, np.int32), self.block_size)
        self.queue.append(rid)
        return rid

    @property
    def live_rows(self) -> int:
        """Pool rows still generating (``remaining > 0``)."""
        return int((self.remaining > 0).sum())

    @property
    def pending(self) -> int:
        """Requests queued but not yet admitted (FIFO depth)."""
        return len(self.queue)

    def poll_completed(self) -> list[tuple[int, dict]]:
        """``(rid, result)`` pairs finished since the last poll (completion
        order). Ownership of each result transfers to the caller: the
        scheduler evicts the request's retained state, so a long-lived
        polling server stays O(pool), not O(requests ever served)."""
        done, self._done = self._done, []
        out = []
        for rid in done:
            out.append((rid, self.results.pop(rid)))
            self._reqs.pop(rid, None)
            if self.paged and self.registry is not None:
                self._prefix_keys.pop(rid, None)
        return out

    # -------------------------------------------------------------- admission
    def admit(self) -> int:
        """Fill free slots from the FIFO queue; returns #requests admitted.

        One admission *wave* is ONE device dispatch: every admitted request
        rides in a single ragged prefill (left-padded to a shared pow2 prompt
        bucket, ``prompt_len`` as data — one executable per bucket), first
        tokens come from an on-device argmax, and each prefilled row is
        scattered into its free pool slot, all inside the server's donated
        admit jit. The wave's prefills are billed like the stepwise engine
        bills prefill: one inference per admitted request.

        Paged pools add two twists. Admission is gated on *blocks* as well
        as slots: candidates are taken strictly FIFO and the wave stops at
        the first request the allocator cannot satisfy (backpressure).
        And a candidate whose enqueue-time prefix hashes hit the registry
        joins a separate *shared* wave — one ``_admit_shared`` dispatch
        that prefills only the suffixes (prefix KV replayed from the
        registered masters) and maps the shared blocks copy-on-write —
        while cold candidates ride the usual full-prefill wave; at most two
        dispatches per admission round.
        """
        if self.paged:
            return self._admit_paged_waves()
        free = [s for s in range(self.n_slots) if self.slot_req[s] is None]
        take = min(len(free), len(self.queue))
        if not take:
            return 0
        rids = [self.queue.popleft() for _ in range(take)]
        slots = free[:take]
        reqs = [self._reqs[r] for r in rids]
        bucket = _next_pow2(max(self.bucket_min,
                                max(len(r.tokens) for r in reqs)))
        a = _next_pow2(take)               # pow2 wave shape (pad rows drop):
        # a 1–2 row refill costs a 2-row prefill, not a full-pool one, and
        # the executable count stays log² (row bucket × length bucket)
        prompts = np.zeros((a, bucket), np.int32)
        plen = np.zeros((a,), np.int32)    # pad rows: prompt_len 0 → masked
        sidx = np.full((a,), self.n_slots, np.int32)     # OOB → scatter-drop
        for j, r in enumerate(reqs):
            t = np.asarray(r.tokens, np.int32)
            prompts[j, bucket - len(t):] = t             # left-pad
            plen[j] = len(t)
            sidx[j] = slots[j]
        mgr = self.srv.manager
        crit = any(r.accuracy_critical for r in reqs)
        pid = 0 if mgr is None else mgr.select(crit)
        if mgr is not None:
            mgr.account(pid, take)
        if self.record_events:
            self.events.append((pid, take, crit))
        tok0, self._tok, self._pos, self._caches = self._admit(
            pid,
            {"tokens": jnp.asarray(prompts),
             "prompt_len": jnp.asarray(plen)},
            jnp.asarray(sidx), self._tok, self._pos, self._caches)
        entry = {"kind": "admit", "toks": tok0,
                 "name": self.srv.engine.profile_names[pid],
                 "rows": [], "completes": []}
        for j, (rid, slot) in enumerate(zip(rids, slots)):
            req = self._reqs[rid]
            self.results[rid] = {"tokens": [], "profile_trace": []}
            entry["rows"].append((j, rid))
            if self.record_events:
                self.admission_log.append(rid)
            if req.max_new == 1:                         # already complete
                entry["completes"].append(rid)
                continue
            self.slot_req[slot] = rid
            self._slot_crit[slot] = req.accuracy_critical
            self.remaining[slot] = req.max_new - 1
        self._inflight.append(entry)
        return take

    def _admit_paged_waves(self) -> int:
        """FIFO claim of slots *and* blocks, then ≤3 dispatches per round
        (cold+first-chunk wave / shared wave / chunk-continuation wave;
        the rare deferred-registration-failure fallback adds one more
        combined cold wave).

        Candidates classify four ways: registry hits join the *shared*
        wave; cold prompts longer than ``chunk`` become *chunked* (their
        first chunk rides the cold wave, the rest follows one chunk per
        admission round); a cold candidate whose prefix will be registered
        by an earlier candidate of THIS round's cold wave is *deferred* —
        intra-wave prefix dedup: it resolves against the registry right
        after the cold wave dispatches (and registers), so two identical
        prompts arriving in the same cold wave no longer both prefill the
        prefix. Everything else is plain cold.

        One FIFO caveat rides on deferral: if the registered prefix turns
        out shorter than assumed AND the top-up allocation fails, the
        deferred request rolls back to the queue head for the next round —
        requests behind it in this round's waves were already dispatched.
        Rollbacks keep their relative order; the strict stop-at-first-
        failure contract otherwise holds.
        """
        free = [s for s in range(self.n_slots)
                if self.slot_req[s] is None and s not in self._chunk_state]
        cold, shared, deferred, chunked = [], [], [], []
        pending: dict[bytes, int] = {}   # key -> n_tokens this wave registers
        while free and self.queue:
            rid = self.queue[0]
            req = self._reqs[rid]
            plen = len(req.tokens)
            need = self._blocks_needed(plen, req.max_new)
            keys = self._prefix_keys.get(rid, [])
            entry, wait, n_shared = None, False, 0
            if self.registry is not None:
                entry = self.registry.lookup(keys)
            if entry is not None:
                self.registry.acquire(entry)     # pins it through eviction
                if entry.block_ids is not None:  # kv16: map, don't re-store
                    n_shared = entry.n_tokens // self.block_size
            elif pending:
                for k in keys:                   # longest-first, like lookup
                    if k in pending:
                        wait = True
                        if self.srv.scfg.kv_bits == 16:
                            n_shared = pending[k] // self.block_size
                        break
            n_priv = need - n_shared
            if self.allocator.free_blocks < n_priv and \
                    self.registry is not None:
                self.registry.evict_for(n_priv)
            blocks = self.allocator.alloc(n_priv)
            if blocks is None:                   # backpressure: head waits,
                if entry is not None:            # FIFO order preserved
                    self.registry.release(entry)
                break
            self.queue.popleft()
            slot = free.pop(0)
            if self.registry is not None and not wait:
                self.registry.record_admission(entry)
            if entry is not None:
                shared.append((rid, slot, entry, blocks))
            elif wait:
                deferred.append((rid, slot, blocks, keys))
            elif self.chunk and plen > self.chunk:
                chunked.append((rid, slot, blocks))
            else:
                cold.append((rid, slot, blocks))
                if self.registry is not None:
                    j_max = (plen - 1) // self.block_size
                    for i, k in enumerate(keys):     # chain, longest first
                        pending.setdefault(
                            k, (j_max - i) * self.block_size)
        n = 0
        if cold or chunked:
            n += self._dispatch_cold(cold, chunked)
        rollback: list[int] = []
        fb_cold, fb_chunked = [], []     # registration-failure fallbacks,
        for rid, slot, blocks, keys in deferred:   # batched into ONE wave
            # the cold wave above has dispatched and registered its chains;
            # a deferred candidate now hits the registry like any other.
            # The entry actually registered may cover a different prefix
            # length than the deferral assumed (LRU capacity), so square up
            # the private-block allocation before dispatching.
            req = self._reqs[rid]
            need = self._blocks_needed(len(req.tokens), req.max_new)
            entry = self.registry.lookup(keys)
            n_shared = (entry.n_tokens // self.block_size
                        if entry is not None and entry.block_ids is not None
                        else 0)
            n_priv = need - n_shared
            if len(blocks) > n_priv:
                self.allocator.release(blocks[n_priv:])
                blocks = blocks[:n_priv]
            elif len(blocks) < n_priv:
                extra = self.allocator.alloc(n_priv - len(blocks))
                if extra is None:
                    self.allocator.release(blocks)   # roll the request back
                    rollback.append(rid)             # (requeued in order
                    continue                         # after the loop)
                blocks = blocks + extra
            if entry is not None:
                self.registry.acquire(entry)
                self.registry.record_admission(entry)
                shared.append((rid, slot, entry, blocks))
            else:   # registration failed (capacity full of in-use entries)
                self.registry.record_admission(None)
                if self.chunk and len(req.tokens) > self.chunk:
                    # a long prompt falling back cold still chunks — the
                    # monolithic-wave stall is what chunking exists to avoid
                    fb_chunked.append((rid, slot, blocks))
                else:
                    fb_cold.append((rid, slot, blocks))
        if fb_cold or fb_chunked:
            n += self._dispatch_cold(fb_cold, fb_chunked)
        for rid in reversed(rollback):      # preserve their relative order
            self.queue.appendleft(rid)
        if shared:
            n += self._dispatch_shared(shared)
        if n:
            self.peak_used_blocks = max(self.peak_used_blocks,
                                        self.allocator.used_blocks)
        self._advance_chunks()
        return n

    def _bill(self, reqs) -> int:
        """Select/account the wave's profile (one inference per request)."""
        mgr = self.srv.manager
        crit = any(r.accuracy_critical for r in reqs)
        pid = 0 if mgr is None else mgr.select(crit)
        if mgr is not None:
            mgr.account(pid, len(reqs))
        if self.record_events:
            self.events.append((pid, len(reqs), crit))
        return pid

    def _pad_slot_idx(self, slots: list) -> jnp.ndarray:
        """Fixed-shape ``[n_slots]`` slot-index vector (OOB-padded) so row
        clearing reuses one executable regardless of how many rows retire."""
        out = np.full((self.n_slots,), self.n_slots, np.int32)
        out[:len(slots)] = slots
        return jnp.asarray(out)

    def _dispatch_cold(self, rows, chunked=()) -> int:
        """One ``_admit_paged`` wave: full ragged prefill + block scatter.

        ``chunked`` rows ride the same wave but prefill only their FIRST
        ``chunk`` tokens; the rest of the prompt follows one chunk per
        admission round through :meth:`_advance_chunks` continuation waves.
        A chunked row holds its slot and blocks from here on but is not yet
        live (``remaining`` stays 0 — the done-mask keeps it frozen through
        the decode segments that run between its chunks).
        """
        allrows = list(rows) + list(chunked)
        n_cold = len(rows)
        reqs = [self._reqs[rid] for rid, _, _ in allrows]
        lens = [len(r.tokens) if j < n_cold else min(len(r.tokens), self.chunk)
                for j, r in enumerate(reqs)]
        bucket = _next_pow2(max(self.bucket_min, max(lens)))
        a = _next_pow2(len(allrows))
        nb_oob = self.allocator.n_blocks
        prompts = np.zeros((a, bucket), np.int32)
        plen = np.zeros((a,), np.int32)
        sidx = np.full((a,), self.n_slots, np.int32)
        dest = np.full((a, self.n_lblk), nb_oob, np.int32)
        for j, (rid, slot, blocks) in enumerate(allrows):
            t = np.asarray(reqs[j].tokens, np.int32)[:lens[j]]
            prompts[j, bucket - lens[j]:] = t                # left-pad
            plen[j] = lens[j]
            sidx[j] = slot
            dest[j, :len(blocks)] = blocks
        pid = self._bill(reqs)
        tok0, raw, self._tok, self._pos, self._caches = self._admit_paged(
            pid,
            {"tokens": jnp.asarray(prompts),
             "prompt_len": jnp.asarray(plen)},
            jnp.asarray(sidx), jnp.asarray(dest),
            self._tok, self._pos, self._caches)
        if self.registry is not None and rows:
            self._register_prefixes(rows, reqs[:n_cold], raw, bucket)
        for off, (rid, slot, blocks) in enumerate(chunked):
            j = n_cold + off
            st = {"rid": rid, "blocks": blocks, "done": lens[j],
                  "fresh": True,   # chunk 2 waits for the next round — one
                                   # chunk wave per row per admission round
                  "pid": pid,      # profile pinned for the WHOLE prompt:
                                   # a monolithic admission prefills under
                                   # one profile, so chunks must too or the
                                   # row's KV would mix precisions no cold
                                   # path can produce (token identity)
                  "mk": None, "mv": None, "ka": None, "va": None}
            if raw is not None:
                # int KV: keep the chunk's pre-quantization K/V + running
                # amax so the next chunk can replay it as its prefix
                # masters (the exact-scale recalibration path)
                k_all, v_all = raw
                c0 = bucket - lens[j]
                st["mk"] = k_all[:, j, c0:].astype(jnp.float32)
                st["mv"] = v_all[:, j, c0:].astype(jnp.float32)
                st["ka"] = jnp.max(jnp.abs(st["mk"]), axis=(1, 3))
                st["va"] = jnp.max(jnp.abs(st["mv"]), axis=(1, 3))
            self._chunk_state[slot] = st
            self.results[rid] = {"tokens": [], "profile_trace": []}
            if self.record_events:
                self.admission_log.append(rid)
        self._post_admission(tok0, self.srv.engine.profile_names[pid],
                             [(j, rid, slot, blocks, None)
                              for j, (rid, slot, blocks) in enumerate(rows)])
        return len(allrows)

    def _register_prefixes(self, rows, reqs, raw, bucket: int) -> None:
        """Pin each new prompt's longest block-aligned prefix for reuse.

        The whole block-aligned prefix CHAIN registers, longest first —
        key ``j`` covers ``j·bs`` tokens — because the next prompt's
        shared span is unknown: a request whose unique tail crosses a
        block boundary must still hit the shorter shared-prefix keys
        (registering only the longest key would fold tail tokens into
        every hash and never match a multi-tenant system prompt). Every
        key of the chain is offered — ``register`` no-ops on present ones
        — because LRU eviction removes single entries, so a present long
        key does NOT imply its shorter companions survived.

        At kv16 each entry refcounts the row's first ``j`` blocks so they
        survive the row's retirement and later admissions can map them in
        place — the pool's bf16 blocks double as the masters, so nothing
        else is stored. At int KV precisions the pool rows sit on the
        owner's quantization grid, so entries instead snapshot the wave's
        pre-quantization K/V (one lazily-sliced device array shared by
        the whole chain) plus per-length raw amax that re-calibrate
        scales exactly.
        """
        kv16 = self.srv.scfg.kv_bits == 16
        bs = self.block_size
        for j, (rid, slot, blocks) in enumerate(rows):
            t = np.asarray(reqs[j].tokens, np.int32)
            j_max = (len(t) - 1) // bs
            mk = mv = None
            if not kv16 and j_max >= 1:
                k_all, v_all = raw
                c0 = bucket - len(t)
                mk = k_all[:, j, c0:c0 + j_max * bs].astype(jnp.float32)
                mv = v_all[:, j, c0:c0 + j_max * bs].astype(jnp.float32)
            self._register_chain(rid, j_max, blocks, mk, mv)

    def _register_chain(self, rid: int, j_max: int, blocks,
                        mk, mv) -> None:
        """Offer every key of one prompt's prefix chain to the registry —
        the single home of the chain invariants (see
        :meth:`_register_prefixes`): every key is offered because LRU
        evicts single entries; kv16 entries pin ``blocks[:n_blk]`` (the
        pool is its own master); int-KV entries share the ONE master
        buffer ``mk``/``mv`` (already truncated to ``j_max`` blocks) and
        snapshot per-length raw amax — O(chain), not O(chain²), memory.
        Used by cold-wave registration and chunked-admission completion.
        """
        keys = self._prefix_keys.get(rid)
        if j_max < 1 or not keys:
            return
        bs = self.block_size
        for i, key in enumerate(keys):           # longest first
            if self.registry.contains(key):
                continue
            n_blk = j_max - i
            n_tok = n_blk * bs
            if mk is None:                       # kv16: pin pool blocks
                self.registry.register(key, n_tok, blocks[:n_blk],
                                       None, None, None, None)
            else:
                ka = jnp.max(jnp.abs(mk[:, :n_tok]), axis=(1, 3))
                va = jnp.max(jnp.abs(mv[:, :n_tok]), axis=(1, 3))
                self.registry.register(key, n_tok, None, mk, mv, ka, va)

    def _call_admit_shared(self, pid, batch, sidx, dest, bt_rows, plen_pre,
                           pp: int, pre: list):
        """Assemble the prefix operands and dispatch one ``_admit_shared``
        wave — the single place that knows the continuation executable's
        calling convention, shared by registry-hit admissions
        (:meth:`_dispatch_shared`) and chunk continuations
        (:meth:`_dispatch_chunks`).

        ``pre``: one ``(n_tok, block_ids, mk, mv, ka, va)`` tuple per wave
        row. At kv16 the prefix is gathered in-jit from ``block_ids`` (the
        bf16 pool is its own master); at int KV the full-precision masters
        ``mk``/``mv`` (sliced to ``n_tok`` — chain entries share one
        buffer — and padded to the ``pp`` bucket) are replayed with their
        raw amax. Returns ``(tok0, raw)``.
        """
        cfg = self.srv.cfg
        a = dest.shape[0]
        nb_oob = self.allocator.n_blocks
        if self.srv.scfg.kv_bits == 16:
            pb = pp // self.block_size
            pre_bids = np.full((a, pb), nb_oob, np.int32)
            for j, (n_tok, bids, *_rest) in enumerate(pre):
                nbl = n_tok // self.block_size
                pre_bids[j, :nbl] = bids[:nbl]
            tok0, raw, self._tok, self._pos, self._caches = \
                self._admit_shared(
                    pid, batch, jnp.asarray(sidx), jnp.asarray(dest),
                    jnp.asarray(bt_rows), jnp.asarray(pre_bids),
                    jnp.asarray(plen_pre), self._tok, self._pos,
                    self._caches)
            return tok0, raw

        def padm(m, n_tok):
            m = m[:, :n_tok].astype(jnp.float32)
            return (m if n_tok == pp else
                    jnp.pad(m, ((0, 0), (0, pp - n_tok), (0, 0), (0, 0))))

        zk = jnp.zeros((cfg.n_layers, pp, cfg.n_kv, cfg.hd), jnp.float32)
        za = jnp.zeros((cfg.n_layers, cfg.n_kv), jnp.float32)
        npad = a - len(pre)
        kpre = jnp.stack([padm(mk, n) for n, _, mk, _, _, _ in pre]
                         + [zk] * npad, axis=1)
        vpre = jnp.stack([padm(mv, n) for n, _, _, mv, _, _ in pre]
                         + [zk] * npad, axis=1)
        ka = jnp.stack([ka_ for *_x, ka_, _va in pre] + [za] * npad, axis=1)
        va = jnp.stack([va_ for *_x, va_ in pre] + [za] * npad, axis=1)
        tok0, raw, self._tok, self._pos, self._caches = self._admit_shared(
            pid, batch, jnp.asarray(sidx), jnp.asarray(dest),
            jnp.asarray(bt_rows), kpre, vpre, ka, va,
            jnp.asarray(plen_pre), self._tok, self._pos, self._caches)
        return tok0, raw

    def _dispatch_shared(self, rows) -> int:
        """One ``_admit_shared`` wave: suffix-only continuation prefill."""
        bs = self.block_size
        reqs = [self._reqs[rid] for rid, _, _, _ in rows]
        sufs = [np.asarray(r.tokens, np.int32)[e.n_tokens:]
                for r, (_, _, e, _) in zip(reqs, rows)]
        sb = _next_pow2(max(self.bucket_min, max(len(s) for s in sufs)))
        pp = bs * _next_pow2(max(-(-e.n_tokens // bs)
                                 for _, _, e, _ in rows))
        a = _next_pow2(len(rows))
        nb_oob = self.allocator.n_blocks
        prompts = np.zeros((a, sb), np.int32)
        slen = np.zeros((a,), np.int32)
        plen_pre = np.zeros((a,), np.int32)
        sidx = np.full((a,), self.n_slots, np.int32)
        dest = np.full((a, self.n_lblk), nb_oob, np.int32)
        bt_rows = np.full((a, self.n_lblk), nb_oob, np.int32)
        for j, ((rid, slot, e, blocks), suf) in enumerate(zip(rows, sufs)):
            prompts[j, sb - len(suf):] = suf                 # left-pad
            slen[j] = len(suf)
            plen_pre[j] = e.n_tokens
            sidx[j] = slot
            ns = e.n_tokens // bs if e.block_ids is not None else 0
            if ns:
                bt_rows[j, :ns] = e.block_ids[:ns]           # mapped, shared
            bt_rows[j, ns:ns + len(blocks)] = blocks         # private tail
            dest[j, ns:ns + len(blocks)] = blocks            # only these get
        ents = [e for _, _, e, _ in rows]                    # written (CoW)
        pid = self._bill(reqs)
        batch = {"tokens": jnp.asarray(prompts),
                 "prompt_len": jnp.asarray(slen)}
        tok0, _ = self._call_admit_shared(
            pid, batch, sidx, dest, bt_rows, plen_pre, pp,
            [(e.n_tokens, e.block_ids, e.master_k, e.master_v,
              e.k_amax, e.v_amax) for e in ents])
        self._post_admission(tok0, self.srv.engine.profile_names[pid],
                             [(j, rid, slot, blocks, e)
                              for j, (rid, slot, e, blocks)
                              in enumerate(rows)])
        return len(rows)

    def _advance_chunks(self) -> None:
        """Advance every mid-admission chunked row by one prompt chunk.

        Called once per admission round, BETWEEN decode segments — that
        interleaving is the whole point: a 4-chunk prompt costs four small
        continuation dispatches with decode quanta in between instead of
        one monolithic wave that stalls every live row for the full
        prompt's prefill.
        """
        if not self._chunk_state:
            return
        waves: dict[int, list] = {}          # rows grouped by pinned profile
        for slot in sorted(self._chunk_state):
            st = self._chunk_state[slot]
            if st.pop("fresh", False):       # admitted this round: a decode
                continue                     # segment runs before chunk 2
            t = np.asarray(self._reqs[st["rid"]].tokens, np.int32)
            clen = min(self.chunk, len(t) - st["done"])
            waves.setdefault(st["pid"], []).append(
                (slot, st, t[st["done"]:st["done"] + clen]))
        for pid, rows in waves.items():
            self._dispatch_chunks(pid, rows)

    def _dispatch_chunks(self, pid: int, rows) -> None:
        """One continuation wave over ``(slot, state, chunk_tokens)`` rows,
        all pinned to profile ``pid`` (the one their first chunk billed).

        Reuses the shared-prefix executable verbatim: the "prefix" is the
        row's own previously processed tokens — gathered from its own pool
        blocks at kv16 (chunk boundaries are block-aligned by
        construction), replayed from the accumulated full-precision
        masters at int KV. ``dest`` rewrites ALL of the row's blocks each
        chunk, which both lands the new chunk and scrubs any junk a frozen
        row's residual decode writes parked there between chunks. Rows
        whose final chunk lands go live (``remaining = max_new − 1``) with
        their first generated token coming from this wave's argmax —
        exactly the cold admission contract.
        """
        bs = self.block_size
        sb = _next_pow2(max(self.bucket_min,
                            max(len(c) for _, _, c in rows)))
        pp = bs * _next_pow2(max(st["done"] // bs for _, st, _ in rows))
        a = _next_pow2(len(rows))
        nb_oob = self.allocator.n_blocks
        prompts = np.zeros((a, sb), np.int32)
        slen = np.zeros((a,), np.int32)
        plen_pre = np.zeros((a,), np.int32)
        sidx = np.full((a,), self.n_slots, np.int32)
        dest = np.full((a, self.n_lblk), nb_oob, np.int32)
        bt_rows = np.full((a, self.n_lblk), nb_oob, np.int32)
        for j, (slot, st, chunk) in enumerate(rows):
            prompts[j, sb - len(chunk):] = chunk             # left-pad
            slen[j] = len(chunk)
            plen_pre[j] = st["done"]
            sidx[j] = slot
            blocks = st["blocks"]
            bt_rows[j, :len(blocks)] = blocks
            dest[j, :len(blocks)] = blocks   # all private: rewrite wholesale
        # continuation waves reuse the pinned profile and bill nothing new —
        # the request was billed its one prefill inference at the first
        # chunk, and re-selecting here could mix precisions within one
        # prompt's KV (no monolithic admission can produce that state)
        batch = {"tokens": jnp.asarray(prompts),
                 "prompt_len": jnp.asarray(slen)}
        tok0, raw = self._call_admit_shared(
            pid, batch, sidx, dest, bt_rows, plen_pre, pp,
            [(st["done"], st["blocks"], st["mk"], st["mv"],
              st["ka"], st["va"]) for _, st, _ in rows])
        entry = {"kind": "admit", "toks": tok0,
                 "name": self.srv.engine.profile_names[pid],
                 "rows": [], "completes": []}
        clear = []
        for j, (slot, st, chunk) in enumerate(rows):
            st["done"] += len(chunk)
            if raw is not None:
                k_all, v_all = raw
                c0 = sb - len(chunk)
                new_k = k_all[:, j, c0:].astype(jnp.float32)
                new_v = v_all[:, j, c0:].astype(jnp.float32)
                st["mk"] = jnp.concatenate([st["mk"], new_k], axis=1)
                st["mv"] = jnp.concatenate([st["mv"], new_v], axis=1)
                st["ka"] = jnp.maximum(
                    st["ka"], jnp.max(jnp.abs(new_k), axis=(1, 3)))
                st["va"] = jnp.maximum(
                    st["va"], jnp.max(jnp.abs(new_v), axis=(1, 3)))
            rid = st["rid"]
            req = self._reqs[rid]
            if st["done"] < len(req.tokens):
                continue                       # more chunks to go
            # final chunk: the row goes live exactly like a cold admission
            del self._chunk_state[slot]
            entry["rows"].append((j, rid))
            self._register_chunked(rid, st)
            if req.max_new == 1:               # done on arrival
                entry["completes"].append(rid)
                self.allocator.release(st["blocks"])
                clear.append(slot)
                continue
            self.slot_req[slot] = rid
            self._slot_crit[slot] = req.accuracy_critical
            self.remaining[slot] = req.max_new - 1
            self._slot_blocks[slot] = (st["blocks"], None)
        if clear:
            self._caches = self._clear(self._pad_slot_idx(clear),
                                       self._caches)
        if entry["rows"]:
            self._inflight.append(entry)

    def _register_chunked(self, rid: int, st: dict) -> None:
        """Register a finished chunked prompt's prefix chain for reuse —
        same chain discipline as :meth:`_register_prefixes`, sourced from
        the row's own blocks (kv16) / accumulated masters (int KV)."""
        if self.registry is None:
            return
        t = np.asarray(self._reqs[rid].tokens, np.int32)
        j_max = (len(t) - 1) // self.block_size
        mk = mv = None
        if self.srv.scfg.kv_bits != 16 and j_max >= 1:
            # one master buffer for the whole chain, truncated to the
            # registrable span (entries slice by their own n_tokens)
            mk = st["mk"][:, :j_max * self.block_size]
            mv = st["mv"][:, :j_max * self.block_size]
        self._register_chain(rid, j_max, st["blocks"], mk, mv)

    def _post_admission(self, tok0, pname: str, rows) -> None:
        """Common post-dispatch bookkeeping for paged admission waves.

        ``rows``: ``(wave_row, rid, slot, private_blocks, registry_entry)``.
        ``max_new == 1`` rows complete at admission: their blocks go straight
        back to the allocator and their (never-live) slot's block table is
        cleared so residual dead-row writes can't follow the blocks to their
        next owner.
        """
        entry = {"kind": "admit", "toks": tok0, "name": pname,
                 "rows": [], "completes": []}
        clear = []
        for j, rid, slot, blocks, reg in rows:
            req = self._reqs[rid]
            self.results[rid] = {"tokens": [], "profile_trace": []}
            entry["rows"].append((j, rid))
            if self.record_events:
                self.admission_log.append(rid)
            if req.max_new == 1:                             # done on arrival
                entry["completes"].append(rid)
                self.allocator.release(blocks)
                if reg is not None:
                    self.registry.release(reg)
                clear.append(slot)
                continue
            self.slot_req[slot] = rid
            self._slot_crit[slot] = req.accuracy_critical
            self.remaining[slot] = req.max_new - 1
            self._slot_blocks[slot] = (blocks, reg)
        if clear:
            self._caches = self._clear(self._pad_slot_idx(clear),
                                       self._caches)
        self._inflight.append(entry)

    # --------------------------------------------------------------- decoding
    def run_segment(self) -> None:
        """One decode segment over the pool: plan ``quantum`` steps against
        the live rows, dispatch the fused scan, distribute tokens, retire."""
        q = self.quantum
        mgr = self.srv.manager
        rem = self.remaining
        if mgr is None:
            sched = np.zeros((q,), np.int32)
        else:
            sched = mgr.plan_schedule_ragged(q, rem, self._slot_crit)
        if self.record_events:
            for i in range(q):
                live_i = rem > i
                self.events.append((int(sched[i]), int(live_i.sum()),
                                    bool((self._slot_crit & live_i).any())))
        toks, self._tok, self._pos, self._caches = self._segment(
            jnp.asarray(sched), self._tok, self._pos, self._caches,
            jnp.asarray(self.remaining, jnp.int32))
        # retirement depends only on host-side remaining counts, never on
        # token *values* — so bookkeeping (and the next admission/segment
        # dispatch) proceeds without materializing ``toks``
        entry = {"kind": "seg", "toks": toks, "sched": sched,
                 "rows": [], "completes": []}
        retired: list[int] = []
        for slot in range(self.n_slots):
            rid = self.slot_req[slot]
            if rid is None:
                continue
            n = int(min(self.remaining[slot], q))
            entry["rows"].append((slot, rid, n))
            self.remaining[slot] -= n
            if self.remaining[slot] == 0:                # retire → refillable
                self.slot_req[slot] = None
                self._slot_crit[slot] = False
                entry["completes"].append(rid)
                retired.append(slot)
        if self.paged and retired:
            # hand the rows' blocks back (shared prefix blocks just drop one
            # reference); their block tables need no host dispatch — the
            # segment already unmapped every row that finished inside it
            # (see decode_segment's writeback), so residual dead-row writes
            # can't follow the freed blocks to their next owner
            for slot in retired:
                blocks, reg = self._slot_blocks[slot]
                self.allocator.release(blocks)
                if reg is not None:
                    self.registry.release(reg)
                self._slot_blocks[slot] = None
        self._inflight.append(entry)

    def _flush(self, keep: int = 0) -> None:
        """Materialize in-flight token blocks into per-request results.

        ``keep`` leaves the newest entries un-synced: with ``keep=1`` the
        engine loop runs one segment ahead of the host sync, so planning,
        admission bookkeeping, and the next dispatch overlap device compute
        (async dispatch) instead of serializing on ``np.asarray`` per segment.
        A request counts as completed only once its tokens are materialized.
        """
        names = self.srv.engine.profile_names
        while len(self._inflight) > keep:
            e = self._inflight.pop(0)
            arr = np.asarray(e["toks"])                  # blocks until ready
            if e["kind"] == "admit":
                for j, rid in e["rows"]:
                    res = self.results[rid]
                    res["tokens"].append(int(arr[j]))
                    res["profile_trace"].append(e["name"])
            else:
                for slot, rid, n in e["rows"]:
                    res = self.results[rid]
                    res["tokens"].extend(arr[slot, :n].tolist())
                    res["profile_trace"].extend(
                        names[p] for p in e["sched"][:n])
            self._done.extend(e["completes"])

    # ------------------------------------------------------------------ drive
    def step(self) -> bool:
        """Admit then run one segment, keeping one segment in flight.
        Returns False once fully drained (all tokens materialized).
        Mid-admission chunked rows keep the loop alive: each step's
        ``admit`` advances them one chunk between decode segments."""
        self.admit()
        if self.live_rows:
            self.run_segment()
            self._flush(keep=1)
        else:
            self._flush()
        return bool(self.live_rows or self.queue or self._inflight
                    or (self.paged and self._chunk_state))

    def run(self) -> list[dict]:
        """Drain queue + pool; results in submission order (entries already
        claimed through poll_completed come back as None)."""
        while self.step():
            pass
        return [self.results.get(i) for i in range(self._n)]

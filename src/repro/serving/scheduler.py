"""Continuous-batching execution core over the fused decode scan.

The paper's runtime (§4.4, Fig. 4) is an adaptive inference engine that keeps
serving under a shifting energy budget — which presumes the serving layer
keeps the device *busy* under real, heterogeneous traffic. Static grouped
``serve()`` can't: a group must finish entirely before the next one starts, so
every finished row burns decode steps as dead padding and every queued request
waits for the whole group. This module replaces that with continuous batching.

Since the policy refactor this class is the **execution core** only: it owns
wave dispatch, segment running, flush, and the paged-block bookkeeping.
*Which* request admits next, which class binds which profile, and who gets
preempted for whom live in :mod:`repro.serving.policy`; the physical block
economy (refcounts, the retired-block LRU, prefix registration) lives in
:mod:`repro.serving.paged`.

**Slot pool.** The scheduler owns a fixed ``[max_batch]`` row pool whose
decode state (last token, position, KV/SSM caches) lives on device and is
threaded through *donated* jit boundaries — the pool buffers are updated in
place, never copied. A request occupies one row from admission to retirement;
free rows idle with ``remaining == 0`` (the done-mask freezes them, and MoE
capacity dispatch drops them via ``row_valid``).

**Segment quantum.** Decode runs in fixed-size segments of
:func:`repro.models.transformer.decode_segment` — ``quantum`` scan steps per
dispatch, all shapes static in ``(max_batch, quantum)``, so every segment of
the server's lifetime reuses ONE compiled executable no matter which rows are
live. The quantum is the admission latency knob: between segments, retired
rows are refilled from the policy queue by an *admission wave* — one ragged
prefill of every waiting request (rows bucketed to a power of two, prompts
left-padded to a power-of-two length bucket with ``prompt_len`` riding as
data → compile count log² rather than one executable per shape) whose
first tokens are argmaxed on device and whose cache rows are scattered into
the free slots, all inside a single donated dispatch. Token blocks come back
*asynchronously*: retirement and admission decisions need only host-side
``remaining`` counts, so the engine loop dispatches the next segment before
materializing the previous one's tokens (``_flush(keep=1)``) and host-side
scheduling overlaps device compute.

**Paged KV.** By default the pool's attention cache is *paged* (a global
block pool + per-row block tables — :mod:`repro.serving.paged` holds the
host-side allocator and shared-prefix registry, ``docs/serving.md`` the full
design): a row holds only the blocks its ``prompt + max_new`` actually
touch instead of a whole ``[slots]`` reservation, hash-matched prompt
prefixes are admitted with a suffix-only prefill against blocks that are
mapped rather than recomputed and re-stored, and a dry allocator turns into
queue backpressure — or, under a preemptive policy, a preemption decision —
rather than corruption.

**Preemption.** With :class:`ServingConfig.preemption`, an urgent arrival
that cannot admit evicts policy-chosen victim rows: :meth:`evict_row`
flushes, snapshots the victim's block table + host-side KV masters
(:class:`~repro.serving.paged.RowSnapshot`), releases its blocks (registered
prefixes park in the allocator's retired-block LRU), unmaps its table, and
requeues it at the front of its class. The suspended row later *resumes*
through the existing continuation-prefill executable — its whole written
span replayed as the prefix with an empty suffix, pure data movement that
rebuilds cache bytes, scales and carry **bit-exactly** — so a resumed row
continues token-identically to an uninterrupted run by construction, at
kv16 and kv8, shared-CoW rows included. An admission
round dispatches at most TWO prefill waves (cold / shared / resume /
chunk-continuation — an over-budget kind waits a round; imminent chunk
continuations pre-commit their share), and every decode segment still
runs the one
pool-lifetime ``_segment`` executable; ``tests/test_scheduler_policy.py``
guards both.

**Why re-planning per segment keeps the ledger exact.** The
:class:`ProfileManager` policy is deterministic given its energy ledger, so
profile ids can be precomputed as data — but only as far ahead as the set of
live rows is known. A whole-generation schedule would bill rows that finish
(or get admitted) mid-flight. Planning exactly one segment ahead, with
:meth:`ProfileManager.plan_schedule_classes` over the *actual* per-row
remaining budgets and priority-class bindings, bills step ``i`` for precisely
the rows live at step ``i`` — the same ledger evolution as a per-step
select/account oracle (admission prefills are billed like the stepwise
engine bills prefill: one inference). Suspension and resume bill **nothing
new**: the resume wave recomputes a token the row already emitted (and was
billed for), so a request's total billed inferences are invariant under
preemption. Every billing event is recorded in
:attr:`ContinuousScheduler.events` so the tests can replay the ledger
against that oracle.

**Fault tolerance.** Every request leaves through exactly one terminal
:class:`~repro.serving.engine.RequestStatus` on its result dict:
``COMPLETED`` (all tokens delivered), ``CANCELLED`` (:meth:`cancel` — queued
requests drop immediately, live rows are reaped at the next flush boundary
so billed inferences equal delivered tokens exactly), ``EXPIRED``
(``Request.deadline_ms`` passed, or admission predicts — from the step-time
EMA — that the deadline is unreachable and rejects up front), ``SHED``
(a :class:`~repro.serving.policy.ShedPolicy` judged the pool overloaded at
submission), or ``FAILED`` (quarantine retries exhausted). A row caught
producing non-finite logits (the per-row finite-check rides the decode-scan
carry — see :func:`repro.models.transformer.decode_segment`) is
*quarantined*: its blocks are released through the same machinery as
:meth:`evict_row`, its poisoned tokens are discarded (argmax over NaN is
garbage — a retry must restart from the prompt to be token-identical to a
clean run), its profile binding escalates one rung toward the accuracy
target (``accuracy_critical=True``), and it re-queues at its class front
after an exponential backoff, up to ``retry_budget`` attempts. Injected
chaos (:class:`~repro.serving.faults.FaultSchedule`) and the audit
(:meth:`check`, the ``paranoid`` mode) make all of this testable
deterministically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from .engine import AdaptiveServer, Request, RequestStatus, _next_pow2
from .faults import FaultSchedule, Watchdog
from .paged import BlockAllocator, PrefixRegistry, RowSnapshot, prefix_keys
from .policy import RowState, SchedulingPolicy, ShedPolicy, make_policy

__all__ = ["ContinuousScheduler"]


class ContinuousScheduler:
    """Continuous batching on an :class:`AdaptiveServer`'s slot pool.

    ``quantum`` = decode steps per segment (admission latency vs dispatch
    overhead); ``prefill_bucket`` = minimum power-of-two prompt padding;
    ``policy`` = the :class:`~repro.serving.policy.SchedulingPolicy` that
    owns request ordering, class→profile binding and preemption (defaults
    to the one :func:`~repro.serving.policy.make_policy` derives from the
    server's :class:`ServingConfig` — the exact legacy FIFO unless
    ``priority_classes``/``preemption`` say otherwise).

    With ``ServingConfig.paged_kv`` (the default for attention stacks) the
    pool's KV state is *paged*: a global pool of fixed-size blocks plus
    per-row block tables (:class:`repro.models.attention.PagedKVCache`).
    Admission allocates exactly the blocks a request will touch
    (``ceil((prompt + max_new) / block_size)``, capped at the row's logical
    table) from a refcounted :class:`~repro.serving.paged.BlockAllocator`;
    retirement returns them — blocks a registered prefix still wants park
    in the allocator's retired-block LRU, where a later hash-matched
    admission resurrects them and real pressure reclaims them. When the
    allocator cannot satisfy the head of the policy queue, admission simply
    stops for this wave — queue backpressure, never corruption of a live
    row — unless a preemptive policy elects victims instead (a request that
    could never fit the whole pool is rejected at :meth:`submit`). With
    ``prefix_cache``, prompts are block-hashed at enqueue and matched
    against a :class:`~repro.serving.paged.PrefixRegistry` at admission:
    hits skip the prefix prefill entirely and (at kv16) map the registered
    blocks copy-on-write instead of re-storing them.
    """

    def __init__(self, server: AdaptiveServer, quantum: int = 8,
                 prefill_bucket: int = 8, record_events: bool = True,
                 policy: Optional[SchedulingPolicy] = None,
                 shed: Optional[ShedPolicy] = None,
                 faults: Optional[FaultSchedule] = None,
                 retry_budget: int = 2,
                 watchdog_s: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None,
                 paranoid: bool = False):
        """Build a scheduler (pool state + host bookkeeping) on ``server``.

        The jitted executables live on the server and are shared; the
        donated device pool (tok/pos/caches) and all queue/allocator/
        registry state are per-scheduler, so schedulers can be torn down
        and rebuilt without recompiling anything.

        Robustness knobs: ``shed`` enables graceful overload degradation
        (:class:`~repro.serving.policy.ShedPolicy` thresholds checked at
        :meth:`submit`); ``faults`` arms deterministic chaos injection
        (:class:`~repro.serving.faults.FaultSchedule`); ``retry_budget``
        bounds quarantine retries before ``FAILED``; ``watchdog_s`` arms
        the no-progress :class:`~repro.serving.faults.Watchdog` with that
        per-step budget; ``clock`` substitutes ``time.monotonic`` (tests
        inject virtual time to exercise deadlines without sleeping);
        ``paranoid`` runs the full :meth:`check` audit after every step.
        """
        self.srv = server
        self.quantum = int(quantum)
        self.bucket_min = int(prefill_bucket)
        # events/admission_log power the ledger-oracle and FIFO tests; a
        # long-lived server should pass record_events=False (they grow with
        # every segment step). Per-request state (prompt, result) is evicted
        # by poll_completed(); run() keeps results for its return value.
        self.record_events = record_events
        cfg, scfg = server.cfg, server.scfg
        nslots = self.n_slots = scfg.max_batch
        self.paged = bool(scfg.paged_kv) and cfg.has_attn
        self.policy = policy if policy is not None else make_policy(scfg)
        if self.policy.preemptive and (not self.paged or not scfg.preemption
                                       or server._admit_restore is None):
            raise ValueError(
                "a preemptive policy needs the paged pool and a server "
                "built with ServingConfig.preemption=True (the restore "
                "executable) on a supports_prefix_sharing stack")
        # device-resident pool state (donated through every jit below)
        if self.paged:
            self.block_size = server.block_size
            self.n_lblk = server.n_lblk
            nb = (scfg.pool_blocks if scfg.pool_blocks is not None
                  else nslots * self.n_lblk)
            self._caches = T.init_paged_caches(
                cfg, nslots, scfg.slots, kv_bits=scfg.kv_bits,
                block_size=self.block_size, pool_blocks=nb)
            self.allocator = BlockAllocator(nb, self.block_size)
            self.registry = (
                PrefixRegistry(self.allocator,
                               capacity=scfg.prefix_capacity)
                if server.prefix_sharing else None)
            self._slot_blocks: list = [None] * nslots  # (private_ids, entry)
            self._prefix_keys: dict[int, list[bytes]] = {}
            self.peak_used_blocks = 0
            # chunked prefill: long cold prompts (and registry hits with a
            # long unique suffix) prefill in block-aligned chunks that
            # interleave with decode segments instead of one monolithic
            # admission wave. A mid-admission row occupies its slot +
            # blocks but is not yet live (remaining == 0); its state lives
            # here until the final chunk lands.
            self.chunk = server.chunk_tokens
            self._chunk_state: dict[int, dict] = {}    # slot -> progress
        else:
            self._caches = T.init_caches(cfg, nslots, scfg.slots,
                                         kv_bits=scfg.kv_bits)
            self.allocator = None
            self.registry = None
        self._tok = jnp.zeros((nslots,), jnp.int32)
        self._pos = jnp.zeros((nslots,), jnp.int32)
        # host bookkeeping
        self.remaining = np.zeros((nslots,), np.int64)   # tokens left to emit
        self.slot_req: list[Optional[int]] = [None] * nslots
        self._slot_crit = np.zeros((nslots,), bool)
        self._slot_level = np.zeros((nslots,), np.int32)
        # speculative decode state (ServingConfig.speculate): per-row token
        # history for the n-gram drafter (−1 pad, last entry = the row's
        # current token — updated at the flush boundary) and the per-class
        # opt-out mask (policy.bind_speculative, bound at admission)
        self.spec = bool(scfg.speculate)
        self.draft_w = int(scfg.draft_k) + 1 if self.spec else 1
        if self.spec:
            self._hist = np.full((nslots, int(scfg.draft_hist)), -1,
                                 np.int32)
            self._slot_spec = np.ones((nslots,), bool)
            # (pid, delivered) per verify window, in billing order — the
            # flush-side twin of `events` (which records the PLANNED
            # clamped bills the provisional plan fed select()); together
            # they replay the spec ledger exactly (invariant 11)
            self.spec_billed: list[tuple[int, int]] = []
        self._reqs: dict[int, Request] = {}
        self._suspended: dict[int, RowSnapshot] = {}     # rid -> snapshot
        self.results: dict[int, dict] = {}
        self._n = 0
        self.preemptions = 0
        self.resumes = 0
        self.admission_log: list[int] = []               # rids, admission order
        self.events: list[tuple[int, int, bool]] = []    # (pid, n_rows, crit)
        self._done: list[int] = []                       # completions, in order
        self._inflight: list[dict] = []                  # dispatched, unsynced
        # robustness state: deadlines / cancellation / quarantine / shedding
        self.clock = clock if clock is not None else time.monotonic
        self.shed = shed
        self.faults = faults
        self.retry_budget = int(retry_budget)
        self.watchdog = (Watchdog(float(watchdog_s))
                         if watchdog_s is not None else None)
        self.paranoid = bool(paranoid)
        self._deadline: dict[int, float] = {}     # rid -> absolute deadline
        self._to_reap: dict[int, RequestStatus] = {}     # slot -> status
        self._nf_rows: list[int] = []             # rids w/ non-finite logits
        self._quarantine_q: list[tuple[int, int]] = []   # (ready_round, rid)
        self._attempts: dict[int, int] = {}       # rid -> quarantine retries
        self._q_t0: dict[int, float] = {}         # rid -> first-fault time
        self._round = 0
        self._seg_dt: Optional[float] = None      # step wall-time EMA
        self._flush_idx = 0
        # durability layer (serving/durability.py): when attached, the
        # scheduler notifies it at every lifecycle edge (submit / cancel /
        # finalize / deliver, fsync'd write-ahead records) and flush
        # boundary (checkpoint cadence + crash-point markers). None = the
        # classic in-memory scheduler, zero overhead.
        self.durable = None
        self.draining = False     # graceful drain: stop admitting, finish
        self.cancelled = self.expired = self.shed_count = self.failed = 0
        self.recovered = self.faults_detected = 0
        self.alloc_injected_rounds = 0
        self.recovery_latency: list[float] = []   # seconds, fault -> done
        # the jitted segment/admit executables live on the server, so
        # schedulers can be torn down and rebuilt without recompiling
        self._segment = server._segment
        self._admit = server._admit
        self._admit_paged = server._admit_paged
        self._admit_shared = server._admit_shared
        self._admit_restore = server._admit_restore
        self._clear = server._clear_rows

    # ------------------------------------------------------------- paged util
    def _blocks_needed(self, prompt_len: int, max_new: int) -> int:
        """Physical blocks a request touches over its whole lifetime:
        prompt positions + every decode write, capped at the row's logical
        table (sliding-window rings reuse their blocks by design)."""
        return min(self.n_lblk,
                   -(-(prompt_len + max_new) // self.block_size))

    def _release_blocks(self, blocks) -> None:
        """Return a row's private blocks: ones a registered prefix still
        covers park in the allocator's retired-block LRU (resurrectable by
        a later hash-matched admission, reclaimable under real pressure);
        the rest go straight to the free list."""
        self.allocator.release(
            blocks, cache=(self.registry.covered(blocks)
                           if self.registry is not None else ()))

    def paged_stats(self) -> dict:
        """Block-pool occupancy + prefix-registry counters (bench JSON).

        Occupancy is **refcount-accurate** and three-way: ``live_blocks``
        (at least one live-row reference, derived from the allocator's
        refcounts — ``used_blocks`` is its alias), ``lru_cached_blocks``
        (retired blocks whose content a registered prefix still wants:
        allocatable capacity AND resurrectable cache, the retired-block
        LRU), and ``free_blocks`` (neither). The three always partition
        the pool — the bench asserts it as a cross-check between the
        refcount, LRU, and free-list bookkeeping.
        """
        if not self.paged:
            return {"paged": False,
                    "kv_bytes": T.cache_bytes(self._caches)}
        live = self.allocator.used_blocks
        out = {
            "paged": True,
            "block_size": self.block_size,
            "pool_blocks": self.allocator.n_blocks,
            "used_blocks": live,
            "live_blocks": live,
            "lru_cached_blocks": self.allocator.lru_blocks,
            "reclaimed_blocks": self.allocator.reclaimed_blocks,
            "peak_used_blocks": self.peak_used_blocks,
            "free_blocks": self.allocator.free_blocks,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "kv_bytes": T.cache_bytes(self._caches),
            "registry_bytes": 0,
        }
        if self.registry is not None:
            out.update(registry_entries=len(self.registry),
                       registry_hits=self.registry.hits,
                       registry_misses=self.registry.misses,
                       registry_invalidated=self.registry.invalidated,
                       registry_bytes=self.registry.nbytes())
        return out

    # ------------------------------------------------------------------ queue
    def submit(self, request: Request) -> int:
        """Enqueue a request with the scheduling policy. Returns its id.

        Paged pools validate the request up front: one that could never fit
        (more blocks than the whole pool provisions, or — when prefix
        sharing is active — ``prompt + max_new ≥`` the virtual row length,
        which would let its post-retirement ring position wrap onto a
        potentially shared block) raises ``ValueError`` here, cleanly,
        rather than corrupting live rows later. Transient fullness is *not*
        an error: the request queues and admission backpressure (or
        preemption, under a preemptive policy) holds it until blocks free
        up.
        """
        if self.paged and request.max_new > 0:
            plen = len(request.tokens)
            cfg = self.srv.cfg
            if not cfg.sliding_window and self.registry is not None \
                    and plen + request.max_new >= self.srv.slots_p:
                raise ValueError(
                    f"request needs {plen + request.max_new} KV slots but a "
                    f"prefix-sharing paged pool caps rows at "
                    f"{self.srv.slots_p - 1} (slots={self.srv.scfg.slots})")
            need = self._blocks_needed(plen, request.max_new)
            if need > self.allocator.n_blocks:
                raise ValueError(
                    f"request needs {need} KV blocks but the pool has only "
                    f"{self.allocator.n_blocks} "
                    f"(block_size={self.block_size})")
        rid = self._n
        self._n += 1
        self._reqs[rid] = request
        if self.durable is not None:
            # write-ahead: the submit record is durable BEFORE the request
            # can observably exist (invariant 12 — an accepted request is
            # never silently lost by a crash)
            self.durable.on_submit(rid, request)
        if request.deadline_ms is not None:
            self._deadline[rid] = self.clock() + request.deadline_ms / 1e3
        if request.max_new <= 0:        # nothing to generate: done on arrival
            self.results[rid] = {"tokens": [], "profile_trace": [],
                                 "status": RequestStatus.COMPLETED}
            self._done.append(rid)
            return rid
        if self.shed is not None and self.shed.triggered(
                len(self.policy) + 1, self._predicted_misses()):
            # graceful overload degradation: refuse ONE request with a
            # structured SHED status instead of admitting doomed work. The
            # victim is the least urgent party — the queue's class tail if
            # it is strictly less urgent than this arrival, else the
            # arrival itself (so a saver flood can never displace queued
            # critical work, and a critical arrival always lands).
            tail = self.policy.shed_tail()
            if tail is not None and tail[1] > self.policy.klass(
                    request).level:
                vrid = tail[0]
                self.policy.remove(vrid)
                self._suspended.pop(vrid, None)
                self._finalize(vrid, RequestStatus.SHED,
                               reason="overload: displaced by a more "
                                      "urgent arrival")
            else:
                self._finalize(rid, RequestStatus.SHED,
                               reason="overload: queue depth or deadline "
                                      "pressure over threshold")
                return rid
        if self.paged and self.registry is not None:
            # hash block-aligned prefixes once, at enqueue; admission just
            # dictionary-matches them against the registry
            self._prefix_keys[rid] = prefix_keys(
                np.asarray(request.tokens, np.int32), self.block_size)
        self.policy.enqueue(rid, request)
        return rid

    @property
    def live_rows(self) -> int:
        """Pool rows still generating (``remaining > 0``)."""
        return int((self.remaining > 0).sum())

    @property
    def pending(self) -> int:
        """Requests queued but not yet admitted (policy-queue depth;
        suspended rows waiting to resume count — they hold no slot)."""
        return len(self.policy)

    def poll_completed(self) -> list[tuple[int, dict]]:
        """``(rid, result)`` pairs finished since the last poll (completion
        order). Ownership of each result transfers to the caller: the
        scheduler evicts the request's retained state, so a long-lived
        polling server stays O(pool), not O(requests ever served)."""
        done, self._done = self._done, []
        if done and self.durable is not None:
            # deliver record BEFORE handing results out: after a crash,
            # recovery drops exactly the rids the caller already owns
            # (exactly-once delivery), and re-delivers the rest
            self.durable.on_deliver(done)
        out = []
        for rid in done:
            out.append((rid, self.results.pop(rid)))
            self._reqs.pop(rid, None)
            self._deadline.pop(rid, None)
            self._attempts.pop(rid, None)
            self._q_t0.pop(rid, None)
            if self.paged and self.registry is not None:
                self._prefix_keys.pop(rid, None)
        return out

    # ------------------------------------------- request lifecycle (terminal)
    def _finalize(self, rid: int, status: RequestStatus,
                  reason: Optional[str] = None) -> None:
        """Retire a request through its one terminal status: stamp the
        result dict, count it, and queue it for :meth:`poll_completed`.
        Tokens already materialized stay on the result — a cancelled or
        expired request keeps (and was billed for) exactly what it
        actually generated."""
        res = self.results.setdefault(rid,
                                      {"tokens": [], "profile_trace": []})
        res["status"] = status
        if reason is not None:
            res["reason"] = reason
        if rid in self._attempts:
            res["retries"] = self._attempts[rid]
        self._done.append(rid)
        if status is RequestStatus.CANCELLED:
            self.cancelled += 1
        elif status is RequestStatus.EXPIRED:
            self.expired += 1
        elif status is RequestStatus.SHED:
            self.shed_count += 1
        elif status is RequestStatus.FAILED:
            self.failed += 1
        if self.durable is not None:
            self.durable.on_final(rid)

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it currently sits; True if it took.

        Queued (including suspended and quarantine-backoff) requests drop
        immediately with ``CANCELLED``. A live pool row (or mid-admission
        chunked row) is *marked*: it is reaped at the next flush boundary
        — every dispatched token materializes first, so the energy ledger
        bills exactly the tokens the request actually generated, and its
        blocks release through the same machinery as :meth:`evict_row`
        (registry entries survive, refcounts stay exact). Returns False
        for unknown rids and for requests already terminal — a request
        whose last tokens are already in flight completes as
        ``COMPLETED``, never half-cancelled.
        """
        took = self._cancel(rid)
        if took and self.durable is not None:
            self.durable.on_cancel(rid)
        return took

    def _cancel(self, rid: int) -> bool:
        if rid not in self._reqs or "status" in self.results.get(rid, {}):
            return False
        if self.policy.remove(rid):
            self._suspended.pop(rid, None)
            self._finalize(rid, RequestStatus.CANCELLED)
            return True
        for i, (_rdy, qrid) in enumerate(self._quarantine_q):
            if qrid == rid:
                del self._quarantine_q[i]
                self._finalize(rid, RequestStatus.CANCELLED)
                return True
        for slot in range(self.n_slots):
            if self.slot_req[slot] == rid:
                if slot in self._to_reap:
                    return False             # already marked for the reaper
                self._to_reap[slot] = RequestStatus.CANCELLED
                return True
        if self.paged:
            for slot, st in self._chunk_state.items():
                if st["rid"] == rid:
                    if slot in self._to_reap:
                        return False
                    self._to_reap[slot] = RequestStatus.CANCELLED
                    return True
        if rid in self._nf_rows:
            # flagged non-finite and its slot already retired: quarantine
            # owns it — cancellation preempts the retry
            self._nf_rows.remove(rid)
            self._finalize(rid, RequestStatus.CANCELLED)
            return True
        return False

    def _eta_s(self, rid: int) -> float:
        """Predicted seconds to finish ``rid`` if admitted now: remaining
        tokens at the observed per-step wall-time EMA (0.0 until a first
        segment calibrates the EMA — admission never rejects blind)."""
        if self._seg_dt is None:
            return 0.0
        req = self._reqs[rid]
        left = req.max_new - len(self.results.get(rid, {}).get("tokens", ()))
        return -(-left // self.quantum) * self._seg_dt

    def _deadline_unreachable(self, rid: int) -> bool:
        dl = self._deadline.get(rid)
        return dl is not None and self.clock() + self._eta_s(rid) > dl

    def _predicted_misses(self) -> int:
        """Queued requests already predicted to miss their deadlines at
        current pool pressure (the ShedPolicy's second trigger)."""
        if self._seg_dt is None or not self._deadline:
            return 0
        return sum(1 for rid in self.policy.rids()
                   if self._deadline_unreachable(rid))

    def _expire(self) -> None:
        """Retire every request whose absolute deadline has passed:
        queued/suspended/backoff requests finalize ``EXPIRED`` now; live
        and chunked rows are marked for the flush-boundary reap (their
        generated-so-far tokens are delivered with the EXPIRED result)."""
        if not self._deadline:
            return
        now = self.clock()
        for rid in self.policy.rids():
            dl = self._deadline.get(rid)
            if dl is not None and now > dl:
                self.policy.remove(rid)
                self._suspended.pop(rid, None)
                self._finalize(rid, RequestStatus.EXPIRED)
        if self._quarantine_q:
            keep = []
            for rdy, rid in self._quarantine_q:
                dl = self._deadline.get(rid)
                if dl is not None and now > dl:
                    self._finalize(rid, RequestStatus.EXPIRED)
                else:
                    keep.append((rdy, rid))
            self._quarantine_q = keep
        for slot in range(self.n_slots):
            if slot in self._to_reap:
                continue
            rid = self.slot_req[slot]
            if rid is None and self.paged and slot in self._chunk_state:
                rid = self._chunk_state[slot]["rid"]
            if rid is None:
                continue
            dl = self._deadline.get(rid)
            if dl is not None and now > dl:
                self._to_reap[slot] = RequestStatus.EXPIRED

    def _reap_marked(self) -> None:
        """Flush-boundary reap of cancelled/expired rows: materialize every
        dispatched token first (billed == delivered, exactly), then release
        each marked row's blocks and unmap its table in one batched clear —
        the same release machinery as :meth:`evict_row`, minus the snapshot
        (nothing resumes)."""
        if not self._to_reap:
            return
        self._flush(0)
        marked, self._to_reap = self._to_reap, {}
        clear = []
        for slot, status in marked.items():
            if self.paged and slot in self._chunk_state:
                st = self._chunk_state.pop(slot)
                rid = st["rid"]
                self._release_blocks(st["blocks"])
                if st["entry"] is not None:
                    self.registry.release(st["entry"])
                clear.append(slot)
                if rid in self._nf_rows:
                    self._nf_rows.remove(rid)
                self._finalize(rid, status)
                continue
            rid = self.slot_req[slot]
            if rid is None:
                continue         # completed inside the in-flight segment
            if rid in self._nf_rows:
                self._nf_rows.remove(rid)    # cancel/expiry beats quarantine
            if self.paged:
                blocks, reg = self._slot_blocks[slot]
                self._release_blocks(blocks)
                if reg is not None:
                    self.registry.release(reg)
                self._slot_blocks[slot] = None
                clear.append(slot)
            self.slot_req[slot] = None
            self._slot_crit[slot] = False
            self._slot_level[slot] = 0
            self.remaining[slot] = 0
            self._finalize(rid, status)
        if self.paged and clear:
            self._caches = self._clear(self._pad_slot_idx(clear),
                                       self._caches)

    def _process_quarantine(self) -> None:
        """Quarantine + precision-fallback retry for rows the decode scan
        flagged non-finite.

        The poisoned row's blocks release through the same machinery as
        :meth:`evict_row`, but no snapshot is taken and the attempt's
        tokens are **discarded**: everything argmaxed after the bad logits
        is garbage, so a retry must restart from the prompt — that is what
        makes the recovered output token-identical to a clean run at the
        escalated profile. Escalation is one rung toward the accuracy
        target: the retry binds ``accuracy_critical=True``, pinning the
        ProfileManager to the highest-accuracy regime (the deterministic,
        ledger-independent selection the oracle tests rely on). The retry
        re-queues at its class front after an exponential backoff
        (1, 2, 4, ... rounds); past ``retry_budget`` attempts the request
        finalizes ``FAILED`` — never a hang, never a corrupted pool."""
        if not self._nf_rows:
            return
        self._flush(0)           # may flag more rows; drain what's known
        rows, self._nf_rows = self._nf_rows, []
        clear = []
        for rid in rows:
            slot = next((s for s in range(self.n_slots)
                         if self.slot_req[s] == rid), None)
            if slot is not None:
                if self.paged:
                    blocks, reg = self._slot_blocks[slot]
                    self._release_blocks(blocks)
                    if reg is not None:
                        self.registry.release(reg)
                    self._slot_blocks[slot] = None
                    clear.append(slot)
                self.slot_req[slot] = None
                self._slot_crit[slot] = False
                self._slot_level[slot] = 0
                self.remaining[slot] = 0
            self.faults_detected += 1
            attempt = self._attempts.get(rid, 0) + 1
            self._attempts[rid] = attempt
            self._q_t0.setdefault(rid, self.clock())
            self.results[rid] = {"tokens": [], "profile_trace": []}
            if attempt > self.retry_budget:
                self._q_t0.pop(rid, None)
                self._finalize(rid, RequestStatus.FAILED,
                               reason="retry budget exhausted")
                continue
            req = self._reqs[rid]
            if not req.accuracy_critical:
                self._reqs[rid] = dataclasses.replace(
                    req, accuracy_critical=True)
            self._quarantine_q.append(
                (self._round + (1 << (attempt - 1)), rid))
        if self.paged and clear:
            self._caches = self._clear(self._pad_slot_idx(clear),
                                       self._caches)

    def check(self) -> None:
        """Full paged-pool invariant audit (no-op on non-paged pools).

        Rebuilds the expected per-block refcounts from first principles —
        one reference per live row's private block, per mid-admission
        chunked row's private block, and per registry sharer of each
        entry block — and hands them to
        :meth:`~repro.serving.paged.BlockAllocator.check`, which also
        verifies the free/LRU/live partition. Raises ``RuntimeError`` on
        any divergence. Cheap (O(pool) host work): the ``paranoid``
        constructor flag runs it after every step.
        """
        if not self.paged:
            return
        exp = np.zeros((self.allocator.n_blocks,), np.int64)
        for slot in range(self.n_slots):
            sb = self._slot_blocks[slot]
            if sb is not None:
                for b in sb[0]:
                    exp[int(b)] += 1
        for st in self._chunk_state.values():
            for b in st["blocks"]:
                exp[int(b)] += 1
        if self.registry is not None:
            self.registry.add_expected_refs(exp)
        self.allocator.check(expected=exp)

    def robustness_stats(self) -> dict:
        """Fault-tolerance counters (bench JSON / ops surface)."""
        out = {"cancelled": self.cancelled, "expired": self.expired,
               "shed": self.shed_count, "failed": self.failed,
               "recovered": self.recovered,
               "faults_detected": self.faults_detected,
               "alloc_injected_rounds": self.alloc_injected_rounds,
               "recovery_latency_s": list(self.recovery_latency),
               "watchdog_stalls": (self.watchdog.stalls
                                   if self.watchdog is not None else 0)}
        if self.faults is not None:
            out.update(injected_nan=self.faults.injected_nan,
                       injected_alloc=self.faults.injected_alloc,
                       injected_stall=self.faults.injected_stall)
        return out

    # -------------------------------------------------------------- admission
    def admit(self) -> int:
        """Fill free slots from the policy queue; returns #requests admitted.

        One admission *wave* is ONE device dispatch: every admitted request
        rides in a single ragged prefill (left-padded to a shared pow2 prompt
        bucket, ``prompt_len`` as data — one executable per bucket), first
        tokens come from an on-device argmax, and each prefilled row is
        scattered into its free pool slot, all inside the server's donated
        admit jit. The wave's prefills are billed like the stepwise engine
        bills prefill: one inference per admitted request, under the
        policy-bound profile (an accuracy-critical class pins the wave).

        Paged pools add the wave taxonomy: admission is gated on *blocks*
        as well as slots, candidates are taken strictly in policy order,
        and each round dispatches at most two prefill waves — see
        :meth:`_admit_paged_waves`.

        Admission is deadline-aware: a candidate whose deadline the
        step-time EMA already rules unreachable is rejected here with a
        structured ``EXPIRED`` status instead of admitted as doomed work.
        A :class:`~repro.serving.faults.FaultSchedule` may also declare
        the allocator dry for this round — the round skips entirely, the
        same observable backpressure as a genuinely exhausted pool.
        """
        if self.draining:
            return 0                 # graceful drain: no new admissions
        if self.faults is not None and self.faults.alloc_dry(self._round):
            self.alloc_injected_rounds += 1
            return 0
        if self.paged:
            return self._admit_paged_waves()
        free = [s for s in range(self.n_slots) if self.slot_req[s] is None]
        if not free or not len(self.policy):
            return 0
        rids = []
        while len(rids) < len(free) and len(self.policy):
            rid = self.policy.head()
            if self._deadline_unreachable(rid):
                self.policy.pop_head()
                self._finalize(rid, RequestStatus.EXPIRED,
                               reason="deadline unreachable at admission")
                continue
            rids.append(self.policy.pop_head())
        take = len(rids)
        if not take:
            return 0
        slots = free[:take]
        reqs = [self._reqs[r] for r in rids]
        bucket = _next_pow2(max(self.bucket_min,
                                max(len(r.tokens) for r in reqs)))
        a = _next_pow2(take)               # pow2 wave shape (pad rows drop):
        # a 1–2 row refill costs a 2-row prefill, not a full-pool one, and
        # the executable count stays log² (row bucket × length bucket)
        prompts = np.zeros((a, bucket), np.int32)
        plen = np.zeros((a,), np.int32)    # pad rows: prompt_len 0 → masked
        sidx = np.full((a,), self.n_slots, np.int32)     # OOB → scatter-drop
        for j, r in enumerate(reqs):
            t = np.asarray(r.tokens, np.int32)
            prompts[j, bucket - len(t):] = t             # left-pad
            plen[j] = len(t)
            sidx[j] = slots[j]
        pid = self._bill(reqs)
        tok0, self._tok, self._pos, self._caches = self._admit(
            pid,
            {"tokens": jnp.asarray(prompts),
             "prompt_len": jnp.asarray(plen)},
            jnp.asarray(sidx), self._tok, self._pos, self._caches)
        entry = {"kind": "admit", "toks": tok0,
                 "name": self.srv.engine.profile_names[pid],
                 "rows": [], "completes": []}
        for j, (rid, slot) in enumerate(zip(rids, slots)):
            req = self._reqs[rid]
            self.results[rid] = {"tokens": [], "profile_trace": []}
            entry["rows"].append((j, rid))
            if self.record_events:
                self.admission_log.append(rid)
            if req.max_new == 1:                         # already complete
                entry["completes"].append(rid)
                continue
            self.slot_req[slot] = rid
            self._slot_crit[slot] = self.policy.bind_critical(req)
            self._slot_level[slot] = self.policy.klass(req).level
            self.remaining[slot] = req.max_new - 1
            self._seed_spec(slot, req)
        self._inflight.append(entry)
        return take

    def _admit_paged_waves(self) -> int:
        """Policy-ordered claim of slots *and* blocks, then ≤2 prefill
        dispatches per round.

        Candidates classify by the wave *kind* they need — **cold** (full
        ragged prefill; long prompts chunk their first chunk in), **shared**
        (registry hits and intra-wave-dedup deferrals: suffix-only
        continuation prefill; hits with a long unique suffix chunk too),
        or **resume** (suspended rows replaying their snapshot through the
        restore executable, grouped by their pinned profile). A round
        commits to at most TWO kinds: a head candidate needing a third
        waits for the next round — that cap, plus rolling
        deferred-registration failures back to the class head instead of
        dispatching a fallback wave, is the ≤2-dispatches-per-admission-
        round invariant the policy tests guard. Before any classification,
        a preemptive policy gets the chance to evict victims for an urgent
        head that would otherwise not fit (:meth:`_maybe_preempt`).

        Intra-wave prefix dedup survives the refactor: a cold candidate
        whose prefix will be registered by an earlier candidate of THIS
        round's cold wave is *deferred* — it resolves against the registry
        right after the cold wave dispatches (and registers), then rides
        the shared wave, so two identical prompts arriving in the same
        cold wave no longer both prefill the prefix. Rollbacks keep their
        relative order; the strict stop-at-first-failure contract
        otherwise holds within each class. Chunk *continuation* waves —
        :meth:`_advance_chunks`, one per in-flight pinned profile per
        round — count against the same two-dispatch budget: a round that
        will advance chunks admits at most ``2 - groups`` new kinds, so
        the audited ceiling holds even when restart recovery floods one
        round with resumable rows, queued candidates AND restored
        mid-prompt chunks at once.
        """
        self._maybe_preempt()
        free = [s for s in range(self.n_slots)
                if self.slot_req[s] is None and s not in self._chunk_state]
        cold, shared, deferred, chunked = [], [], [], []
        shared_chunked, resume = [], []
        resume_pid: Optional[int] = None
        kinds: set = set()
        # imminent chunk-continuation dispatches (one per pinned profile,
        # rows admitted THIS round are "fresh" and sit out) pre-commit part
        # of the round's two-dispatch budget
        kind_cap = 2 - len({st["pid"] for st in self._chunk_state.values()
                            if not st.get("fresh")})
        pending: dict[bytes, int] = {}   # key -> n_tokens this wave registers
        while free and len(self.policy):
            rid = self.policy.head()
            if self._deadline_unreachable(rid):
                self.policy.pop_head()
                self._suspended.pop(rid, None)
                self._finalize(rid, RequestStatus.EXPIRED,
                               reason="deadline unreachable at admission")
                continue
            req = self._reqs[rid]
            if rid in self._suspended:
                if "resume" not in kinds and len(kinds) >= kind_cap:
                    break                # over-budget wave kind waits a round
                snap = self._suspended[rid]
                if resume and snap.pid != resume_pid:
                    break                # one pinned-pid resume group/round
                blocks = self.allocator.alloc(
                    self._blocks_needed(len(req.tokens), req.max_new))
                if blocks is None:
                    break                # backpressure: head waits
                self.policy.pop_head()
                resume.append((rid, free.pop(0), blocks))
                resume_pid = snap.pid
                kinds.add("resume")
                continue
            plen = len(req.tokens)
            need = self._blocks_needed(plen, req.max_new)
            # a quarantine retry must NOT hit the prefix registry: a match
            # would map prompt blocks prefilled under the faulted attempt's
            # (or any other wave's) profile, and the recovered output would
            # no longer be token-identical to a clean run at the escalated
            # profile — the retry recomputes its whole prompt, cold
            keys = (self._prefix_keys.get(rid, [])
                    if self._attempts.get(rid, 0) == 0 else [])
            entry, wait, n_shared = None, False, 0
            if self.registry is not None:
                entry = self.registry.lookup(keys)
            if entry is not None:
                self.registry.acquire(entry)     # references (or resurrects
                if entry.block_ids is not None:  # from the LRU) its blocks
                    n_shared = entry.n_tokens // self.block_size
            elif pending:
                for k in keys:                   # longest-first, like lookup
                    if k in pending:
                        wait = True
                        if self.srv.scfg.kv_bits == 16:
                            n_shared = pending[k] // self.block_size
                        break
            kind = "shared" if (entry is not None or wait) else "cold"
            if kind not in kinds and len(kinds) >= kind_cap:
                if entry is not None:
                    self.registry.release(entry)
                break                            # over budget: next round
            blocks = self.allocator.alloc(need - n_shared)
            if blocks is None:                   # backpressure: head waits,
                if entry is not None:            # policy order preserved
                    self.registry.release(entry)
                break
            self.policy.pop_head()
            slot = free.pop(0)
            kinds.add(kind)
            if self.registry is not None and not wait:
                self.registry.record_admission(entry)
            if entry is not None:
                if self.chunk and plen - entry.n_tokens > self.chunk:
                    shared_chunked.append((rid, slot, entry, blocks))
                else:
                    shared.append((rid, slot, entry, blocks))
            elif wait:
                deferred.append((rid, slot, blocks, keys))
            elif self.chunk and plen > self.chunk:
                chunked.append((rid, slot, blocks))
            else:
                cold.append((rid, slot, blocks))
                if self.registry is not None:
                    j_max = (plen - 1) // self.block_size
                    for i, k in enumerate(keys):     # chain, longest first
                        pending.setdefault(
                            k, (j_max - i) * self.block_size)
        n = 0
        if cold or chunked:
            n += self._dispatch_cold(cold, chunked)
        rollback: list[int] = []
        for rid, slot, blocks, keys in deferred:
            # the cold wave above has dispatched and registered its chains;
            # a deferred candidate now hits the registry like any other.
            # The entry actually registered may cover a different prefix
            # length than the deferral assumed (LRU capacity), so square up
            # the private-block allocation before dispatching. If the
            # registration (or the top-up) failed, the candidate rolls back
            # to its class head for the next round — no fallback wave, the
            # ≤2-dispatch round contract holds.
            req = self._reqs[rid]
            need = self._blocks_needed(len(req.tokens), req.max_new)
            entry = self.registry.lookup(keys)
            if entry is None:
                self.allocator.release(blocks)
                rollback.append(rid)
                continue
            self.registry.acquire(entry)
            n_shared = (entry.n_tokens // self.block_size
                        if entry.block_ids is not None else 0)
            n_priv = need - n_shared
            if len(blocks) > n_priv:
                self.allocator.release(blocks[n_priv:])
                blocks = blocks[:n_priv]
            elif len(blocks) < n_priv:
                extra = self.allocator.alloc(n_priv - len(blocks))
                if extra is None:
                    self.registry.release(entry)
                    self.allocator.release(blocks)
                    rollback.append(rid)
                    continue
                blocks = blocks + extra
            self.registry.record_admission(entry)
            if self.chunk and len(req.tokens) - entry.n_tokens > self.chunk:
                shared_chunked.append((rid, slot, entry, blocks))
            else:
                shared.append((rid, slot, entry, blocks))
        if shared or shared_chunked:
            n += self._dispatch_shared(shared, shared_chunked)
        if resume:
            n += self._dispatch_resume(resume)
        for rid in reversed(rollback):      # preserve their relative order
            self.policy.push_front(rid, self._reqs[rid])
        if n:
            self.peak_used_blocks = max(self.peak_used_blocks,
                                        self.allocator.used_blocks)
        self._advance_chunks()
        return n

    def _bill(self, reqs) -> int:
        """Select/account the wave's profile (one inference per request).
        The policy resolves the wave's accuracy binding: any row of an
        accuracy-critical class (or with its own critical flag) pins the
        selection to the accuracy target."""
        mgr = self.srv.manager
        crit = self.policy.wave_critical(reqs)
        pid = 0 if mgr is None else mgr.select(crit)
        if mgr is not None:
            mgr.account(pid, len(reqs))
        if self.record_events:
            self.events.append((pid, len(reqs), crit))
        return pid

    def _pad_slot_idx(self, slots: list) -> jnp.ndarray:
        """Fixed-shape ``[n_slots]`` slot-index vector (OOB-padded) so row
        clearing reuses one executable regardless of how many rows retire."""
        out = np.full((self.n_slots,), self.n_slots, np.int32)
        out[:len(slots)] = slots
        return jnp.asarray(out)

    # ------------------------------------------------------------- preemption
    def _maybe_preempt(self) -> None:
        """Preemption trigger: the policy-queue head belongs to a class that
        may preempt, and the pool cannot take it — no free slot, or the
        allocator (free + reclaimable LRU) cannot cover its blocks. The
        policy picks victims (default: lowest class first, fewest generated
        tokens first, all-or-nothing); each is suspended via
        :meth:`evict_row` and all victim tables unmap in ONE fixed-shape
        clear dispatch. Victim private-block counts are what eviction
        actually frees (shared CoW blocks only drop references)."""
        if not self.policy.preemptive or self._admit_restore is None:
            return
        rid = self.policy.head()
        if rid is None:
            return
        req = self._reqs[rid]
        need = self._blocks_needed(len(req.tokens), req.max_new)
        if rid not in self._suspended and self.registry is not None:
            # a registry hit maps its prefix blocks instead of allocating
            # them — count only the private need, or a hit-holding critical
            # arrival would evict savers the classification loop was never
            # going to need evicted (lookup is a pure read: no LRU churn)
            entry = self.registry.lookup(self._prefix_keys.get(rid, []))
            if entry is not None and entry.block_ids is not None:
                need -= entry.n_tokens // self.block_size
        have_slot = any(self.slot_req[s] is None
                        and s not in self._chunk_state
                        for s in range(self.n_slots))
        need_slots = 0 if have_slot else 1
        need_blocks = max(0, need - self.allocator.available_blocks)
        if not need_slots and not need_blocks:
            return
        rows = []
        for slot in range(self.n_slots):
            vrid = self.slot_req[slot]
            if vrid is None or slot in self._chunk_state:
                continue
            vreq = self._reqs[vrid]
            blocks, _reg = self._slot_blocks[slot]
            rows.append(RowState(
                slot=slot, rid=vrid, level=int(self._slot_level[slot]),
                generated=len(self.results[vrid]["tokens"]),
                blocks=len(blocks),
                preemptible=self.policy.klass(vreq).preemptible))
        victims = self.policy.pick_victims(req, rows, need_slots,
                                           need_blocks)
        if not victims:
            return
        for v in victims:
            self.evict_row(v.slot)
        self._caches = self._clear(
            self._pad_slot_idx([v.slot for v in victims]), self._caches)

    def _snapshot_row(self, slot: int) -> RowSnapshot:
        """Materialize a live row's :class:`RowSnapshot` — the row's true
        progress as replayable data (f32 masters + exact int-KV scale
        preimages). Pure read; the caller must have flushed every
        in-flight token first (``_flush(0)``) so the snapshot reflects the
        row's real position. Shared by the preemption SUSPEND edge
        (:meth:`evict_row`) and the durability layer's live-state
        checkpoint — crash recovery replays the exact same bytes through
        the exact same restore executable."""
        rid = self.slot_req[slot]
        req = self._reqs[rid]
        res = self.results[rid]
        g = len(res["tokens"])              # ≥ 1: admission emitted one
        p_written = len(req.tokens) + g - 1  # KV positions 0..p_written-1
        pid = self.srv.engine.profile_names.index(res["profile_trace"][-1])
        blocks, reg = self._slot_blocks[slot]
        ns = (reg.n_tokens // self.block_size
              if reg is not None and reg.block_ids is not None else 0)
        row_map = ([int(b) for b in reg.block_ids[:ns]] if ns else []) \
            + list(blocks)
        mk, mv = T.paged_row_masters(self._caches["kv"], slot, row_map,
                                     p_written)
        ka = va = ksc = vsc = None
        kv_bits = self.srv.scfg.kv_bits
        if kv_bits in (4, 8):
            qmax = 127.0 if kv_bits == 8 else 7.0
            pool = self._caches["kv"]
            # repro: allow(host-sync) suspend edge materializes masters
            ksc = np.asarray(pool.k_scale[:, slot])
            # repro: allow(host-sync) suspend edge materializes masters
            vsc = np.asarray(pool.v_scale[:, slot])
            # best-effort preimages: XLA's reciprocal-multiply /qmax can
            # emit scales with no exact division preimage (seen at qmax=7);
            # the exact scales ride along and are forced post-restore.
            ka = jnp.asarray(T.amax_for_scale(ksc, qmax, strict=False))
            va = jnp.asarray(T.amax_for_scale(vsc, qmax, strict=False))
            ksc, vsc = jnp.asarray(ksc), jnp.asarray(vsc)
        return RowSnapshot(
            rid=rid, n_done=p_written,
            last_tok=int(res["tokens"][-1]), pid=pid,
            master_k=mk, master_v=mv, k_amax=ka, v_amax=va,
            k_scale=ksc, v_scale=vsc)

    def evict_row(self, slot: int) -> int:
        """Suspend one live pool row; returns its rid.

        The preemption state machine's SUSPEND edge: flush every in-flight
        token (the snapshot needs the row's true progress), snapshot the
        row's block table + host-side KV masters
        (:class:`~repro.serving.paged.RowSnapshot` — masters via
        :func:`repro.models.transformer.paged_row_masters`, exact int-KV
        scale preimages via :func:`~repro.models.transformer.
        amax_for_scale`), release its blocks (registered prefixes park in
        the retired-block LRU; a mapped CoW entry just drops this sharer's
        references), and requeue the request at the front of its class.
        The caller unmaps the slot's block table (``_clear_rows``) — the
        host-side twin of in-graph retirement, so the row's residual
        frozen-position writes can never follow the freed blocks to their
        next owner. The row later resumes through
        :meth:`_dispatch_resume`, token-identically.
        """
        rid = self.slot_req[slot]
        assert rid is not None and slot not in self._chunk_state
        self._flush(0)
        self._suspended[rid] = self._snapshot_row(slot)
        req = self._reqs[rid]
        blocks, reg = self._slot_blocks[slot]
        self._release_blocks(blocks)
        if reg is not None:
            self.registry.release(reg)
        self._slot_blocks[slot] = None
        self.slot_req[slot] = None
        self._slot_crit[slot] = False
        self._slot_level[slot] = 0
        self.remaining[slot] = 0
        self.policy.push_front(rid, req)
        self.preemptions += 1
        return rid

    def _dispatch_resume(self, rows) -> int:
        """One continuation wave re-admitting suspended rows — the RESUME
        edge of the preemption state machine, riding the restore
        executable (the master-replay continuation body; at int KV it IS
        the shared-admission executable).

        The "prefix" is EVERYTHING the row had written when evicted
        (positions ``0..P−1``, replayed from the snapshot masters) and the
        "suffix" is **empty** (``prompt_len = 0`` — every suffix write is
        masked out of the scatter): the wave is pure data movement, so the
        restored cache bytes, scales and ``token_idx`` are identical to
        the suspended row's by construction — at kv16 the masters
        round-trip through bf16, at int KV re-quantization under the
        snapshot's exact scale preimage reproduces every int — never by
        floating-point luck. It recomputes no token and **bills nothing**:
        a request's total billed inferences are invariant under
        preemption. All rows of the wave share the snapshot-pinned
        profile (their last pre-eviction step's — bookkeeping only; no
        profile-dependent compute lands in the cache). After the dispatch
        the decode carry is re-pointed at the recorded last emitted token
        (the empty-suffix wave's argmax is meaningless); with
        ``pos = P`` set by the wave, the carry equals the uninterrupted
        row's exactly, and the next segment continues it bit-for-bit.
        """
        bs = self.block_size
        snaps = [self._suspended.pop(rid) for rid, _, _ in rows]
        pid = snaps[0].pid
        sb = _next_pow2(self.bucket_min)            # empty suffixes
        pp = bs * _next_pow2(max(-(-s.n_done // bs) for s in snaps))
        a = _next_pow2(len(rows))
        nb_oob = self.allocator.n_blocks
        prompts = np.zeros((a, sb), np.int32)
        slen = np.zeros((a,), np.int32)             # 0: nothing prefills
        plen_pre = np.zeros((a,), np.int32)
        sidx = np.full((a,), self.n_slots, np.int32)
        dest = np.full((a, self.n_lblk), nb_oob, np.int32)
        bt_rows = np.full((a, self.n_lblk), nb_oob, np.int32)
        for j, ((rid, slot, blocks), s) in enumerate(zip(rows, snaps)):
            plen_pre[j] = s.n_done
            sidx[j] = slot
            dest[j, :len(blocks)] = blocks          # fully private rebuild
            bt_rows[j, :len(blocks)] = blocks
        batch = {"tokens": jnp.asarray(prompts),
                 "prompt_len": jnp.asarray(slen)}
        self._call_continuation(
            self._admit_restore, pid, batch, sidx, dest, bt_rows, plen_pre,
            pp, [(s.n_done, None, s.master_k, s.master_v, s.k_amax, s.v_amax)
                 for s in snaps], masters=True)
        sl = jnp.asarray(np.asarray([slot for _, slot, _ in rows], np.int32))
        if snaps[0].k_scale is not None:
            # force the suspended rows' exact scales over the wave's
            # recalibration: the amax preimages are best-effort (XLA's
            # /qmax lowering can produce scales with no exact preimage),
            # and while the re-quantized ints are identical either way,
            # the scale bytes themselves must match the uninterrupted
            # row's for the next segment to be bit-exact.
            kv = self._caches["kv"]
            self._caches["kv"] = kv._replace(
                k_scale=kv.k_scale.at[:, sl].set(
                    jnp.stack([s.k_scale for s in snaps], axis=1)),
                v_scale=kv.v_scale.at[:, sl].set(
                    jnp.stack([s.v_scale for s in snaps], axis=1)))
        self._tok = self._tok.at[sl].set(
            jnp.asarray(np.asarray([s.last_tok for s in snaps], np.int32)))
        for (rid, slot, blocks), s in zip(rows, snaps):
            req = self._reqs[rid]
            self.slot_req[slot] = rid
            self._slot_crit[slot] = self.policy.bind_critical(req)
            self._slot_level[slot] = self.policy.klass(req).level
            self.remaining[slot] = \
                req.max_new - len(self.results[rid]["tokens"])
            self._slot_blocks[slot] = (blocks, None)
            self._seed_spec(slot, req,
                            history=self.results[rid]["tokens"])
            self.resumes += 1
        return len(rows)

    # ------------------------------------------------------------------ waves
    def _dispatch_cold(self, rows, chunked=()) -> int:
        """One ``_admit_paged`` wave: full ragged prefill + block scatter.

        ``chunked`` rows ride the same wave but prefill only their FIRST
        ``chunk`` tokens; the rest of the prompt follows one chunk per
        admission round through :meth:`_advance_chunks` continuation waves.
        A chunked row holds its slot and blocks from here on but is not yet
        live (``remaining`` stays 0 — the done-mask keeps it frozen through
        the decode segments that run between its chunks).
        """
        allrows = list(rows) + list(chunked)
        n_cold = len(rows)
        reqs = [self._reqs[rid] for rid, _, _ in allrows]
        lens = [len(r.tokens) if j < n_cold else min(len(r.tokens), self.chunk)
                for j, r in enumerate(reqs)]
        bucket = _next_pow2(max(self.bucket_min, max(lens)))
        a = _next_pow2(len(allrows))
        nb_oob = self.allocator.n_blocks
        prompts = np.zeros((a, bucket), np.int32)
        plen = np.zeros((a,), np.int32)
        sidx = np.full((a,), self.n_slots, np.int32)
        dest = np.full((a, self.n_lblk), nb_oob, np.int32)
        for j, (rid, slot, blocks) in enumerate(allrows):
            t = np.asarray(reqs[j].tokens, np.int32)[:lens[j]]
            prompts[j, bucket - lens[j]:] = t                # left-pad
            plen[j] = lens[j]
            sidx[j] = slot
            dest[j, :len(blocks)] = blocks
        pid = self._bill(reqs)
        tok0, raw, self._tok, self._pos, self._caches = self._admit_paged(
            pid,
            {"tokens": jnp.asarray(prompts),
             "prompt_len": jnp.asarray(plen)},
            jnp.asarray(sidx), jnp.asarray(dest),
            self._tok, self._pos, self._caches)
        if self.registry is not None and rows:
            self._register_prefixes(rows, reqs[:n_cold], raw, bucket)
        for off, (rid, slot, blocks) in enumerate(chunked):
            j = n_cold + off
            st = {"rid": rid, "blocks": blocks, "done": lens[j],
                  "map": list(blocks),  # logical→physical incl. shared span
                  "entry": None, "n_shared": 0,
                  "fresh": True,   # chunk 2 waits for the next round — one
                                   # chunk wave per row per admission round
                  "pid": pid,      # profile pinned for the WHOLE prompt:
                                   # a monolithic admission prefills under
                                   # one profile, so chunks must too or the
                                   # row's KV would mix precisions no cold
                                   # path can produce (token identity)
                  "mk": None, "mv": None, "ka": None, "va": None}
            if raw is not None:
                # int KV: keep the chunk's pre-quantization K/V + running
                # amax so the next chunk can replay it as its prefix
                # masters (the exact-scale recalibration path)
                k_all, v_all = raw
                c0 = bucket - lens[j]
                st["mk"] = k_all[:, j, c0:].astype(jnp.float32)
                st["mv"] = v_all[:, j, c0:].astype(jnp.float32)
                st["ka"] = jnp.max(jnp.abs(st["mk"]), axis=(1, 3))
                st["va"] = jnp.max(jnp.abs(st["mv"]), axis=(1, 3))
            self._chunk_state[slot] = st
            self.results[rid] = {"tokens": [], "profile_trace": []}
            if self.record_events:
                self.admission_log.append(rid)
        self._post_admission(tok0, self.srv.engine.profile_names[pid],
                             [(j, rid, slot, blocks, None)
                              for j, (rid, slot, blocks) in enumerate(rows)])
        return len(allrows)

    def _register_prefixes(self, rows, reqs, raw, bucket: int) -> None:
        """Offer each new prompt's block-aligned prefix chain for reuse.

        The chain discipline lives in :meth:`~repro.serving.paged.
        PrefixRegistry.register_chain`; this method only slices each row's
        pre-quantization masters out of the wave (int KV — one lazily
        sliced device array shared by the whole chain; at kv16 the pool's
        bf16 blocks double as the masters and nothing is stored).
        """
        kv16 = self.srv.scfg.kv_bits == 16
        bs = self.block_size
        for j, (rid, slot, blocks) in enumerate(rows):
            t = np.asarray(reqs[j].tokens, np.int32)
            j_max = (len(t) - 1) // bs
            mk = mv = None
            if raw is not None and j_max >= 1:
                k_all, v_all = raw
                c0 = bucket - len(t)
                mk = k_all[:, j, c0:c0 + j_max * bs].astype(jnp.float32)
                mv = v_all[:, j, c0:c0 + j_max * bs].astype(jnp.float32)
            # kv16_masters: blocks stay shareable (the bf16 pool is still
            # exact) AND the f32 masters ride along for durable snapshots
            self.registry.register_chain(self._prefix_keys.get(rid, []),
                                         j_max, blocks, mk, mv,
                                         share_blocks=kv16)

    def _call_continuation(self, fn, pid, batch, sidx, dest, bt_rows,
                           plen_pre, pp: int, pre: list,
                           masters: bool = False):
        """Assemble the prefix operands and dispatch one continuation-
        prefill wave — the single place that knows the executable's calling
        convention, shared by registry-hit admissions
        (:meth:`_dispatch_shared`), chunk continuations
        (:meth:`_dispatch_chunks`) and preemption resumes
        (:meth:`_dispatch_resume`).

        ``pre``: one ``(n_tok, block_ids, mk, mv, ka, va)`` tuple per wave
        row. At kv16 the prefix is normally gathered in-jit from
        ``block_ids`` (the bf16 pool is its own master); ``masters=True``
        forces the master-replay convention regardless of precision — the
        resume path, where the evicted row's blocks are gone and its
        snapshot is the only source. At int KV the full-precision masters
        ``mk``/``mv`` (sliced to ``n_tok`` — chain entries share one
        buffer — and padded to the ``pp`` bucket) are replayed with their
        raw amax. Returns ``(tok0, raw)``.
        """
        cfg = self.srv.cfg
        a = dest.shape[0]
        nb_oob = self.allocator.n_blocks
        if not self.srv.masters_mode and not masters:
            pb = pp // self.block_size
            pre_bids = np.full((a, pb), nb_oob, np.int32)
            for j, (n_tok, bids, *_rest) in enumerate(pre):
                nbl = n_tok // self.block_size
                pre_bids[j, :nbl] = bids[:nbl]
            tok0, raw, self._tok, self._pos, self._caches = fn(
                pid, batch, jnp.asarray(sidx), jnp.asarray(dest),
                jnp.asarray(bt_rows), jnp.asarray(pre_bids),
                jnp.asarray(plen_pre), self._tok, self._pos,
                self._caches)
            return tok0, raw

        def padm(m, n_tok):
            m = m[:, :n_tok].astype(jnp.float32)
            return (m if n_tok == pp else
                    jnp.pad(m, ((0, 0), (0, pp - n_tok), (0, 0), (0, 0))))

        zk = jnp.zeros((cfg.n_layers, pp, cfg.n_kv, cfg.hd), jnp.float32)
        za = jnp.zeros((cfg.n_layers, cfg.n_kv), jnp.float32)
        npad = a - len(pre)
        kpre = jnp.stack([padm(mk, n) for n, _, mk, _, _, _ in pre]
                         + [zk] * npad, axis=1)
        vpre = jnp.stack([padm(mv, n) for n, _, _, mv, _, _ in pre]
                         + [zk] * npad, axis=1)
        ka = jnp.stack([za if ka_ is None else ka_
                        for *_x, ka_, _va in pre] + [za] * npad, axis=1)
        va = jnp.stack([za if va_ is None else va_
                        for *_x, va_ in pre] + [za] * npad, axis=1)
        tok0, raw, self._tok, self._pos, self._caches = fn(
            pid, batch, jnp.asarray(sidx), jnp.asarray(dest),
            jnp.asarray(bt_rows), kpre, vpre, ka, va,
            jnp.asarray(plen_pre), self._tok, self._pos, self._caches)
        return tok0, raw

    def _dispatch_shared(self, rows, chunked=()) -> int:
        """One ``_admit_shared`` wave: suffix-only continuation prefill.

        ``chunked`` rows are registry hits whose unique suffix exceeds the
        prefill chunk: they ride the same wave but prefill only the FIRST
        ``chunk`` suffix tokens, then advance one chunk per admission round
        through :meth:`_advance_chunks` exactly like a long cold prompt —
        the prefix-chain hit just moved their starting line (closes the
        chunk-from-hit gap: before this, a hit with a long unique suffix
        prefilled that suffix monolithically, stalling every live row).
        """
        bs = self.block_size
        allrows = list(rows) + list(chunked)
        n_full = len(rows)
        reqs = [self._reqs[rid] for rid, _, _, _ in allrows]
        sufs = []
        for j, (r, (_, _, e, _)) in enumerate(zip(reqs, allrows)):
            s = np.asarray(r.tokens, np.int32)[e.n_tokens:]
            sufs.append(s if j < n_full else s[:self.chunk])
        sb = _next_pow2(max(self.bucket_min, max(len(s) for s in sufs)))
        pp = bs * _next_pow2(max(-(-e.n_tokens // bs)
                                 for _, _, e, _ in allrows))
        a = _next_pow2(len(allrows))
        nb_oob = self.allocator.n_blocks
        prompts = np.zeros((a, sb), np.int32)
        slen = np.zeros((a,), np.int32)
        plen_pre = np.zeros((a,), np.int32)
        sidx = np.full((a,), self.n_slots, np.int32)
        dest = np.full((a, self.n_lblk), nb_oob, np.int32)
        bt_rows = np.full((a, self.n_lblk), nb_oob, np.int32)
        for j, ((rid, slot, e, blocks), suf) in enumerate(zip(allrows, sufs)):
            prompts[j, sb - len(suf):] = suf                 # left-pad
            slen[j] = len(suf)
            plen_pre[j] = e.n_tokens
            sidx[j] = slot
            ns = e.n_tokens // bs if e.block_ids is not None else 0
            if ns:
                bt_rows[j, :ns] = e.block_ids[:ns]           # mapped, shared
            bt_rows[j, ns:ns + len(blocks)] = blocks         # private tail
            dest[j, ns:ns + len(blocks)] = blocks            # only these get
        ents = [e for _, _, e, _ in allrows]                 # written (CoW)
        pid = self._bill(reqs)
        batch = {"tokens": jnp.asarray(prompts),
                 "prompt_len": jnp.asarray(slen)}
        tok0, raw = self._call_continuation(
            self._admit_shared, pid, batch, sidx, dest, bt_rows, plen_pre,
            pp, [(e.n_tokens, e.block_ids, e.master_k, e.master_v,
                  e.k_amax, e.v_amax) for e in ents])
        for off, (rid, slot, e, blocks) in enumerate(chunked):
            j = n_full + off
            ns = e.n_tokens // bs if e.block_ids is not None else 0
            st = {"rid": rid, "blocks": blocks,
                  "map": ([int(b) for b in e.block_ids[:ns]] if ns else [])
                         + list(blocks),
                  "entry": e, "n_shared": ns,
                  "done": e.n_tokens + len(sufs[j]),
                  "fresh": True, "pid": pid,
                  "mk": None, "mv": None, "ka": None, "va": None}
            if raw is not None:
                # int KV: seed the accumulated masters with the ENTRY's
                # prefix masters + this wave's raw suffix, so later chunks
                # replay the full processed span with running-amax scales
                k_all, v_all = raw
                c0 = sb - len(sufs[j])
                new_k = k_all[:, j, c0:].astype(jnp.float32)
                new_v = v_all[:, j, c0:].astype(jnp.float32)
                st["mk"] = jnp.concatenate(
                    [e.master_k[:, :e.n_tokens].astype(jnp.float32), new_k],
                    axis=1)
                st["mv"] = jnp.concatenate(
                    [e.master_v[:, :e.n_tokens].astype(jnp.float32), new_v],
                    axis=1)
                st["ka"] = jnp.maximum(
                    e.k_amax, jnp.max(jnp.abs(new_k), axis=(1, 3)))
                st["va"] = jnp.maximum(
                    e.v_amax, jnp.max(jnp.abs(new_v), axis=(1, 3)))
            self._chunk_state[slot] = st
            self.results[rid] = {"tokens": [], "profile_trace": []}
            if self.record_events:
                self.admission_log.append(rid)
        self._post_admission(tok0, self.srv.engine.profile_names[pid],
                             [(j, rid, slot, blocks, e)
                              for j, (rid, slot, e, blocks)
                              in enumerate(rows)])
        return len(allrows)

    def _advance_chunks(self) -> None:
        """Advance every mid-admission chunked row by one prompt chunk.

        Called once per admission round, BETWEEN decode segments — that
        interleaving is the whole point: a 4-chunk prompt costs four small
        continuation dispatches with decode quanta in between instead of
        one monolithic wave that stalls every live row for the full
        prompt's prefill.
        """
        if not self._chunk_state:
            return
        waves: dict[int, list] = {}          # rows grouped by pinned profile
        for slot in sorted(self._chunk_state):
            st = self._chunk_state[slot]
            if st.pop("fresh", False):       # admitted this round: a decode
                continue                     # segment runs before chunk 2
            t = np.asarray(self._reqs[st["rid"]].tokens, np.int32)
            clen = min(self.chunk, len(t) - st["done"])
            waves.setdefault(st["pid"], []).append(
                (slot, st, t[st["done"]:st["done"] + clen]))
        for pid, rows in waves.items():
            self._dispatch_chunks(pid, rows)

    def _dispatch_chunks(self, pid: int, rows) -> None:
        """One continuation wave over ``(slot, state, chunk_tokens)`` rows,
        all pinned to profile ``pid`` (the one their first chunk billed).

        Reuses the shared-prefix executable verbatim: the "prefix" is the
        row's own previously processed span — gathered from its mapped
        blocks at kv16 (for a chunk-from-hit row that includes the shared
        CoW prefix blocks, read-only; chunk boundaries are block-aligned
        by construction), replayed from the accumulated full-precision
        masters at int KV. ``dest`` rewrites the row's PRIVATE blocks each
        chunk, which both lands the new chunk and scrubs any junk a frozen
        row's residual decode writes parked there between chunks (frozen
        positions are always past the shared span, so the shared blocks
        never need — or get — a write). Rows whose final chunk lands go
        live (``remaining = max_new − 1``) with their first generated
        token coming from this wave's argmax — exactly the cold admission
        contract.
        """
        bs = self.block_size
        sb = _next_pow2(max(self.bucket_min,
                            max(len(c) for _, _, c in rows)))
        pp = bs * _next_pow2(max(st["done"] // bs for _, st, _ in rows))
        a = _next_pow2(len(rows))
        nb_oob = self.allocator.n_blocks
        prompts = np.zeros((a, sb), np.int32)
        slen = np.zeros((a,), np.int32)
        plen_pre = np.zeros((a,), np.int32)
        sidx = np.full((a,), self.n_slots, np.int32)
        dest = np.full((a, self.n_lblk), nb_oob, np.int32)
        bt_rows = np.full((a, self.n_lblk), nb_oob, np.int32)
        for j, (slot, st, chunk) in enumerate(rows):
            prompts[j, sb - len(chunk):] = chunk             # left-pad
            slen[j] = len(chunk)
            plen_pre[j] = st["done"]
            sidx[j] = slot
            ns = st["n_shared"]
            bt_rows[j, :len(st["map"])] = st["map"]
            dest[j, ns:ns + len(st["blocks"])] = st["blocks"]
        # continuation waves reuse the pinned profile and bill nothing new —
        # the request was billed its one prefill inference at the first
        # chunk, and re-selecting here could mix precisions within one
        # prompt's KV (no monolithic admission can produce that state)
        batch = {"tokens": jnp.asarray(prompts),
                 "prompt_len": jnp.asarray(slen)}
        tok0, raw = self._call_continuation(
            self._admit_shared, pid, batch, sidx, dest, bt_rows, plen_pre,
            pp, [(st["done"], st["map"], st["mk"], st["mv"],
                  st["ka"], st["va"]) for _, st, _ in rows])
        entry = {"kind": "admit", "toks": tok0,
                 "name": self.srv.engine.profile_names[pid],
                 "rows": [], "completes": []}
        clear = []
        for j, (slot, st, chunk) in enumerate(rows):
            st["done"] += len(chunk)
            if raw is not None:
                k_all, v_all = raw
                c0 = sb - len(chunk)
                new_k = k_all[:, j, c0:].astype(jnp.float32)
                new_v = v_all[:, j, c0:].astype(jnp.float32)
                st["mk"] = jnp.concatenate([st["mk"], new_k], axis=1)
                st["mv"] = jnp.concatenate([st["mv"], new_v], axis=1)
                st["ka"] = jnp.maximum(
                    st["ka"], jnp.max(jnp.abs(new_k), axis=(1, 3)))
                st["va"] = jnp.maximum(
                    st["va"], jnp.max(jnp.abs(new_v), axis=(1, 3)))
            rid = st["rid"]
            req = self._reqs[rid]
            if st["done"] < len(req.tokens):
                continue                       # more chunks to go
            # final chunk: the row goes live exactly like a cold admission
            del self._chunk_state[slot]
            entry["rows"].append((j, rid))
            self._register_chunked(rid, st)
            if req.max_new == 1:               # done on arrival
                entry["completes"].append(rid)
                self._release_blocks(st["blocks"])
                if st["entry"] is not None:
                    self.registry.release(st["entry"])
                clear.append(slot)
                continue
            self.slot_req[slot] = rid
            self._slot_crit[slot] = self.policy.bind_critical(req)
            self._slot_level[slot] = self.policy.klass(req).level
            self.remaining[slot] = req.max_new - 1
            self._slot_blocks[slot] = (st["blocks"], st["entry"])
            self._seed_spec(slot, req)
        if clear:
            self._caches = self._clear(self._pad_slot_idx(clear),
                                       self._caches)
        if entry["rows"]:
            self._inflight.append(entry)

    def _register_chunked(self, rid: int, st: dict) -> None:
        """Offer a finished chunked prompt's prefix chain for reuse —
        same chain discipline as :meth:`_register_prefixes`, sourced from
        the row's mapped blocks (kv16; a chunk-from-hit chain includes the
        shared span it mapped) / accumulated masters (int KV)."""
        if self.registry is None:
            return
        t = np.asarray(self._reqs[rid].tokens, np.int32)
        j_max = (len(t) - 1) // self.block_size
        mk = mv = None
        if st["mk"] is not None and j_max >= 1:
            # one master buffer for the whole chain, truncated to the
            # registrable span (entries slice by their own n_tokens)
            mk = st["mk"][:, :j_max * self.block_size]
            mv = st["mv"][:, :j_max * self.block_size]
        self.registry.register_chain(self._prefix_keys.get(rid, []),
                                     j_max, st["map"], mk, mv,
                                     share_blocks=self.srv.scfg.kv_bits
                                     == 16)

    def _post_admission(self, tok0, pname: str, rows) -> None:
        """Common post-dispatch bookkeeping for paged admission waves.

        ``rows``: ``(wave_row, rid, slot, private_blocks, registry_entry)``.
        ``max_new == 1`` rows complete at admission: their blocks go straight
        back to the allocator and their (never-live) slot's block table is
        cleared so residual dead-row writes can't follow the blocks to their
        next owner.
        """
        entry = {"kind": "admit", "toks": tok0, "name": pname,
                 "rows": [], "completes": []}
        clear = []
        for j, rid, slot, blocks, reg in rows:
            req = self._reqs[rid]
            self.results[rid] = {"tokens": [], "profile_trace": []}
            entry["rows"].append((j, rid))
            if self.record_events:
                self.admission_log.append(rid)
            if req.max_new == 1:                             # done on arrival
                entry["completes"].append(rid)
                self._release_blocks(blocks)
                if reg is not None:
                    self.registry.release(reg)
                clear.append(slot)
                continue
            self.slot_req[slot] = rid
            self._slot_crit[slot] = self.policy.bind_critical(req)
            self._slot_level[slot] = self.policy.klass(req).level
            self.remaining[slot] = req.max_new - 1
            self._slot_blocks[slot] = (blocks, reg)
            self._seed_spec(slot, req)
        if clear:
            self._caches = self._clear(self._pad_slot_idx(clear),
                                       self._caches)
        self._inflight.append(entry)

    def _seed_spec(self, slot: int, req, history=None) -> None:
        """Reset slot ``slot``'s speculation state for its new occupant:
        fresh −1 history (the admission flush lands the first token — a
        stale previous occupant's n-grams must never draft for this row)
        and the request's class speculation binding. ``history`` replays a
        resumed row's already-delivered tokens so the drafter warm-starts
        (drafter *quality* only — acceptance verification never depends on
        what was proposed)."""
        if not self.spec:
            return
        self._hist[slot] = -1
        if history:
            h = np.asarray(history[-self._hist.shape[1]:], np.int32)
            self._hist[slot, -len(h):] = h
        self._slot_spec[slot] = self.policy.bind_speculative(req)

    # --------------------------------------------------------------- decoding
    def run_segment(self) -> None:
        """One decode segment over the pool: plan ``quantum`` steps against
        the live rows, dispatch the fused scan, distribute tokens, retire.
        A speculative server's segments route to :meth:`_run_segment_spec`
        (same pool, same executable slot, multi-token windows)."""
        if self.spec:
            return self._run_segment_spec()
        q = self.quantum
        mgr = self.srv.manager
        rem = self.remaining
        if mgr is None:
            sched = np.zeros((q,), np.int32)
        elif len(self.policy.classes) > 1:
            # per-class planning: class profile bindings pin the steps a
            # bound row is live for (plus per-request critical flags, which
            # _slot_crit already folds in)
            sched = mgr.plan_schedule_classes(
                q, rem, self._slot_level,
                tuple(c.level for c in self.policy.classes
                      if c.accuracy_critical),
                row_critical=self._slot_crit)
        else:
            sched = mgr.plan_schedule_ragged(q, rem, self._slot_crit)
        if self.record_events:
            for i in range(q):
                live_i = rem > i
                self.events.append((int(sched[i]), int(live_i.sum()),
                                    bool((self._slot_crit & live_i).any())))
        # chaos operand: normally all −1 (never fires, dead data through
        # the one pool-lifetime executable); an armed FaultSchedule poisons
        # a targeted row's logits at the segment's first step
        fault = np.full((self.n_slots,), -1, np.int32)
        if self.faults is not None:
            for slot in range(self.n_slots):
                rid = self.slot_req[slot]
                if rid is None or self.remaining[slot] <= 0:
                    continue
                if self.faults.want_nan(rid, self._attempts.get(rid, 0)):
                    fault[slot] = 0
        toks, ok, self._tok, self._pos, self._caches = self._segment(
            jnp.asarray(sched), self._tok, self._pos, self._caches,
            jnp.asarray(self.remaining, jnp.int32), jnp.asarray(fault))
        # retirement depends only on host-side remaining counts, never on
        # token *values* — so bookkeeping (and the next admission/segment
        # dispatch) proceeds without materializing ``toks``
        entry = {"kind": "seg", "toks": toks, "ok": ok, "sched": sched,
                 "rows": [], "completes": []}
        retired: list[int] = []
        for slot in range(self.n_slots):
            rid = self.slot_req[slot]
            if rid is None:
                continue
            n = int(min(self.remaining[slot], q))
            entry["rows"].append((slot, rid, n))
            self.remaining[slot] -= n
            if self.remaining[slot] == 0:                # retire → refillable
                self.slot_req[slot] = None
                self._slot_crit[slot] = False
                self._slot_level[slot] = 0
                entry["completes"].append(rid)
                retired.append(slot)
        if self.paged and retired:
            # hand the rows' blocks back (shared prefix blocks just drop one
            # reference; registered private blocks park in the LRU); their
            # block tables need no host dispatch — the segment already
            # unmapped every row that finished inside it (see
            # decode_segment's writeback), so residual dead-row writes
            # can't follow the freed blocks to their next owner
            for slot in retired:
                blocks, reg = self._slot_blocks[slot]
                self._release_blocks(blocks)
                if reg is not None:
                    self.registry.release(reg)
                self._slot_blocks[slot] = None
        self._inflight.append(entry)

    def _run_segment_spec(self) -> None:
        """One *speculative* decode segment: ``ceil(quantum / W)``
        draft/verify windows through the one pool-lifetime spec executable
        (``W = draft_k + 1``).

        Spec mode is synchronous by design: each window's delivered count
        ``m ∈ [1, W]`` is *data* the host needs for retirement, history
        and billing, so the greedy loop's one-segment-ahead overlap is
        traded for multi-token windows (:meth:`step` flushes with
        ``keep=0``). Two consequences land here:

        * the profile plan is **provisional** — per-window ids bind now
          (the schedule rides the scan as data), but the ledger advances
          only at the flush with the tokens each window actually
          delivered (invariant 11: accepted-token billing);
        * retirement and block release move to :meth:`_flush_spec` — the
          host cannot know which rows finished until ``m`` materializes.

        ``quota = quantum`` caps every row's delivered tokens per segment,
        so the fairness quantum is measured in *accepted* tokens no matter
        how lucky the drafter gets.
        """
        self._flush(0)      # land admissions first: fresh rows' history
        w = self.draft_w    # must hold tok0 before their first window
        n_iter = max(1, -(-self.quantum // w))
        mgr = self.srv.manager
        rem = self.remaining
        if mgr is None:
            sched = np.zeros((n_iter,), np.int32)
        elif len(self.policy.classes) > 1:
            sched = mgr.plan_schedule_classes(
                n_iter, rem, self._slot_level,
                tuple(c.level for c in self.policy.classes
                      if c.accuracy_critical),
                row_critical=self._slot_crit, draft_w=w, provisional=True)
        else:
            sched = mgr.plan_schedule_ragged(n_iter, rem, self._slot_crit,
                                             draft_w=w, provisional=True)
        if self.record_events:
            # events mirror the greedy convention — the PLANNED clamped
            # bill per window (what the provisional planner fed select());
            # the tokens actually billed land in ``spec_billed`` at flush,
            # so a replay oracle reproduces both halves exactly
            for i in range(n_iter):
                live_i = rem > i * w
                self.events.append(
                    (int(sched[i]),
                     int(np.minimum(w, np.maximum(rem - i * w, 0)).sum()),
                     bool((self._slot_crit & live_i).any())))
        fault = np.full((self.n_slots,), -1, np.int32)
        if self.faults is not None:
            for slot in range(self.n_slots):
                rid = self.slot_req[slot]
                if rid is not None and self.remaining[slot] > 0 and \
                        self.faults.want_nan(rid,
                                             self._attempts.get(rid, 0)):
                    fault[slot] = 0
        quota = np.full((self.n_slots,), self.quantum, np.int32)
        toks, ms, ok, self._tok, self._pos, self._caches = self._segment(
            jnp.asarray(sched), jnp.asarray(self._hist),
            jnp.asarray(self._slot_spec), self._tok, self._pos,
            self._caches, jnp.asarray(self.remaining, jnp.int32),
            jnp.asarray(quota), jnp.asarray(fault))
        self._inflight.append({
            "kind": "spec", "toks": toks, "ms": ms, "ok": ok,
            "sched": sched, "crit": self._slot_crit.copy(),
            "rows": [(s, self.slot_req[s]) for s in range(self.n_slots)
                     if self.slot_req[s] is not None],
            "completes": []})

    def _flush_spec(self, e: dict, arr: np.ndarray, names) -> None:
        """Materialize one speculative segment entry (the ``keep=0`` sync
        point): distribute each window's delivered prefix, bill the ledger
        the tokens actually delivered (the dispatch plan was provisional —
        invariant 11), slide each row's drafter history, then retire rows
        whose budget hit zero and hand their blocks back. Rows whose
        verify windows went non-finite route to quarantine exactly like
        greedy segments."""
        # repro: allow(host-sync) the flush boundary IS the sync point
        ms = np.asarray(e["ms"])                          # [B, n_iter]
        # repro: allow(host-sync) flush-boundary sync, same as ms
        okarr = np.asarray(e["ok"]) if e.get("ok") is not None else None
        mgr = self.srv.manager
        sched = e["sched"]
        n_iter = ms.shape[1]
        h = self._hist.shape[1]
        for i in range(n_iter):
            n_tok = int(ms[:, i].sum())   # idle rows deliver 0: full sum
            if mgr is not None:
                mgr.account(int(sched[i]), n_tok)
            if self.record_events:
                self.spec_billed.append((int(sched[i]), n_tok))
        retired: list[int] = []
        for slot, rid in e["rows"]:
            res = self.results[rid]
            delivered: list[int] = []
            for i in range(n_iter):
                m = int(ms[slot, i])
                if m:
                    delivered.extend(arr[slot, i, :m].tolist())
                    res["profile_trace"].extend([names[sched[i]]] * m)
            res["tokens"].extend(delivered)
            if delivered:
                cat = np.concatenate([self._hist[slot],
                                      np.asarray(delivered, np.int32)])
                self._hist[slot] = cat[-h:]
            if okarr is not None and delivered and not okarr[slot] \
                    and rid not in self._nf_rows:
                self._nf_rows.append(rid)
            self.remaining[slot] -= len(delivered)
            if self.remaining[slot] == 0 and delivered:
                self.slot_req[slot] = None               # retire → refill
                self._slot_crit[slot] = False
                self._slot_level[slot] = 0
                e["completes"].append(rid)
                retired.append(slot)
        if self.paged and retired:
            # same contract as greedy retirement: the spec segment already
            # unmapped finished rows in-graph (decode_segment_spec's
            # `finish` writeback), so freed blocks can't take dead writes
            for slot in retired:
                blocks, reg = self._slot_blocks[slot]
                self._release_blocks(blocks)
                if reg is not None:
                    self.registry.release(reg)
                self._slot_blocks[slot] = None

    def _flush(self, keep: int = 0) -> None:
        """Materialize in-flight token blocks into per-request results.

        ``keep`` leaves the newest entries un-synced: with ``keep=1`` the
        engine loop runs one segment ahead of the host sync, so planning,
        admission bookkeeping, and the next dispatch overlap device compute
        (async dispatch) instead of serializing on ``np.asarray`` per segment.
        A request counts as completed only once its tokens are materialized.

        The flush boundary is also where fault *detection* lands on the
        host: each segment entry carries its per-row finite-check flags,
        and a live row that went non-finite is routed to quarantine
        (:meth:`_process_quarantine`) instead of completing.
        """
        if self.faults is not None and len(self._inflight) > keep:
            s = self.faults.flush_stall(self._flush_idx)
            self._flush_idx += 1
            if s > 0.0:
                time.sleep(s)            # injected stall: watchdog fodder
        names = self.srv.engine.profile_names
        drained = len(self._inflight) > keep
        while len(self._inflight) > keep:
            e = self._inflight.pop(0)
            # repro: allow(host-sync) the flush boundary IS the sync point
            arr = np.asarray(e["toks"])                  # blocks until ready
            if e["kind"] == "admit":
                for j, rid in e["rows"]:
                    res = self.results[rid]
                    res["tokens"].append(int(arr[j]))
                    res["profile_trace"].append(e["name"])
                    if self.spec:
                        # the admission token is the row's current token:
                        # it lands in the history's last slot (the n-gram
                        # drafter convention) before the first window runs
                        try:
                            self._hist[self.slot_req.index(rid), -1] = \
                                int(arr[j])
                        except ValueError:
                            pass         # max_new == 1: never went live
            elif e["kind"] == "spec":
                self._flush_spec(e, arr, names)
            else:
                # repro: allow(host-sync) flush-boundary sync, same as toks
                okarr = (np.asarray(e["ok"])
                         if e.get("ok") is not None else None)
                for slot, rid, n in e["rows"]:
                    res = self.results[rid]
                    res["tokens"].extend(arr[slot, :n].tolist())
                    res["profile_trace"].extend(
                        names[p] for p in e["sched"][:n])
                    if okarr is not None and n > 0 and not okarr[slot] \
                            and rid not in self._nf_rows:
                        self._nf_rows.append(rid)
            for rid in e["completes"]:
                if rid in self._nf_rows:
                    continue             # quarantine owns this row now
                res = self.results[rid]
                res["status"] = RequestStatus.COMPLETED
                if rid in self._attempts:
                    res["retries"] = self._attempts[rid]
                if rid in self._q_t0:
                    self.recovery_latency.append(
                        self.clock() - self._q_t0.pop(rid))
                    self.recovered += 1
                self._done.append(rid)
                if self.durable is not None:
                    self.durable.on_final(rid)
        if drained and self.durable is not None:
            self.durable.on_flush()      # crash-point / consistency-cut mark

    # ------------------------------------------------------------------ drive
    def step(self) -> bool:
        """One engine round: retire deadline/cancel/fault casualties, then
        admit and run one segment (one kept in flight). Returns False once
        fully drained (all tokens materialized, no pending retries).
        Mid-admission chunked rows, suspended (preempted) requests, and
        quarantine-backoff retries keep the loop alive."""
        self._round += 1
        t0 = self.clock()
        self._expire()
        self._reap_marked()
        self._process_quarantine()
        if self._quarantine_q:
            ripe = [(r, rid) for r, rid in self._quarantine_q
                    if r <= self._round]
            if ripe:
                self._quarantine_q = [x for x in self._quarantine_q
                                      if x[0] > self._round]
                for _, rid in reversed(ripe):    # preserve relative order
                    self.policy.push_front(rid, self._reqs[rid])
        self.policy.age_tick()           # anti-starvation promotion (if on)
        n_adm = self.admit()
        if n_adm and self.durable is not None:
            self.durable.on_admit(n_adm)
        ran = False
        if self.live_rows:
            self.run_segment()
            # spec mode is synchronous (delivered counts gate retirement);
            # greedy keeps one segment in flight to overlap host + device
            self._flush(keep=0 if self.spec else 1)
            ran = True
        else:
            self._flush()
        dt = self.clock() - t0
        if ran:         # EMA over rounds that actually ran a segment
            self._seg_dt = (dt if self._seg_dt is None
                            else 0.5 * dt + 0.5 * self._seg_dt)
        if self.durable is not None:
            self.durable.on_step_end()   # checkpoint cadence hook
        if self.watchdog is not None:
            self.watchdog.record(f"round {self._round}", dt)
        if self.paranoid:
            self.check()
        return bool(self.live_rows or len(self.policy) or self._inflight
                    or (self.paged and self._chunk_state)
                    or self._to_reap or self._nf_rows or self._quarantine_q)

    def run(self) -> list[dict]:
        """Drain queue + pool; results in submission order (entries already
        claimed through poll_completed come back as None)."""
        while self.step():
            pass
        return [self.results.get(i) for i in range(self._n)]

    def drain(self) -> None:
        """Graceful-shutdown drain: stop admitting new work, then step the
        pool until every already-admitted row (live, chunked, in-flight,
        reaped, quarantined) has reached a terminal status. Queued-but-
        never-admitted requests stay queued — a durability layer
        checkpoints them for the next process; without one the caller
        still holds their journal/submission record. The SIGTERM handler
        in ``launch/serve.py`` drives this."""
        self.draining = True
        if self.durable is not None:
            self.durable.on_drain()
        while (self.live_rows or self._inflight
               or (self.paged and self._chunk_state)
               or self._to_reap or self._nf_rows or self._quarantine_q):
            self.step()

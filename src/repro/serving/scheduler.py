"""Continuous-batching scheduler over the fused decode scan.

The paper's runtime (§4.4, Fig. 4) is an adaptive inference engine that keeps
serving under a shifting energy budget — which presumes the serving layer
keeps the device *busy* under real, heterogeneous traffic. Static grouped
``serve()`` can't: a group must finish entirely before the next one starts, so
every finished row burns decode steps as dead padding and every queued request
waits for the whole group. This module replaces that with continuous batching:

**Slot pool.** The scheduler owns a fixed ``[max_batch]`` row pool whose
decode state (last token, position, KV/SSM caches) lives on device and is
threaded through *donated* jit boundaries — the pool buffers are updated in
place, never copied. A request occupies one row from admission to retirement;
free rows idle with ``remaining == 0`` (the done-mask freezes them, and MoE
capacity dispatch drops them via ``row_valid``).

**Segment quantum.** Decode runs in fixed-size segments of
:func:`repro.models.transformer.decode_segment` — ``quantum`` scan steps per
dispatch, all shapes static in ``(max_batch, quantum)``, so every segment of
the server's lifetime reuses ONE compiled executable no matter which rows are
live. The quantum is the admission latency knob: between segments, retired
rows are refilled from the FIFO queue by an *admission wave* — one ragged
prefill of every waiting request (rows bucketed to a power of two, prompts
left-padded to a power-of-two length bucket with ``prompt_len`` riding as
data → compile count log² rather than one executable per shape) whose
first tokens are argmaxed on device and whose cache rows are scattered into
the free slots, all inside a single donated dispatch. Token blocks come back
*asynchronously*: retirement and admission decisions need only host-side
``remaining`` counts, so the engine loop dispatches the next segment before
materializing the previous one's tokens (``_flush(keep=1)``) and host-side
scheduling overlaps device compute.

**Why re-planning per segment keeps the ledger exact.** The
:class:`ProfileManager` policy is deterministic given its energy ledger, so
profile ids can be precomputed as data — but only as far ahead as the set of
live rows is known. A whole-generation schedule would bill rows that finish
(or get admitted) mid-flight. Planning exactly one segment ahead, with
:meth:`ProfileManager.plan_schedule_ragged` over the *actual* per-row
remaining budgets, bills step ``i`` for precisely the rows live at step ``i``
— the same ledger evolution as a per-step select/account oracle (admission
prefills are billed like the stepwise engine bills prefill: one inference).
Every billing event is recorded in :attr:`ContinuousScheduler.events` so the
tests can replay the ledger against that oracle.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from .engine import AdaptiveServer, Request, _next_pow2

__all__ = ["ContinuousScheduler"]


class ContinuousScheduler:
    """FIFO continuous batching on an :class:`AdaptiveServer`'s slot pool.

    ``quantum`` = decode steps per segment (admission latency vs dispatch
    overhead); ``prefill_bucket`` = minimum power-of-two prompt padding.
    """

    def __init__(self, server: AdaptiveServer, quantum: int = 8,
                 prefill_bucket: int = 8, record_events: bool = True):
        self.srv = server
        self.quantum = int(quantum)
        self.bucket_min = int(prefill_bucket)
        # events/admission_log power the ledger-oracle and FIFO tests; a
        # long-lived server should pass record_events=False (they grow with
        # every segment step). Per-request state (prompt, result) is evicted
        # by poll_completed(); run() keeps results for its return value.
        self.record_events = record_events
        cfg, scfg = server.cfg, server.scfg
        nslots = self.n_slots = scfg.max_batch
        # device-resident pool state (donated through every jit below)
        self._caches = T.init_caches(cfg, nslots, scfg.slots,
                                     kv_bits=scfg.kv_bits)
        self._tok = jnp.zeros((nslots,), jnp.int32)
        self._pos = jnp.zeros((nslots,), jnp.int32)
        # host bookkeeping
        self.remaining = np.zeros((nslots,), np.int64)   # tokens left to emit
        self.slot_req: list[Optional[int]] = [None] * nslots
        self._slot_crit = np.zeros((nslots,), bool)
        self.queue: deque[int] = deque()                 # FIFO pending rids
        self._reqs: dict[int, Request] = {}
        self.results: dict[int, dict] = {}
        self._n = 0
        self.admission_log: list[int] = []               # rids, admission order
        self.events: list[tuple[int, int, bool]] = []    # (pid, n_rows, crit)
        self._done: list[int] = []                       # completions, in order
        self._inflight: list[dict] = []                  # dispatched, unsynced
        # the jitted segment/admit executables live on the server, so
        # schedulers can be torn down and rebuilt without recompiling
        self._segment = server._segment
        self._admit = server._admit

    # ------------------------------------------------------------------ queue
    def submit(self, request: Request) -> int:
        """Enqueue a request (FIFO). Returns its request id."""
        rid = self._n
        self._n += 1
        self._reqs[rid] = request
        if request.max_new <= 0:        # nothing to generate: done on arrival
            self.results[rid] = {"tokens": [], "profile_trace": []}
            self._done.append(rid)
            return rid
        self.queue.append(rid)
        return rid

    @property
    def live_rows(self) -> int:
        return int((self.remaining > 0).sum())

    @property
    def pending(self) -> int:
        return len(self.queue)

    def poll_completed(self) -> list[tuple[int, dict]]:
        """``(rid, result)`` pairs finished since the last poll (completion
        order). Ownership of each result transfers to the caller: the
        scheduler evicts the request's retained state, so a long-lived
        polling server stays O(pool), not O(requests ever served)."""
        done, self._done = self._done, []
        out = []
        for rid in done:
            out.append((rid, self.results.pop(rid)))
            self._reqs.pop(rid, None)
        return out

    # -------------------------------------------------------------- admission
    def admit(self) -> int:
        """Fill free slots from the FIFO queue; returns #requests admitted.

        One admission *wave* is ONE device dispatch: every admitted request
        rides in a single ragged prefill (left-padded to a shared pow2 prompt
        bucket, ``prompt_len`` as data — one executable per bucket), first
        tokens come from an on-device argmax, and each prefilled row is
        scattered into its free pool slot, all inside the server's donated
        ``_admit`` jit. The wave's prefills are billed like the stepwise
        engine bills prefill: one inference per admitted request.
        """
        free = [s for s in range(self.n_slots) if self.slot_req[s] is None]
        take = min(len(free), len(self.queue))
        if not take:
            return 0
        rids = [self.queue.popleft() for _ in range(take)]
        slots = free[:take]
        reqs = [self._reqs[r] for r in rids]
        bucket = _next_pow2(max(self.bucket_min,
                                max(len(r.tokens) for r in reqs)))
        a = _next_pow2(take)               # pow2 wave shape (pad rows drop):
        # a 1–2 row refill costs a 2-row prefill, not a full-pool one, and
        # the executable count stays log² (row bucket × length bucket)
        prompts = np.zeros((a, bucket), np.int32)
        plen = np.zeros((a,), np.int32)    # pad rows: prompt_len 0 → masked
        sidx = np.full((a,), self.n_slots, np.int32)     # OOB → scatter-drop
        for j, r in enumerate(reqs):
            t = np.asarray(r.tokens, np.int32)
            prompts[j, bucket - len(t):] = t             # left-pad
            plen[j] = len(t)
            sidx[j] = slots[j]
        mgr = self.srv.manager
        crit = any(r.accuracy_critical for r in reqs)
        pid = 0 if mgr is None else mgr.select(crit)
        if mgr is not None:
            mgr.account(pid, take)
        if self.record_events:
            self.events.append((pid, take, crit))
        tok0, self._tok, self._pos, self._caches = self._admit(
            pid,
            {"tokens": jnp.asarray(prompts),
             "prompt_len": jnp.asarray(plen)},
            jnp.asarray(sidx), self._tok, self._pos, self._caches)
        entry = {"kind": "admit", "toks": tok0,
                 "name": self.srv.engine.profile_names[pid],
                 "rows": [], "completes": []}
        for j, (rid, slot) in enumerate(zip(rids, slots)):
            req = self._reqs[rid]
            self.results[rid] = {"tokens": [], "profile_trace": []}
            entry["rows"].append((j, rid))
            if self.record_events:
                self.admission_log.append(rid)
            if req.max_new == 1:                         # already complete
                entry["completes"].append(rid)
                continue
            self.slot_req[slot] = rid
            self._slot_crit[slot] = req.accuracy_critical
            self.remaining[slot] = req.max_new - 1
        self._inflight.append(entry)
        return take

    # --------------------------------------------------------------- decoding
    def run_segment(self) -> None:
        """One decode segment over the pool: plan ``quantum`` steps against
        the live rows, dispatch the fused scan, distribute tokens, retire."""
        q = self.quantum
        mgr = self.srv.manager
        rem = self.remaining
        if mgr is None:
            sched = np.zeros((q,), np.int32)
        else:
            sched = mgr.plan_schedule_ragged(q, rem, self._slot_crit)
        if self.record_events:
            for i in range(q):
                live_i = rem > i
                self.events.append((int(sched[i]), int(live_i.sum()),
                                    bool((self._slot_crit & live_i).any())))
        toks, self._tok, self._pos, self._caches = self._segment(
            jnp.asarray(sched), self._tok, self._pos, self._caches,
            jnp.asarray(self.remaining, jnp.int32))
        # retirement depends only on host-side remaining counts, never on
        # token *values* — so bookkeeping (and the next admission/segment
        # dispatch) proceeds without materializing ``toks``
        entry = {"kind": "seg", "toks": toks, "sched": sched,
                 "rows": [], "completes": []}
        for slot in range(self.n_slots):
            rid = self.slot_req[slot]
            if rid is None:
                continue
            n = int(min(self.remaining[slot], q))
            entry["rows"].append((slot, rid, n))
            self.remaining[slot] -= n
            if self.remaining[slot] == 0:                # retire → refillable
                self.slot_req[slot] = None
                self._slot_crit[slot] = False
                entry["completes"].append(rid)
        self._inflight.append(entry)

    def _flush(self, keep: int = 0) -> None:
        """Materialize in-flight token blocks into per-request results.

        ``keep`` leaves the newest entries un-synced: with ``keep=1`` the
        engine loop runs one segment ahead of the host sync, so planning,
        admission bookkeeping, and the next dispatch overlap device compute
        (async dispatch) instead of serializing on ``np.asarray`` per segment.
        A request counts as completed only once its tokens are materialized.
        """
        names = self.srv.engine.profile_names
        while len(self._inflight) > keep:
            e = self._inflight.pop(0)
            arr = np.asarray(e["toks"])                  # blocks until ready
            if e["kind"] == "admit":
                for j, rid in e["rows"]:
                    res = self.results[rid]
                    res["tokens"].append(int(arr[j]))
                    res["profile_trace"].append(e["name"])
            else:
                for slot, rid, n in e["rows"]:
                    res = self.results[rid]
                    res["tokens"].extend(arr[slot, :n].tolist())
                    res["profile_trace"].extend(
                        names[p] for p in e["sched"][:n])
            self._done.extend(e["completes"])

    # ------------------------------------------------------------------ drive
    def step(self) -> bool:
        """Admit then run one segment, keeping one segment in flight.
        Returns False once fully drained (all tokens materialized)."""
        self.admit()
        if self.live_rows:
            self.run_segment()
            self._flush(keep=1)
        else:
            self._flush()
        return bool(self.live_rows or self.queue or self._inflight)

    def run(self) -> list[dict]:
        """Drain queue + pool; results in submission order (entries already
        claimed through poll_completed come back as None)."""
        while self.step():
            pass
        return [self.results.get(i) for i in range(self._n)]

"""Adaptive serving engine: batched prefill + fused on-device decode loop.

The FPGA paper's runtime (Fig. 4 left) = Adaptive Inference Engine + Profile
Manager. Here the engine is a pair of jitted functions closed over the merged
profile family (profile_id is a traced scalar → switching never recompiles),
and the manager picks the profile per decode step from the energy budget.

**Scan/donation design.** Decode runs as a single jitted ``jax.lax.scan`` over
the generation length (:func:`repro.models.transformer.decode_many`):

* one dispatch per ``generate`` call — greedy argmax sampling, KV/SSM cache
  updates, and profile switching all stay on device; the only host sync is
  one ``np.asarray`` of the final ``[B, steps]`` token block (the seed
  engine synced + re-dispatched per token);
* the KV caches are threaded through the scan carry and **donated** at the
  ``jit`` boundary (``donate_argnums``), so XLA updates the cache buffers in
  place instead of copying them every step;
* profile adaptivity survives fusion: the :class:`ProfileManager` budget
  policy is deterministic given its energy ledger, so the per-step profile
  ids are precomputed as an ``int32[steps]`` schedule
  (``ProfileManager.plan_schedule``) and fed to the scan as *data* — the
  merged engine stays branch-free and a new schedule never retraces. The
  realized per-step trace comes back from the device for accounting.

``generate_stepwise`` keeps the seed per-token host loop as the benchmark
baseline (``benchmarks/serving_bench.py`` measures the tokens/sec win).

KV cache precision is a deployment knob (``kv_bits``: 16 = bf16 baseline,
8 = int8 — the beyond-paper memory-roofline win; the Pallas
``qkv_attention`` kernel is the TPU path for the int8 layout, and the jnp
decode path contracts on the same int8 grid).
"""
from __future__ import annotations

import dataclasses
import enum
import logging
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import AdaptiveEngine
from repro.core.manager import ProfileManager, ProfileStats
from repro.models import transformer as T

__all__ = ["ServingConfig", "AdaptiveServer", "Request", "RequestStatus"]


class RequestStatus(str, enum.Enum):
    """Terminal outcome of one request — the single enum every lifecycle
    path resolves to on ``poll_completed`` results (``result["status"]``).

    ``COMPLETED`` — all ``max_new`` tokens delivered. ``CANCELLED`` — client
    cancellation (:meth:`~repro.serving.scheduler.ContinuousScheduler.
    cancel`); tokens generated before the cancel are delivered. ``EXPIRED``
    — the request's ``deadline_ms`` passed (in queue, mid-generation, or
    rejected up front as unreachable at admission — ``result["reason"]``
    says which). ``SHED`` — dropped by the overload shedding policy
    (:class:`~repro.serving.policy.ShedPolicy`) instead of queueing
    unboundedly. ``FAILED`` — produced non-finite output on every attempt
    of the quarantine/precision-fallback retry ladder. Values are plain
    strings (``str`` subclass) so results serialize to JSON untouched.
    """

    COMPLETED = "completed"
    CANCELLED = "cancelled"
    EXPIRED = "expired"
    SHED = "shed"
    FAILED = "failed"


def _next_pow2(n: int) -> int:
    """Smallest power of two ≥ ``n`` (shape-bucketing helper)."""
    return 1 << (int(n) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Deployment knobs for an :class:`AdaptiveServer`.

    ``slots`` — per-row KV capacity in tokens; must cover ``prompt_len +
    max_new`` for every request (sliding-window stacks ring within their
    window regardless). ``kv_bits`` — KV cache storage precision: 16 (bf16
    baseline), 8 (int8, the beyond-paper memory-roofline win) or 4 (packed
    int4, two nibbles per byte — half of kv8's pool bytes, 2× its token
    capacity; the paged-attention kernel unpacks in VMEM). ``max_batch``
    — decode rows: the static group width of :meth:`AdaptiveServer.serve` and
    the slot-pool size of :class:`~repro.serving.scheduler.
    ContinuousScheduler`. ``greedy`` — argmax sampling (the only mode the
    fused decode scan implements today).

    Paged-KV knobs (used by the continuous scheduler's slot pool; the
    solo/static paths keep the contiguous layout as the oracle):

    ``paged_kv`` — lay the pool out as a global block pool + per-row block
    tables instead of contiguous ``[max_batch, slots]`` rows.
    ``block_size`` — tokens per block (rounded down to a divisor of the
    sliding window when one exists, so paged placement matches the
    contiguous ring exactly). ``pool_blocks`` — physical blocks to
    provision; ``None`` means ``max_batch * ceil(slots/block_size)``, the
    exact contiguous footprint — set it lower to realize the paged memory
    win (short rows + shared prefixes), with admission backpressure as the
    safety valve. ``prefix_cache`` — register block-aligned prompt prefixes
    and serve hash-matched admissions from them (full-attention stacks
    only); ``prefix_capacity`` bounds registered entries (LRU — note one
    prompt registers its whole block-aligned prefix chain, one entry per
    length, so later prompts can match at any block boundary).
    ``paged_backend`` — how decode reads the paged pool: ``"pallas"``
    attends in place against the blocks through the paged-attention kernel
    (no dense view, no fold-back — the serving hot path), ``"gather"``
    materializes the per-segment dense view (the CPU oracle path),
    ``"auto"`` picks pallas on TPU and gather elsewhere. ``prefill_chunk``
    — when set, admission prompts longer than this many tokens prefill in
    block-aligned chunks that interleave with decode segments instead of
    one monolithic wave (full-causal stacks only), smoothing the
    admission-wave latency spike; ``None`` disables chunking.

    Scheduling-policy knobs (:mod:`repro.serving.policy`):

    ``priority_classes`` — number of request priority classes; 1 keeps the
    classless FIFO, ≥2 builds the stock ladder (class 0 = ``critical``:
    admitted first and profile-bound to the accuracy target; the last
    class = ``saver``: preemptible). Requests pick their class with
    :attr:`Request.priority`. ``preemption`` — arm preemptive scheduling:
    a critical arrival that cannot admit (no free slot, or the block
    allocator is dry) evicts saver-class rows — their block tables and
    host-side KV masters are snapshotted (:meth:`~repro.serving.scheduler.
    ContinuousScheduler.evict_row`) and they resume later through the
    continuation-prefill executable, token-identically. Requires the paged
    pool on a ``supports_prefix_sharing`` stack. ``aging`` — anti-
    starvation promotion age in scheduler rounds: a queued class head
    that has waited this many rounds is promoted one level up the
    ladder (queue position only — profile binding, billing and
    preemption keep the request's class); ``None`` keeps strict
    lowest-level-first.

    Speculative-decoding knobs (docs/serving.md §Speculation):

    ``speculate`` — decode through draft/verify windows: each segment
    iteration proposes ``draft_k`` tokens per row and verifies the
    ``draft_k + 1`` window in ONE batched forward
    (:func:`repro.models.transformer.decode_segment_spec`), delivering
    1..``draft_k + 1`` tokens per row per iteration — **token-identical**
    to non-speculative greedy at kv16 and kv8, it only changes
    throughput. Requires a ``supports_speculation`` stack (full causal
    attention, kv16/kv8). The pool-lifetime ``_segment`` executable IS
    the speculative one on such a server: still exactly one decode
    executable, zero per-token dispatches. ``draft_k`` — drafted tokens
    per window. ``draft_hist`` — token-history length the self-
    speculative n-gram drafter sees (a host-side ``[max_batch,
    draft_hist]`` operand, updated at the flush boundary).
    ``draft_model`` — which drafter proposes: ``None``/``"ngram"`` = the
    built-in majority-vote follower n-gram drafter, ``"repeat"`` = repeat
    the current token (the degenerate run-length drafter). External
    small-model drafters plug in as a traced ``draft_fn(hist, tok) ->
    [B, draft_k]`` via :class:`AdaptiveServer`'s ``draft_fn`` argument.

    Durability knob (docs/serving.md §Durability):

    ``kv16_masters`` — keep full-precision (f32) KV masters for shared
    prefixes and chunked rows even at ``kv_bits=16``. The bf16 pool is
    normally its own master (shared admissions gather the prefix straight
    from the shared blocks), which is token-identical but not
    *structurally* bit-exact: a continuation attends over bf16-rounded
    prefix values where a cold prefill attends over the raw f32 ones.
    With masters on, every continuation path (shared, chunked, restore)
    replays the prefix from the raw activations — the same structural
    bit-exactness the preemption-restore path has at int KV — and durable
    checkpoints snapshot exact row state at kv16. Costs host memory
    (f32 masters per registry entry / in-flight chunk row); identity of
    delivered tokens does not depend on it. Only meaningful at
    ``kv_bits=16`` — int pools (kv8/kv4) already keep masters, and the
    combination is rejected at construction.

    Precision-policy knob (docs/serving.md §Precision ladder):

    ``precision_policy`` — per-profile, per-layer KV bit-width schedule: a
    ``[n_profiles, n_layers]`` nested tuple of entries in (4, 8, 16),
    typically searched offline against the accuracy-vs-bytes frontier
    (:meth:`repro.core.manager.ProfileManager.search_precision` /
    ``benchmarks/precision_frontier.py``). The table rides the executables
    as **data** (rows gathered by the traced profile id), so profile
    switches never retrace; entries of 16 are exact passthrough, which is
    how a ``critical``-bound profile row pins the hand-set baseline
    token-identically while ``saver`` profiles ride the searched frontier.
    ``None`` (default) disables the policy with a byte-identical lowering.
    Incompatible with ``speculate`` (draft/verify windows do not thread
    the per-layer schedule).
    """

    slots: int = 4096
    kv_bits: int = 16
    max_batch: int = 8
    greedy: bool = True
    paged_kv: bool = True
    block_size: int = 16
    pool_blocks: Optional[int] = None
    prefix_cache: bool = True
    prefix_capacity: int = 32
    paged_backend: str = "auto"
    prefill_chunk: Optional[int] = None
    priority_classes: int = 1
    preemption: bool = False
    aging: Optional[int] = None
    speculate: bool = False
    draft_k: int = 4
    draft_hist: int = 32
    draft_model: Optional[str] = None
    kv16_masters: bool = False
    precision_policy: Optional[tuple] = None


@dataclasses.dataclass
class Request:
    """One generation request.

    ``tokens`` — the ``[S]`` int32 prompt. ``max_new`` — token budget; the
    request retires after exactly ``max_new`` generated tokens (greedy, no
    EOS short-circuit). ``accuracy_critical`` — pins profile selection to
    the accuracy target even in the battery-saver regime (paper §4.4).
    ``priority`` — priority-class index under a class-aware scheduling
    policy (0 = most urgent, clamped into the configured ladder; ignored
    by the classless FIFO). Class membership also binds the profile
    policy: rows of an accuracy-critical class pin selection like
    ``accuracy_critical`` does. ``deadline_ms`` — optional client SLO in
    milliseconds from submission: the scheduler expires the request
    (``RequestStatus.EXPIRED``) if the deadline passes while it is queued
    or mid-generation, and rejects it up front at admission when the
    current throughput estimate says it cannot finish in time.
    """

    tokens: np.ndarray
    max_new: int = 32
    accuracy_critical: bool = False
    priority: int = 1
    deadline_ms: Optional[float] = None


class AdaptiveServer:
    """Adaptive inference engine: jitted serving entry points over one model.

    Owns the compiled executables of the serving stack — ``_prefill`` /
    ``_decode`` (stepwise oracle), ``_generate`` (fused whole-generation
    scan), and the continuous-batching primitives ``_segment`` / ``_admit``
    (+ paged variants) shared by every :class:`~repro.serving.scheduler.
    ContinuousScheduler` built on top — plus the per-profile prequantized
    weight images. Profile adaptivity is bits-as-data: ``profile_id`` and
    per-step schedules are traced int32 inputs, so switching profiles never
    recompiles (the paper's runtime configuration word).

    Args:
        cfg: model architecture.
        params: parameter pytree (fixed for the server's lifetime — the
            prequant images and closed-over executables assume it).
        engine: merged :class:`AdaptiveEngine` (profile family + bits table).
        serving: :class:`ServingConfig` deployment knobs.
        manager: optional :class:`ProfileManager`; ``None`` pins profile 0.
    """

    def __init__(self, cfg: T.ModelConfig, params, engine: AdaptiveEngine,
                 serving: ServingConfig,
                 manager: Optional[ProfileManager] = None,
                 draft_fn=None):
        """Compile the serving executables and prequantize weight images
        (see the class docstring for the argument contract). ``draft_fn``
        overrides the speculative drafter: a traced ``(hist [B, H], tok
        [B]) -> proposals [B, draft_k]`` callable (external small-model
        drafters); ``None`` defers to ``ServingConfig.draft_model``."""
        self.cfg = cfg
        self.params = params
        self.engine = engine
        self.scfg = serving
        self.manager = manager
        table = engine.table
        if serving.kv_bits not in (4, 8, 16, 32):
            raise ValueError(f"kv_bits must be 4, 8, 16 or 32, "
                             f"got {serving.kv_bits}")
        if serving.kv16_masters and serving.kv_bits != 16:
            raise ValueError(
                "kv16_masters only applies to bf16 pools (kv_bits=16): "
                f"a kv{serving.kv_bits} pool is lossy and always keeps "
                "full-precision masters")
        if serving.speculate:
            if not T.supports_speculation(cfg, serving.kv_bits):
                raise ValueError(
                    "speculate=True needs a supports_speculation stack: "
                    "full causal attention (no SSM/MoE/sliding-window) "
                    "with kv_bits in (8, 16)")
            if serving.draft_k < 1:
                raise ValueError("draft_k must be >= 1")
            if serving.draft_hist < 2:
                raise ValueError("draft_hist must be >= 2 (the n-gram "
                                 "drafter votes over history pairs)")
        if draft_fn is None:
            if serving.draft_model in (None, "ngram"):
                pass                     # decode_segment_spec's built-in
            elif serving.draft_model == "repeat":
                def draft_fn(hist, tok):
                    return jnp.broadcast_to(tok[:, None],
                                            (tok.shape[0], serving.draft_k))
            else:
                raise ValueError(f"unknown draft_model "
                                 f"{serving.draft_model!r}: use None, "
                                 f"'ngram' or 'repeat' (or pass draft_fn)")
        self.draft_fn = draft_fn

        # ---- per-layer precision policy (kv_table) -----------------------
        # precision as a policy OUTPUT: each profile binds an int32[L] row
        # of per-layer KV bit-widths. The [P, L] table is a server-lifetime
        # constant the executables close over; rows are gathered by the
        # *traced* profile id, so schedule/profile switches never retrace —
        # the same bits-as-data trick as the engine's quant table. With no
        # policy every call site passes kv_sched=None and the lowering is
        # byte-identical to the policy-free engine.
        kv_table = None
        if serving.precision_policy is not None:
            if serving.speculate:
                raise ValueError(
                    "precision_policy is incompatible with speculate=True: "
                    "draft/verify windows do not thread the per-layer KV "
                    "schedule")
            pol = np.asarray(serving.precision_policy, np.int32)
            n_prof = len(engine.profile_names)
            if pol.shape != (n_prof, cfg.n_layers):
                raise ValueError(
                    f"precision_policy must have shape [n_profiles="
                    f"{n_prof}, n_layers={cfg.n_layers}], got "
                    f"{tuple(pol.shape)}")
            if not np.isin(pol, (4, 8, 16)).all():
                raise ValueError(
                    "precision_policy entries must be 4, 8 or 16")
            kv_table = jnp.asarray(pol)
        self.kv_table = kv_table

        def prefill_fn(params, profile_id, batch):
            bits = jnp.asarray(table)[profile_id]
            ks = None if kv_table is None else kv_table[profile_id]
            return T.prefill(params, cfg, bits, batch, serving.slots,
                             kv_bits=serving.kv_bits, kv_sched=ks)

        def decode_fn(params, profile_id, tokens, pos, caches):
            bits = jnp.asarray(table)[profile_id]
            ks = None if kv_table is None else kv_table[profile_id]
            return T.decode_step(params, cfg, bits, tokens, pos, caches,
                                 kv_sched=ks)

        def generate_fn(params, prequant, schedule, logits0, pos0, caches,
                        row_budget):
            return T.decode_many(params, cfg, jnp.asarray(table), schedule,
                                 logits0, pos0, caches, row_budget=row_budget,
                                 prequant=prequant, kv_table=kv_table)

        # ---- paged decode backend ----------------------------------------
        # "pallas" = in-place paged-attention kernel (interpret mode off-TPU,
        # compiled on TPU); "gather" = per-segment dense view, the oracle.
        # kv4/kv8/kv16 all have a kernel path (kv4 unpacks its nibbles in
        # VMEM); any other precision degrades to gather — loudly.
        pb = serving.paged_backend
        if pb not in ("auto", "pallas", "gather"):
            raise ValueError(f"paged_backend must be auto|pallas|gather, "
                             f"got {pb!r}")
        if pb == "auto":
            pb = "pallas" if jax.default_backend() == "tpu" else "gather"
        if pb == "pallas" and serving.kv_bits not in (4, 8, 16):
            logging.getLogger("repro.serving").warning(
                "paged_backend degraded pallas -> gather: kv_bits=%d has "
                "no paged-attention kernel path (kv4/kv8/kv16 only)",
                serving.kv_bits)
            pb = "gather"
        self.paged_backend = pb

        # params / prequant are server-lifetime constants: the continuous
        # primitives close over them so a dispatch only flattens the small
        # slot-pool carry (schedule, tok, pos, caches, remaining) instead of
        # re-processing the full parameter pytree every segment — per-call
        # python overhead is what continuous batching lives or dies by
        def segment_fn(schedule, tok, pos, caches, remaining, fault_step):
            # fault_step [B] is DATA (normally all −1): the chaos machinery's
            # NaN-injection operand plus the per-row finite-check flag ride
            # the one pool-lifetime segment executable — detection and
            # injection never add a dispatch or a recompile
            return T.decode_segment(self.params, cfg, jnp.asarray(table),
                                    schedule, tok, pos, caches, remaining,
                                    prequant=self._prequant,
                                    paged_backend=self.paged_backend,
                                    fault_step=fault_step,
                                    kv_table=kv_table)

        def segment_spec_fn(schedule, hist, spec_on, tok, pos, caches,
                            remaining, quota, fault_step):
            # speculative pool-lifetime segment: len(schedule) draft/verify
            # windows; hist/spec_on/quota are per-dispatch DATA operands
            # (host token history, per-class opt-out, quantum in accepted
            # tokens) — same zero-recompile contract as the greedy segment
            return T.decode_segment_spec(self.params, cfg, jnp.asarray(table),
                                         schedule, tok, pos, caches,
                                         remaining, quota=quota, hist0=hist,
                                         spec_on=spec_on,
                                         prequant=self._prequant,
                                         paged_backend=self.paged_backend,
                                         fault_step=fault_step,
                                         draft_k=serving.draft_k,
                                         draft_fn=self.draft_fn)

        def admit_fn(profile_id, batch, slots_idx, tok, pos, caches):
            # one admission wave = one dispatch: ragged prefill of every
            # waiting request (left-padded to a shared pow2 bucket,
            # ``prompt_len`` as data) + on-device first-token argmax + scatter
            # of each prefilled row into its pool slot. Rows whose
            # ``slots_idx`` is out of range (admission-batch padding) are
            # dropped by the scatter. The WHOLE pool row is overwritten
            # (batch axis 1 under the [L, ...] layer stacking): stale
            # token_idx entries of a retired request must not survive into
            # the new request's attention window.
            bits = jnp.asarray(table)[profile_id]
            ks = None if kv_table is None else kv_table[profile_id]
            logits, rows = T.prefill(self.params, cfg, bits, batch,
                                     serving.slots, kv_bits=serving.kv_bits,
                                     kv_sched=ks)
            tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            caches = jax.tree.map(
                lambda pool, row: pool.at[:, slots_idx].set(row, mode="drop"),
                caches, rows)
            return (tok0,
                    tok.at[slots_idx].set(tok0, mode="drop"),
                    pos.at[slots_idx].set(
                        jnp.asarray(batch["prompt_len"], jnp.int32),
                        mode="drop"),
                    caches)

        # ---- paged-KV geometry (continuous scheduler's block pool) -------
        # block size degrades to a divisor of the SWA window so paged ring
        # placement matches the contiguous ring slot-for-slot
        self.block_size = T.paged_block_size(cfg, serving.slots,
                                             serving.block_size)
        eff = (min(serving.slots, cfg.sliding_window) if cfg.sliding_window
               else serving.slots)
        self.n_lblk = -(-eff // self.block_size)       # logical blocks / row
        self.slots_p = self.n_lblk * self.block_size   # virtual row length
        self.prefix_sharing = bool(serving.prefix_cache
                                   and T.supports_prefix_sharing(cfg))
        # chunked prefill rides the continuation-prefill machinery
        # (prefill_extend at absolute positions), which is exact only where
        # prefix sharing is: full causal attention, no SSM/MoE coupling.
        # Chunk length rounds down to a block multiple so every chunk
        # boundary is a block boundary (the kv16 path gathers the processed
        # prefix straight from the row's own whole blocks).
        self.chunk_tokens: Optional[int] = None
        if serving.prefill_chunk and T.supports_prefix_sharing(cfg):
            self.chunk_tokens = max(
                self.block_size,
                (int(serving.prefill_chunk) // self.block_size)
                * self.block_size)
        # full-precision prefix masters are needed when the pool's storage
        # is lossy (int KV): a bf16 pool *is* its own master, so kv16 shared
        # admissions gather the prefix straight from the shared blocks and
        # the registry stores nothing but block ids. Chunked prefill needs
        # them for the same reason (each chunk replays the previous ones as
        # its prefix). ``kv16_masters`` opts a bf16 pool into the same
        # master-backed continuations (structural bit-exactness + exact
        # durable snapshots at kv16 — see the ServingConfig docstring).
        self.masters_mode = (serving.kv_bits != 16
                             or bool(serving.kv16_masters))
        self._collect_masters = self.masters_mode and bool(
            self.prefix_sharing or self.chunk_tokens)

        def admit_paged_fn(profile_id, batch, slots_idx, dest, tok, pos,
                           caches):
            # paged admission wave: one ragged prefill into transient dense
            # rows, then one scatter of those rows into the block pool at
            # the host-chosen physical ids. ``dest[j, l]`` is the write
            # mapping for row j's logical block l — out-of-range entries
            # (wave padding, logical blocks past the row's need, and shared
            # prefix blocks owned by the registry) are DROPPED by the
            # scatter: that drop is the copy-on-write discipline. Writing
            # every private block wholesale also clears any stale
            # ``token_idx`` left by the block's previous owner.
            bits = jnp.asarray(table)[profile_id]
            ks = None if kv_table is None else kv_table[profile_id]
            out = T.prefill(self.params, cfg, bits, batch, self.slots_p,
                            kv_bits=serving.kv_bits,
                            return_raw_kv=self._collect_masters,
                            kv_sched=ks)
            logits, rows = out[0], out[1]
            raw = out[2] if self._collect_masters else None
            tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            caches = dict(caches)
            caches["kv"] = self._scatter_blocks(caches["kv"], rows["kv"],
                                                dest, slots_idx)
            if "ssm" in caches:
                caches["ssm"] = jax.tree.map(
                    lambda pool, row: pool.at[:, slots_idx].set(
                        row, mode="drop"),
                    caches["ssm"], rows["ssm"])
            plen = jnp.asarray(batch["prompt_len"], jnp.int32)
            return (tok0, raw,
                    tok.at[slots_idx].set(tok0, mode="drop"),
                    pos.at[slots_idx].set(plen, mode="drop"),
                    caches)

        def _admit_shared_body(profile_id, batch, slots_idx, dest, bt_rows,
                               kpre, vpre, ka, va, prefix_len, tok, pos,
                               caches):
            # shared-prefix admission wave: continuation prefill over the
            # suffixes only (prefix KV replayed from masters / pool
            # blocks), then the same block scatter — with ``dest``
            # out-of-range on the shared blocks (never written; ``bt_rows``
            # still maps them) and private on everything after the
            # divergence point: that skipped write IS the copy-on-write.
            # Chunked prefill reuses this executable verbatim: a chunk's
            # "prefix" is simply the row's own previously processed chunks.
            bits = jnp.asarray(table)[profile_id]
            ks = None if kv_table is None else kv_table[profile_id]
            out = T.prefill_extend(
                self.params, cfg, bits, batch, self.slots_p,
                kv_bits=serving.kv_bits, prefix_k=kpre, prefix_v=vpre,
                prefix_len=prefix_len, prefix_k_amax=ka, prefix_v_amax=va,
                return_raw_kv=self._collect_masters, kv_sched=ks)
            logits, rows = out[0], out[1]
            raw = out[2] if self._collect_masters else None
            tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            caches = dict(caches)
            caches["kv"] = self._scatter_blocks(caches["kv"], rows["kv"],
                                                dest, slots_idx,
                                                bt_rows=bt_rows)
            plen = jnp.asarray(prefix_len, jnp.int32) + \
                jnp.asarray(batch["prompt_len"], jnp.int32)
            return (tok0, raw,
                    tok.at[slots_idx].set(tok0, mode="drop"),
                    pos.at[slots_idx].set(plen, mode="drop"),
                    caches)

        def admit_shared_pool_fn(profile_id, batch, slots_idx, dest, bt_rows,
                                 pre_bids, prefix_len, tok, pos, caches):
            # bf16 variant: the shared pool blocks ARE the masters — gather
            # the prefix KV straight from them (zero duplicated storage)
            pool = caches["kv"]
            a, pb = pre_bids.shape

            def gather(x):                     # [L, nb, bs, Hkv, hd]
                g = jnp.take(x, pre_bids, axis=1, mode="fill", fill_value=0)
                return g.reshape(cfg.n_layers, a, pb * x.shape[2],
                                 *x.shape[3:]).astype(jnp.float32)

            return _admit_shared_body(profile_id, batch, slots_idx, dest,
                                      bt_rows, gather(pool.k),
                                      gather(pool.v), None, None,
                                      prefix_len, tok, pos, caches)

        def clear_rows_fn(slots_idx, caches):
            # retirement: unmap the rows' block tables so a retired row's
            # residual junk writes (dead rows keep stepping inside a
            # segment) can never land in a block that has been reallocated
            pool = caches["kv"]
            nb = pool.k.shape[1]           # [L, n_blocks, bs, ...]
            bt = pool.block_table.at[:, slots_idx].set(nb, mode="drop")
            return {**caches, "kv": pool._replace(block_table=bt)}

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn,
                               donate_argnums=(4,))        # stepwise baseline
        # per-profile weight images, materialized once per server (params and
        # the profile table are fixed for its lifetime)
        self._prequant = jax.jit(
            lambda p: T.prequant_decode_weights(p, cfg, jnp.asarray(table))
        )(params)
        # donate the caches: the scan threads them through its carry and XLA
        # aliases input → output buffers (in-place ring-buffer writes, no
        # per-step cache copy)
        self._generate = jax.jit(generate_fn, donate_argnums=(5,))
        # continuous-batching primitives (ContinuousScheduler): jitted here so
        # every scheduler instance over this server shares the compiled
        # executables; the slot-pool state they donate lives in the scheduler.
        # A speculative server's ONE pool-lifetime segment executable IS the
        # spec variant — never both, so the single-_segment invariant holds
        # in either mode (SchedulerAudit.assert_single_segment)
        if serving.speculate:
            self._segment = jax.jit(segment_spec_fn,
                                    donate_argnums=(3, 4, 5))
        else:
            self._segment = jax.jit(segment_fn, donate_argnums=(1, 2, 3))
        self._admit = jax.jit(admit_fn, donate_argnums=(3, 4, 5))
        # paged continuous-batching primitives: same sharing story as above
        # (compiled once per server; the scheduler owns the donated pool)
        self._admit_paged = jax.jit(admit_paged_fn, donate_argnums=(4, 5, 6))
        # shared-prefix admissions and chunked-prefill continuations share
        # the same continuation executable
        if not (self.prefix_sharing or self.chunk_tokens):
            self._admit_shared = None
        elif not self.masters_mode:
            self._admit_shared = jax.jit(admit_shared_pool_fn,
                                         donate_argnums=(7, 8, 9))
        else:
            # master-backed variant: prefix replayed from full-precision
            # registry masters — mandatory at int KV (the pool's int8 rows
            # were quantized on the *owner's* per-row grid and are not
            # bit-shareable), opt-in at kv16 via ``kv16_masters``
            self._admit_shared = jax.jit(_admit_shared_body,
                                         donate_argnums=(10, 11, 12))
        self._clear_rows = jax.jit(clear_rows_fn, donate_argnums=(1,))
        # preemption restore: a suspended row re-admits by replaying its own
        # processed tokens as the continuation prefix — always from the
        # host-side masters its eviction snapshotted (the row's blocks were
        # released to the pool), so the master-replay continuation body is
        # the restore executable at EVERY precision. At int KV that is
        # literally self._admit_shared (same jit object, zero extra
        # compiles); at kv16 the pool-gather shared wave cannot serve (there
        # are no blocks left to gather from), so the master body gets its
        # own jit — one more admission-side executable per server, while the
        # pool-lifetime single-_segment invariant is untouched.
        if serving.preemption and not (serving.paged_kv
                                       and T.supports_prefix_sharing(cfg)):
            raise ValueError(
                "preemption requires the paged KV pool on a full-causal "
                "attention stack (supports_prefix_sharing): suspended rows "
                "resume through the continuation-prefill executable")
        # built on every capable stack (not just under preemption): crash
        # recovery re-admits checkpointed rows through the exact same
        # executable, and jit objects compile lazily — an unused restore
        # path costs nothing
        if not (serving.paged_kv and T.supports_prefix_sharing(cfg)):
            self._admit_restore = None
        elif self.masters_mode and self._admit_shared is not None:
            self._admit_restore = self._admit_shared
        else:
            self._admit_restore = jax.jit(_admit_shared_body,
                                          donate_argnums=(10, 11, 12))

    def _scatter_blocks(self, pool, rows, dest, sidx, bt_rows=None):
        """Scatter dense admission rows into the paged pool (traced helper).

        ``rows`` is the stacked contiguous ``[L, a, slots_p, ...]`` cache an
        admission prefill produced; each row is cut into ``n_lblk`` blocks
        and written at physical ids ``dest [a, n_lblk]`` (out-of-range =
        skip: wave padding, unallocated tail, shared prefix blocks).
        ``bt_rows`` is the mapping installed in the block table — it differs
        from ``dest`` exactly when shared blocks are mapped-but-not-written.
        Per-row scales and the block table land at pool rows ``sidx``.
        """
        nlb, bs = self.n_lblk, self.block_size
        L = self.cfg.n_layers
        a = dest.shape[0]

        def blk(x):
            return x.reshape(L, a, nlb, bs, *x.shape[3:])

        bt = pool.block_table.at[:, sidx].set(
            dest if bt_rows is None else bt_rows, mode="drop")
        return pool._replace(
            k=pool.k.at[:, dest].set(blk(rows.k), mode="drop"),
            v=pool.v.at[:, dest].set(blk(rows.v), mode="drop"),
            token_idx=pool.token_idx.at[:, dest].set(blk(rows.token_idx),
                                                     mode="drop"),
            k_scale=pool.k_scale.at[:, sidx].set(rows.k_scale, mode="drop"),
            v_scale=pool.v_scale.at[:, sidx].set(rows.v_scale, mode="drop"),
            block_table=bt)

    def _select_profile(self, critical: bool) -> int:
        if self.manager is None:
            return 0
        return self.manager.select(accuracy_critical=critical)

    def generate(self, prompts: np.ndarray, max_new: int,
                 accuracy_critical: bool = False, *,
                 row_budget: Optional[np.ndarray] = None,
                 prompt_len: Optional[np.ndarray] = None,
                 row_critical: Optional[np.ndarray] = None,
                 account_rows: Optional[int] = None) -> dict:
        """Batched greedy generation, fused: one prefill dispatch + one decode
        dispatch. prompts ``[B, S]`` int32 (ragged requests left-padded to a
        common length). ``prompt_len [B]`` marks each row's real length: rows
        then get per-row rope offsets, pad-key masks, logical-position KV
        handoff, and per-row ``pos0 = prompt_len`` — a mixed-length batch
        generates exactly what each row would solo. ``row_budget [B]`` masks
        per-row tokens at index ≥ budget to −1 (early stop for heterogeneous
        request budgets). With a manager, per-row data (``row_budget`` /
        ``row_critical``) switches the schedule to the exact ragged ledger
        (step ``i`` bills only rows still live); otherwise ``account_rows``
        rows are billed every step. Returns tokens + the per-step profile
        trace."""
        b, s = prompts.shape
        if self.manager is None:
            schedule = np.zeros((max_new,), np.int32)
        elif row_budget is not None or row_critical is not None:
            rb_plan = (np.full((b,), max_new) if row_budget is None
                       else np.minimum(np.asarray(row_budget), max_new))
            rc = (np.full((b,), bool(accuracy_critical))
                  if row_critical is None else np.asarray(row_critical, bool))
            schedule = self.manager.plan_schedule_ragged(max_new, rb_plan, rc)
        else:
            n_account = b if account_rows is None else account_rows
            schedule = self.manager.plan_schedule(max_new, n_account,
                                                  accuracy_critical=accuracy_critical)
        batch = {"tokens": jnp.asarray(prompts)}
        if prompt_len is not None:
            batch["prompt_len"] = jnp.asarray(prompt_len, jnp.int32)
        logits, caches = self._prefill(self.params, int(schedule[0]), batch)
        pos0 = (jnp.full((b,), s, jnp.int32) if prompt_len is None
                else jnp.asarray(prompt_len, jnp.int32))
        rb = (jnp.full((b,), max_new, jnp.int32) if row_budget is None
              else jnp.asarray(row_budget, jnp.int32))
        toks, pids, _ = self._generate(self.params, self._prequant,
                                       jnp.asarray(schedule),
                                       logits, pos0, caches, rb)
        # repro: allow(host-sync) the call's single decode sync, at the end
        toks = np.asarray(toks)
        # repro: allow(host-sync) profile trace decode, same single sync point
        trace = [self.engine.profile_names[p] for p in np.asarray(pids)]
        return {"tokens": [row.tolist() for row in toks],
                "profile_trace": trace}

    def generate_stepwise(self, prompts: np.ndarray, max_new: int,  # repro: allow(host-sync) seed oracle syncs per token by design
                          accuracy_critical: bool = False) -> dict:
        """Seed per-token host loop (one dispatch + host argmax per token).
        Kept as the fused path's oracle and the benchmark baseline."""
        b, s = prompts.shape
        pid = self._select_profile(accuracy_critical)
        logits, caches = self._prefill(self.params, pid,
                                       {"tokens": jnp.asarray(prompts)})
        if self.manager is not None:
            self.manager.account(pid, b)    # prefill billed like an inference
        out = [int(np.argmax(np.asarray(logits)[i])) for i in range(b)]
        tokens = [list(row) for row in prompts.tolist()]
        trace = [self.engine.profile_names[pid]]
        next_tok = jnp.asarray(np.asarray(out, np.int32)[:, None])
        for step in range(max_new - 1):
            pid = self._select_profile(accuracy_critical)
            pos = jnp.full((b,), s + step, jnp.int32)
            logits, caches = self._decode(self.params, pid, next_tok, pos, caches)
            if self.manager is not None:
                self.manager.account(pid, b)
            nxt = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
            for i in range(b):
                tokens[i].append(int(next_tok[i, 0]))
            next_tok = jnp.asarray(nxt[:, None])
            trace.append(self.engine.profile_names[pid])
        for i in range(b):
            tokens[i].append(int(next_tok[i, 0]))
        return {"tokens": [t[s:] for t in tokens], "profile_trace": trace}

    def serve(self, requests: Sequence[Request]) -> list[dict]:
        """Request batching: group by padded length up to ``max_batch``; one
        fused *ragged* generate call per group. Mixed-length requests are
        left-padded and ride in with per-row ``prompt_len`` (per-row rope
        offsets, pad-key masks, logical-position KV handoff, per-row decode
        start) so every row's tokens match a solo run. The batch is padded to
        ``max_batch`` (pad rows: budget 0, ``prompt_len`` 0 → fully masked) so
        every equal-length group reuses one compiled executable. MoE group
        sizes are bucketed to powers of two instead — pad rows are dropped
        from the capacity dispatch (``token_valid``), and the compile count
        stays logarithmic in ``max_batch`` rather than one executable per
        distinct group size. Each result's ``profile_trace`` is sliced to its
        own ``max_new``; the ledger bills per step only the rows still live."""
        results: list[dict] = [None] * len(requests)  # type: ignore
        order = sorted(range(len(requests)), key=lambda i: len(requests[i].tokens))
        for i0 in range(0, len(order), self.scfg.max_batch):
            group = order[i0:i0 + self.scfg.max_batch]
            maxlen = max(len(requests[i].tokens) for i in group)
            rows = (_next_pow2(max(2, len(group))) if self.cfg.family == "moe"
                    else self.scfg.max_batch)
            prompts = np.zeros((rows, maxlen), np.int32)
            budget = np.zeros((rows,), np.int32)
            plen = np.zeros((rows,), np.int32)       # pad rows: fully masked
            crit = np.zeros((rows,), bool)
            for row, i in enumerate(group):
                t = requests[i].tokens
                prompts[row, maxlen - len(t):] = t   # left-pad
                budget[row] = requests[i].max_new
                plen[row] = len(t)
                crit[row] = requests[i].accuracy_critical
            max_new = max(requests[i].max_new for i in group)
            out = self.generate(prompts, max_new, row_budget=budget,
                                prompt_len=plen, row_critical=crit)
            for row, i in enumerate(group):
                mn = requests[i].max_new
                results[i] = {"tokens": out["tokens"][row][:mn],
                              "profile_trace": out["profile_trace"][:mn]}
        return results

"""Adaptive serving engine: batched prefill + decode under the Profile Manager.

The FPGA paper's runtime (Fig. 4 left) = Adaptive Inference Engine + Profile
Manager. Here the engine is a pair of jitted functions closed over the merged
profile family (profile_id is a traced scalar → switching never recompiles),
and the manager picks the profile per decode step from the energy budget.

KV cache precision is a deployment knob (``kv_bits``: 16 = bf16 baseline,
8 = int8 — the beyond-paper memory-roofline win; the Pallas
``qkv_attention`` kernel is the TPU path for the int8 layout).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import AdaptiveEngine
from repro.core.manager import ProfileManager, ProfileStats
from repro.models import transformer as T

__all__ = ["ServingConfig", "AdaptiveServer", "Request"]


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    slots: int = 4096           # KV slots (≥ prompt + generation budget)
    kv_bits: int = 16           # 16 (bf16) | 8 (int8 cache)
    max_batch: int = 8
    greedy: bool = True


@dataclasses.dataclass
class Request:
    tokens: np.ndarray          # [S] prompt
    max_new: int = 32
    accuracy_critical: bool = False


class AdaptiveServer:
    def __init__(self, cfg: T.ModelConfig, params, engine: AdaptiveEngine,
                 serving: ServingConfig,
                 manager: Optional[ProfileManager] = None):
        self.cfg = cfg
        self.params = params
        self.engine = engine
        self.scfg = serving
        self.manager = manager
        table = engine.table

        def prefill_fn(params, profile_id, batch):
            bits = jnp.asarray(table)[profile_id]
            return T.prefill(params, cfg, bits, batch, serving.slots,
                             kv_bits=serving.kv_bits)

        def decode_fn(params, profile_id, tokens, pos, caches):
            bits = jnp.asarray(table)[profile_id]
            return T.decode_step(params, cfg, bits, tokens, pos, caches)

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)

    def _select_profile(self, critical: bool) -> int:
        if self.manager is None:
            return 0
        return self.manager.select(accuracy_critical=critical)

    def generate(self, prompts: np.ndarray, max_new: int,
                 accuracy_critical: bool = False) -> dict:
        """Batched greedy generation. prompts ``[B, S]`` int32 (same length —
        the request queue pads). Returns tokens + the per-step profile trace."""
        b, s = prompts.shape
        pid = self._select_profile(accuracy_critical)
        logits, caches = self._prefill(self.params, pid,
                                       {"tokens": jnp.asarray(prompts)})
        if self.manager is not None:
            self.manager.account(pid, b)    # prefill billed like an inference
        out = [int(np.argmax(np.asarray(logits)[i])) for i in range(b)]
        tokens = [list(row) for row in prompts.tolist()]
        trace = [self.engine.profile_names[pid]]
        next_tok = jnp.asarray(np.asarray(out, np.int32)[:, None])
        for step in range(max_new - 1):
            pid = self._select_profile(accuracy_critical)
            pos = jnp.full((b,), s + step, jnp.int32)
            logits, caches = self._decode(self.params, pid, next_tok, pos, caches)
            if self.manager is not None:
                self.manager.account(pid, b)
            nxt = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
            for i in range(b):
                tokens[i].append(int(next_tok[i, 0]))
            next_tok = jnp.asarray(nxt[:, None])
            trace.append(self.engine.profile_names[pid])
        for i in range(b):
            tokens[i].append(int(next_tok[i, 0]))
        return {"tokens": [t[s:] for t in tokens], "profile_trace": trace}

    def serve(self, requests: Sequence[Request]) -> list[dict]:
        """Naive request batching: group by padded length up to max_batch."""
        results: list[dict] = [None] * len(requests)  # type: ignore
        order = sorted(range(len(requests)), key=lambda i: len(requests[i].tokens))
        for i0 in range(0, len(order), self.scfg.max_batch):
            group = order[i0:i0 + self.scfg.max_batch]
            maxlen = max(len(requests[i].tokens) for i in group)
            prompts = np.zeros((len(group), maxlen), np.int32)
            for row, i in enumerate(group):
                t = requests[i].tokens
                prompts[row, maxlen - len(t):] = t   # left-pad
            max_new = max(requests[i].max_new for i in group)
            critical = any(requests[i].accuracy_critical for i in group)
            out = self.generate(prompts, max_new, accuracy_critical=critical)
            for row, i in enumerate(group):
                results[i] = {"tokens": out["tokens"][row][:requests[i].max_new],
                              "profile_trace": out["profile_trace"]}
        return results

"""Adaptive serving engine: batched prefill + fused on-device decode loop.

The FPGA paper's runtime (Fig. 4 left) = Adaptive Inference Engine + Profile
Manager. Here the engine is a pair of jitted functions closed over the merged
profile family (profile_id is a traced scalar → switching never recompiles),
and the manager picks the profile per decode step from the energy budget.

**Scan/donation design.** Decode runs as a single jitted ``jax.lax.scan`` over
the generation length (:func:`repro.models.transformer.decode_many`):

* one dispatch per ``generate`` call — greedy argmax sampling, KV/SSM cache
  updates, and profile switching all stay on device; the only host sync is
  one ``np.asarray`` of the final ``[B, steps]`` token block (the seed
  engine synced + re-dispatched per token);
* the KV caches are threaded through the scan carry and **donated** at the
  ``jit`` boundary (``donate_argnums``), so XLA updates the cache buffers in
  place instead of copying them every step;
* profile adaptivity survives fusion: the :class:`ProfileManager` budget
  policy is deterministic given its energy ledger, so the per-step profile
  ids are precomputed as an ``int32[steps]`` schedule
  (``ProfileManager.plan_schedule``) and fed to the scan as *data* — the
  merged engine stays branch-free and a new schedule never retraces. The
  realized per-step trace comes back from the device for accounting.

``generate_stepwise`` keeps the seed per-token host loop as the benchmark
baseline (``benchmarks/serving_bench.py`` measures the tokens/sec win).

KV cache precision is a deployment knob (``kv_bits``: 16 = bf16 baseline,
8 = int8 — the beyond-paper memory-roofline win; the Pallas
``qkv_attention`` kernel is the TPU path for the int8 layout, and the jnp
decode path contracts on the same int8 grid).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import AdaptiveEngine
from repro.core.manager import ProfileManager, ProfileStats
from repro.models import transformer as T

__all__ = ["ServingConfig", "AdaptiveServer", "Request"]


def _next_pow2(n: int) -> int:
    return 1 << (int(n) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    slots: int = 4096           # KV slots (≥ prompt + generation budget)
    kv_bits: int = 16           # 16 (bf16) | 8 (int8 cache)
    max_batch: int = 8
    greedy: bool = True


@dataclasses.dataclass
class Request:
    tokens: np.ndarray          # [S] prompt
    max_new: int = 32
    accuracy_critical: bool = False


class AdaptiveServer:
    def __init__(self, cfg: T.ModelConfig, params, engine: AdaptiveEngine,
                 serving: ServingConfig,
                 manager: Optional[ProfileManager] = None):
        self.cfg = cfg
        self.params = params
        self.engine = engine
        self.scfg = serving
        self.manager = manager
        table = engine.table

        def prefill_fn(params, profile_id, batch):
            bits = jnp.asarray(table)[profile_id]
            return T.prefill(params, cfg, bits, batch, serving.slots,
                             kv_bits=serving.kv_bits)

        def decode_fn(params, profile_id, tokens, pos, caches):
            bits = jnp.asarray(table)[profile_id]
            return T.decode_step(params, cfg, bits, tokens, pos, caches)

        def generate_fn(params, prequant, schedule, logits0, pos0, caches,
                        row_budget):
            return T.decode_many(params, cfg, jnp.asarray(table), schedule,
                                 logits0, pos0, caches, row_budget=row_budget,
                                 prequant=prequant)

        # params / prequant are server-lifetime constants: the continuous
        # primitives close over them so a dispatch only flattens the small
        # slot-pool carry (schedule, tok, pos, caches, remaining) instead of
        # re-processing the full parameter pytree every segment — per-call
        # python overhead is what continuous batching lives or dies by
        def segment_fn(schedule, tok, pos, caches, remaining):
            return T.decode_segment(self.params, cfg, jnp.asarray(table),
                                    schedule, tok, pos, caches, remaining,
                                    prequant=self._prequant)

        def admit_fn(profile_id, batch, slots_idx, tok, pos, caches):
            # one admission wave = one dispatch: ragged prefill of every
            # waiting request (left-padded to a shared pow2 bucket,
            # ``prompt_len`` as data) + on-device first-token argmax + scatter
            # of each prefilled row into its pool slot. Rows whose
            # ``slots_idx`` is out of range (admission-batch padding) are
            # dropped by the scatter. The WHOLE pool row is overwritten
            # (batch axis 1 under the [L, ...] layer stacking): stale
            # token_idx entries of a retired request must not survive into
            # the new request's attention window.
            bits = jnp.asarray(table)[profile_id]
            logits, rows = T.prefill(self.params, cfg, bits, batch,
                                     serving.slots, kv_bits=serving.kv_bits)
            tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            caches = jax.tree.map(
                lambda pool, row: pool.at[:, slots_idx].set(row, mode="drop"),
                caches, rows)
            return (tok0,
                    tok.at[slots_idx].set(tok0, mode="drop"),
                    pos.at[slots_idx].set(
                        jnp.asarray(batch["prompt_len"], jnp.int32),
                        mode="drop"),
                    caches)

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)                  # stepwise baseline
        # per-profile weight images, materialized once per server (params and
        # the profile table are fixed for its lifetime)
        self._prequant = jax.jit(
            lambda p: T.prequant_decode_weights(p, cfg, jnp.asarray(table))
        )(params)
        # donate the caches: the scan threads them through its carry and XLA
        # aliases input → output buffers (in-place ring-buffer writes, no
        # per-step cache copy)
        self._generate = jax.jit(generate_fn, donate_argnums=(5,))
        # continuous-batching primitives (ContinuousScheduler): jitted here so
        # every scheduler instance over this server shares the compiled
        # executables; the slot-pool state they donate lives in the scheduler
        self._segment = jax.jit(segment_fn, donate_argnums=(1, 2, 3))
        self._admit = jax.jit(admit_fn, donate_argnums=(3, 4, 5))

    def _select_profile(self, critical: bool) -> int:
        if self.manager is None:
            return 0
        return self.manager.select(accuracy_critical=critical)

    def generate(self, prompts: np.ndarray, max_new: int,
                 accuracy_critical: bool = False, *,
                 row_budget: Optional[np.ndarray] = None,
                 prompt_len: Optional[np.ndarray] = None,
                 row_critical: Optional[np.ndarray] = None,
                 account_rows: Optional[int] = None) -> dict:
        """Batched greedy generation, fused: one prefill dispatch + one decode
        dispatch. prompts ``[B, S]`` int32 (ragged requests left-padded to a
        common length). ``prompt_len [B]`` marks each row's real length: rows
        then get per-row rope offsets, pad-key masks, logical-position KV
        handoff, and per-row ``pos0 = prompt_len`` — a mixed-length batch
        generates exactly what each row would solo. ``row_budget [B]`` masks
        per-row tokens at index ≥ budget to −1 (early stop for heterogeneous
        request budgets). With a manager, per-row data (``row_budget`` /
        ``row_critical``) switches the schedule to the exact ragged ledger
        (step ``i`` bills only rows still live); otherwise ``account_rows``
        rows are billed every step. Returns tokens + the per-step profile
        trace."""
        b, s = prompts.shape
        if self.manager is None:
            schedule = np.zeros((max_new,), np.int32)
        elif row_budget is not None or row_critical is not None:
            rb_plan = (np.full((b,), max_new) if row_budget is None
                       else np.minimum(np.asarray(row_budget), max_new))
            rc = (np.full((b,), bool(accuracy_critical))
                  if row_critical is None else np.asarray(row_critical, bool))
            schedule = self.manager.plan_schedule_ragged(max_new, rb_plan, rc)
        else:
            n_account = b if account_rows is None else account_rows
            schedule = self.manager.plan_schedule(max_new, n_account,
                                                  accuracy_critical=accuracy_critical)
        batch = {"tokens": jnp.asarray(prompts)}
        if prompt_len is not None:
            batch["prompt_len"] = jnp.asarray(prompt_len, jnp.int32)
        logits, caches = self._prefill(self.params, int(schedule[0]), batch)
        pos0 = (jnp.full((b,), s, jnp.int32) if prompt_len is None
                else jnp.asarray(prompt_len, jnp.int32))
        rb = (jnp.full((b,), max_new, jnp.int32) if row_budget is None
              else jnp.asarray(row_budget, jnp.int32))
        toks, pids, _ = self._generate(self.params, self._prequant,
                                       jnp.asarray(schedule),
                                       logits, pos0, caches, rb)
        toks = np.asarray(toks)         # the call's single decode host sync
        trace = [self.engine.profile_names[p] for p in np.asarray(pids)]
        return {"tokens": [row.tolist() for row in toks],
                "profile_trace": trace}

    def generate_stepwise(self, prompts: np.ndarray, max_new: int,
                          accuracy_critical: bool = False) -> dict:
        """Seed per-token host loop (one dispatch + host argmax per token).
        Kept as the fused path's oracle and the benchmark baseline."""
        b, s = prompts.shape
        pid = self._select_profile(accuracy_critical)
        logits, caches = self._prefill(self.params, pid,
                                       {"tokens": jnp.asarray(prompts)})
        if self.manager is not None:
            self.manager.account(pid, b)    # prefill billed like an inference
        out = [int(np.argmax(np.asarray(logits)[i])) for i in range(b)]
        tokens = [list(row) for row in prompts.tolist()]
        trace = [self.engine.profile_names[pid]]
        next_tok = jnp.asarray(np.asarray(out, np.int32)[:, None])
        for step in range(max_new - 1):
            pid = self._select_profile(accuracy_critical)
            pos = jnp.full((b,), s + step, jnp.int32)
            logits, caches = self._decode(self.params, pid, next_tok, pos, caches)
            if self.manager is not None:
                self.manager.account(pid, b)
            nxt = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
            for i in range(b):
                tokens[i].append(int(next_tok[i, 0]))
            next_tok = jnp.asarray(nxt[:, None])
            trace.append(self.engine.profile_names[pid])
        for i in range(b):
            tokens[i].append(int(next_tok[i, 0]))
        return {"tokens": [t[s:] for t in tokens], "profile_trace": trace}

    def serve(self, requests: Sequence[Request]) -> list[dict]:
        """Request batching: group by padded length up to ``max_batch``; one
        fused *ragged* generate call per group. Mixed-length requests are
        left-padded and ride in with per-row ``prompt_len`` (per-row rope
        offsets, pad-key masks, logical-position KV handoff, per-row decode
        start) so every row's tokens match a solo run. The batch is padded to
        ``max_batch`` (pad rows: budget 0, ``prompt_len`` 0 → fully masked) so
        every equal-length group reuses one compiled executable. MoE group
        sizes are bucketed to powers of two instead — pad rows are dropped
        from the capacity dispatch (``token_valid``), and the compile count
        stays logarithmic in ``max_batch`` rather than one executable per
        distinct group size. Each result's ``profile_trace`` is sliced to its
        own ``max_new``; the ledger bills per step only the rows still live."""
        results: list[dict] = [None] * len(requests)  # type: ignore
        order = sorted(range(len(requests)), key=lambda i: len(requests[i].tokens))
        for i0 in range(0, len(order), self.scfg.max_batch):
            group = order[i0:i0 + self.scfg.max_batch]
            maxlen = max(len(requests[i].tokens) for i in group)
            rows = (_next_pow2(max(2, len(group))) if self.cfg.family == "moe"
                    else self.scfg.max_batch)
            prompts = np.zeros((rows, maxlen), np.int32)
            budget = np.zeros((rows,), np.int32)
            plen = np.zeros((rows,), np.int32)       # pad rows: fully masked
            crit = np.zeros((rows,), bool)
            for row, i in enumerate(group):
                t = requests[i].tokens
                prompts[row, maxlen - len(t):] = t   # left-pad
                budget[row] = requests[i].max_new
                plen[row] = len(t)
                crit[row] = requests[i].accuracy_critical
            max_new = max(requests[i].max_new for i in group)
            out = self.generate(prompts, max_new, row_budget=budget,
                                prompt_len=plen, row_critical=crit)
            for row, i in enumerate(group):
                mn = requests[i].max_new
                results[i] = {"tokens": out["tokens"][row][:mn],
                              "profile_trace": out["profile_trace"][:mn]}
        return results

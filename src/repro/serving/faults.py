"""Deterministic fault injection + stall watchdog for the serving runtime.

The paper's adaptive loop trades accuracy for energy — which means the
runtime deliberately operates close to the numerical edge (int-KV storage,
4-bit weight variants). A production engine must therefore treat non-finite
outputs, allocator droughts and stalled dispatches as *expected* events with
rehearsed recoveries, not as crashes. This module is the rehearsal
machinery, modeled on :mod:`repro.train.loop`'s injected-failure discipline
(``TrainConfig.fail_at_step`` + the ``StragglerMonitor``): faults are
**seeded and deterministic**, so a chaos run is replayable and a CI gate can
assert exact recovery behavior.

* :class:`FaultSchedule` — decides, per well-defined scheduler hook, whether
  to (a) poison one row's logits with NaN for one decode-segment step
  (:meth:`want_nan` — keyed by ``(rid, attempt)`` so a retry at the
  escalated profile is injected independently of the first attempt),
  (b) report the block allocator dry for one admission round
  (:meth:`alloc_dry` — exercises backpressure without touching refcounts),
  or (c) stall a flush boundary (:meth:`flush_stall` — what the watchdog
  must catch). Random draws hash ``(seed, kind, key)`` through
  ``numpy``'s deterministic bit generator, so the decision for a given
  request/round is independent of call order — two runs over the same
  trace inject the same faults even if wall-clock timing reorders the
  scheduler's queries.
* :class:`Watchdog` — wall-clock no-progress detector for the segment/flush
  loop (the serving twin of the training ``StragglerMonitor``): any step
  exceeding ``limit_s`` is flagged and counted. Detection only — a stalled
  device dispatch cannot be killed from the host, but surfacing it turns a
  silent hang into an observable, alertable event.

Detection of injected (or genuine) non-finite logits is NOT here: it rides
the decode segment itself (:func:`repro.models.transformer.decode_segment`
folds a per-row finite-check into the scan carry, so it costs no extra
dispatch) and the scheduler's quarantine machinery reacts to the flag.
Speculative decode widens the same check, not the machinery: the verify
pass's logits span the whole ``W``-position draft window, and
:func:`repro.models.transformer.decode_segment_spec` finite-checks the
*full* ``[B, W, vocab]`` verify tensor per window — a NaN anywhere in the
window (even at a position whose draft would have been rejected) marks
the row not-ok, and the ordinary quarantine/escalated-retry ladder takes
over — the attempt's tokens (speculatively delivered or not) are
discarded wholesale and the retry restarts from the prompt, so recovery
stays token-identical to a clean accuracy-critical run.
``want_nan`` needs no window awareness: injection still keys on
``(rid, attempt)`` and poisons step 0 of the targeted attempt's first
segment, which under speculation is the first verify window.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["FaultSchedule", "Watchdog"]

# namespaces for the stable per-decision hash draws, so the NaN / allocator /
# stall streams are independent even under one seed
_NAN, _ALLOC, _STALL = 1, 2, 3


def _draw(seed: int, kind: int, key: int) -> float:
    """Uniform in [0, 1) determined solely by ``(seed, kind, key)`` — NOT by
    how many draws happened before it, so injection decisions are stable
    under scheduler-timing differences between runs."""
    return float(np.random.default_rng([int(seed), kind, int(key)]).random())


@dataclasses.dataclass
class FaultSchedule:
    """Seeded, deterministic fault plan consulted by the scheduler.

    Explicit targets (exact tests, CI gates):

    * ``nan_at`` — ``{rid: (attempt, ...)}``: poison that request's logits
      during the named attempts (attempt 0 = first admission, 1 = first
      quarantine retry, ...). ``nan_at={3: (0,)}`` is the canonical
      "recoverable fault": attempt 0 breaks, the escalated retry is clean.
    * ``alloc_at`` — admission-round indices where the allocator reports dry.
    * ``stall_at`` — flush indices to stall by ``stall_s`` seconds.

    Random rates (chaos benches): ``p_nan`` per ``(rid, attempt)``,
    ``p_alloc`` per admission round, ``p_stall`` per flush — all hash-drawn
    from ``seed`` (see module docstring), with ``max_nan`` capping the total
    number of random NaN injections so a chaos trace cannot degenerate into
    all-FAILED.
    """

    seed: int = 0
    p_nan: float = 0.0
    p_alloc: float = 0.0
    p_stall: float = 0.0
    stall_s: float = 0.05
    nan_at: dict = dataclasses.field(default_factory=dict)
    alloc_at: tuple = ()
    stall_at: tuple = ()
    max_nan: Optional[int] = None
    # injection counters (chaos-bench reporting)
    injected_nan: int = 0
    injected_alloc: int = 0
    injected_stall: int = 0
    _fired: set = dataclasses.field(default_factory=set)

    def want_nan(self, rid: int, attempt: int) -> bool:
        """True exactly once per targeted ``(rid, attempt)`` — the scheduler
        asks before every decode segment, and the first segment of a
        targeted attempt takes the hit (step 0 of that segment)."""
        key = (int(rid), int(attempt))
        if key in self._fired:
            return False
        want = attempt in tuple(self.nan_at.get(int(rid), ()))
        if not want and self.p_nan > 0.0:
            if self.max_nan is not None and self.injected_nan >= self.max_nan:
                want = False
            else:
                # fold attempt into the key so a retry draws independently
                want = _draw(self.seed, _NAN, rid * 131 + attempt) < self.p_nan
        if want:
            self._fired.add(key)
            self.injected_nan += 1
        return want

    def alloc_dry(self, admission_round: int) -> bool:
        """Simulated allocator exhaustion for this admission round: the
        scheduler skips the round entirely (queue backpressure — the same
        observable behavior as a genuinely dry pool, with zero refcount
        involvement, so the allocator invariants stay pristine)."""
        dry = admission_round in tuple(self.alloc_at) or (
            self.p_alloc > 0.0
            and _draw(self.seed, _ALLOC, admission_round) < self.p_alloc)
        if dry:
            self.injected_alloc += 1
        return dry

    def flush_stall(self, flush_idx: int) -> float:
        """Seconds to stall the ``flush_idx``-th materializing flush (0.0 =
        no stall) — the injected no-progress condition the watchdog must
        flag."""
        stall = flush_idx in tuple(self.stall_at) or (
            self.p_stall > 0.0
            and _draw(self.seed, _STALL, flush_idx) < self.p_stall)
        if stall:
            self.injected_stall += 1
            return float(self.stall_s)
        return 0.0


@dataclasses.dataclass
class Watchdog:
    """Wall-clock no-progress detector for the scheduler's step loop.

    ``limit_s`` is the per-step budget: any admit→segment→flush round
    exceeding it is recorded in ``flagged`` (label, seconds) and counted in
    ``stalls``. The training-side ``StragglerMonitor`` flags statistical
    outliers across workers; serving has a hard latency contract instead,
    so a fixed threshold is the right detector here.
    """

    limit_s: float
    stalls: int = 0
    flagged: list = dataclasses.field(default_factory=list)

    def record(self, label: str, dt: float) -> bool:
        """Feed one step's wall time; True (and flagged) when over budget."""
        if dt > self.limit_s:
            self.stalls += 1
            self.flagged.append((label, float(dt)))
            return True
        return False

"""Crash-consistent serving: request journal, live-state checkpoints, recovery.

An edge deployment loses power and gets watchdog-reset far more often than a
datacenter host — a serving runtime that forgets every in-flight request on
process death is not deployment-shaped. This module makes
:class:`~repro.serving.scheduler.ContinuousScheduler` crash-consistent with
three pieces (docs/serving.md §Durability; invariant 12: *no accepted
request is lost by restart*):

**Write-ahead request journal** (:class:`RequestJournal`) — an append-only
JSONL file, one crc32-prefixed record per line. Lifecycle edges that change
what the process OWES its clients are fsync'd before the scheduler's own
state moves on: ``submit`` (the full request payload — durable before the
rid is observable), ``cancel``, ``final`` (the full result, so an
undelivered result survives a crash and re-delivers), and ``deliver``
(rids handed to the caller — replay drops exactly those, exactly-once).
``admit`` / ``flush`` / ``ckpt`` / ``drain`` markers are unsynced breadcrumbs
(progress telemetry and crash-point enumeration for the fuzzing harness).
A torn tail — the half-written last line of a mid-``write`` crash — is
detected by its checksum and truncated on reopen; every complete record
before it is intact.

**Live-state checkpoints** (:meth:`Durability.checkpoint`) — a consistency
cut at a flush boundary: force ``_flush(0)`` (no token in flight), then
capture every live row as the SAME :class:`~repro.serving.paged.RowSnapshot`
the preemption SUSPEND edge takes (f32 KV masters + int-KV scale preimages
and exact scale rows via ``_snapshot_row``), plus mid-admission chunk rows'
accumulated
masters, master-backed registry entries, policy-queue order (with aging
state), per-request ledgers, the ProfileManager energy ledger, and every
robustness counter — written through :mod:`repro.checkpoint.manager`'s
atomic rename-commit with a per-leaf crc32 manifest. Physical block ids are
deliberately NOT checkpointed: they are process-local names for pool
storage that dies with the device buffers; recovery re-allocates and the
logical state (masters + positions) is what restores bit-exactly.

**Restart recovery** (:func:`recover`) — restore the newest committed
checkpoint (``strict=False``), replay the journal suffix past the
checkpoint's recorded byte position, then resume: checkpointed live rows
become suspended snapshots that re-admit through the server's
``_admit_restore`` continuation executable — restore-from-disk IS
restore-from-preemption, pure data movement, so recovered streams are
token-identical to an uninterrupted run — and chunk rows replay their
processed span into fresh blocks and continue chunking. A row whose
snapshot leaves failed their checksum degrades to **re-prefill-from-prompt**
(the PR-6 quarantine discipline: tokens discarded, request re-queued at its
class front, attempts/status preserved) — a corrupted checkpoint costs
recompute, never a lost or duplicated request. Recovery ends by writing a
fresh checkpoint, so a second crash during recovery replays the same
prefix idempotently.
"""
from __future__ import annotations

import json
import os
import time
import zlib
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .engine import Request, RequestStatus
from .paged import RowSnapshot, prefix_keys
from ..checkpoint.manager import CheckpointManager

__all__ = ["RequestJournal", "Durability", "recover"]


class RequestJournal:
    """Append-only, checksummed, replayable request journal.

    Line format: ``"%08x %s\\n" % (crc32(payload), payload)`` with a
    compact-JSON payload — human-greppable, machine-verifiable. Appends
    are buffered-write + flush; ``sync=True`` adds an ``fsync`` (the
    write-ahead edges). Opening an existing journal truncates a torn tail
    (first record whose checksum or framing fails, and everything after
    it — by construction only a crash mid-append produces one).
    """

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        if os.path.exists(path):
            recs = self.scan(path)
            valid_end = recs[-1][0] if recs else 0
            if os.path.getsize(path) != valid_end:
                with open(path, "r+b") as f:     # torn tail from a crash
                    f.truncate(valid_end)
        self._f = open(path, "a", encoding="utf-8")

    def append(self, rec: dict, sync: bool = False) -> None:
        payload = json.dumps(rec, separators=(",", ":"), sort_keys=True)
        crc = zlib.crc32(payload.encode("utf-8")) & 0xFFFFFFFF
        self._f.write(f"{crc:08x} {payload}\n")
        self._f.flush()
        if sync:
            os.fsync(self._f.fileno())

    def position(self) -> int:
        """Current byte offset (every record so far ends before it)."""
        self._f.flush()
        return self._f.tell()

    def close(self) -> None:
        self._f.close()

    @staticmethod
    def scan(path: str) -> list[tuple[int, dict]]:
        """``(end_offset, record)`` for every valid record, stopping at the
        first torn/corrupt line (crash-consistent prefix)."""
        out: list[tuple[int, dict]] = []
        if not os.path.exists(path):
            return out
        off = 0
        with open(path, "rb") as f:
            for line in f:
                if not line.endswith(b"\n"):
                    break                        # torn tail
                try:
                    head, payload = line[:-1].split(b" ", 1)
                    if int(head, 16) != zlib.crc32(payload) & 0xFFFFFFFF:
                        break
                    rec = json.loads(payload)
                except ValueError:
                    break
                off += len(line)
                out.append((off, rec))
        return out


class Durability:
    """The scheduler's durability layer: journal hooks + checkpoint cadence.

    Attaching (construction) sets ``sched.durable = self``; the scheduler
    then calls the ``on_*`` hooks at every lifecycle edge. ``journal_dir``
    holds both the journal (``journal.jsonl``) and the checkpoint store
    (``checkpoints/``). ``checkpoint_every=N`` writes a live-state
    checkpoint every N scheduler rounds (0 = only explicit
    :meth:`checkpoint` calls — the journal alone already guarantees no
    request is lost, a checkpoint only bounds recovery recompute).
    """

    def __init__(self, sched, journal_dir: str, checkpoint_every: int = 0,
                 keep: int = 3):
        os.makedirs(journal_dir, exist_ok=True)
        self.sched = sched
        self.journal = RequestJournal(os.path.join(journal_dir,
                                                   "journal.jsonl"))
        self.manager = CheckpointManager(
            os.path.join(journal_dir, "checkpoints"), keep=keep)
        self.checkpoint_every = int(checkpoint_every)
        # checkpoint steps must grow across restarts (latest committed wins)
        self._step = (self.manager.latest_step() or 0)
        self.checkpoints_written = 0
        sched.durable = self

    # ------------------------------------------------- write-ahead (fsync'd)
    def on_submit(self, rid: int, req) -> None:
        self.journal.append(
            {"t": "submit", "rid": rid,
             "tokens": [int(x) for x in np.asarray(req.tokens)],
             "max_new": int(req.max_new),
             "accuracy_critical": bool(req.accuracy_critical),
             "priority": int(req.priority),
             "deadline_ms": req.deadline_ms}, sync=True)

    def on_cancel(self, rid: int) -> None:
        self.journal.append({"t": "cancel", "rid": rid}, sync=True)

    def on_final(self, rid: int) -> None:
        res = self.sched.results.get(rid, {})
        status = res.get("status")
        self.journal.append(
            {"t": "final", "rid": rid,
             "status": getattr(status, "value", ""),
             "reason": res.get("reason"),
             "retries": res.get("retries"),
             "tokens": [int(x) for x in res.get("tokens", [])],
             "profile_trace": list(res.get("profile_trace", []))},
            sync=True)

    def on_deliver(self, rids: list) -> None:
        self.journal.append({"t": "deliver",
                             "rids": [int(r) for r in rids]}, sync=True)

    # ------------------------------------------------ markers (best-effort)
    def on_admit(self, n: int) -> None:
        self.journal.append({"t": "admit", "n": int(n),
                             "round": self.sched._round})

    def on_flush(self) -> None:
        self.journal.append({"t": "flush", "round": self.sched._round})

    def on_drain(self) -> None:
        self.journal.append({"t": "drain", "round": self.sched._round},
                            sync=True)

    def on_step_end(self) -> None:
        if (self.checkpoint_every
                and self.sched._round % self.checkpoint_every == 0):
            self.checkpoint()

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self) -> int:
        """One consistency cut: flush, capture, atomic-commit. Returns the
        committed checkpoint step."""
        s = self.sched
        s._flush(0)                   # the cut is a flush boundary
        tree, meta = _capture(s)
        meta["journal_pos"] = self.journal.position()
        self._step += 1
        self.manager.save(self._step, tree, metadata=meta)
        self.checkpoints_written += 1
        self.journal.append({"t": "ckpt", "step": self._step,
                             "pos": meta["journal_pos"]})
        return self._step


# ---------------------------------------------------------------- capture
def _serialize_results(results: dict) -> dict:
    out = {}
    for rid, res in results.items():
        r = {"tokens": [int(x) for x in res.get("tokens", [])],
             "profile_trace": list(res.get("profile_trace", []))}
        if "status" in res:
            r["status"] = res["status"].value
        if "reason" in res:
            r["reason"] = res["reason"]
        if "retries" in res:
            r["retries"] = int(res["retries"])
        out[str(rid)] = r
    return out


def _capture(s) -> tuple[dict, dict]:
    """Capture scheduler state at a flushed cut → ``(arrays_tree, meta)``.

    Arrays (the checksummed npz leaves) hold the heavy row state — KV
    masters and raw amaxes; ``meta`` (JSON) holds all host bookkeeping.
    """
    now = s.clock()
    skip = set(s._nf_rows)            # quarantine re-prefills: no KV needed
    reap = {}
    for slot, status in s._to_reap.items():
        rid = (s._chunk_state[slot]["rid"]
               if s.paged and slot in s._chunk_state else s.slot_req[slot])
        if rid is not None:
            reap[str(rid)] = status.value
            skip.add(rid)
    rows_meta, rows_arr = {}, {}
    for slot in range(s.n_slots):
        rid = s.slot_req[slot]
        if rid is None or rid in skip:
            continue
        snap = s._snapshot_row(slot)
        rows_meta[str(rid)] = {"n_done": snap.n_done,
                               "last_tok": snap.last_tok,
                               "pid": snap.pid, "kind": "live"}
        arr = {"mk": snap.master_k, "mv": snap.master_v}
        if snap.k_amax is not None:
            arr["ka"], arr["va"] = snap.k_amax, snap.v_amax
            arr["ksc"], arr["vsc"] = snap.k_scale, snap.v_scale
        rows_arr[str(rid)] = arr
    for rid, snap in s._suspended.items():
        if rid in skip:
            continue
        rows_meta[str(rid)] = {"n_done": snap.n_done,
                               "last_tok": snap.last_tok,
                               "pid": snap.pid, "kind": "suspended"}
        arr = {"mk": snap.master_k, "mv": snap.master_v}
        if snap.k_amax is not None:
            arr["ka"], arr["va"] = snap.k_amax, snap.v_amax
            arr["ksc"], arr["vsc"] = snap.k_scale, snap.v_scale
        rows_arr[str(rid)] = arr
    chunks_meta, chunks_arr = {}, {}
    if s.paged:
        from repro.models import transformer as T
        for slot, st in s._chunk_state.items():
            rid = st["rid"]
            if rid in skip:
                continue
            if st["mk"] is not None:          # masters mode: accumulated
                mk, mv = st["mk"], st["mv"]
                ka, va = st["ka"], st["va"]
            else:                             # kv16-plain: pool IS master
                mk, mv = T.paged_row_masters(s._caches["kv"], slot,
                                             st["map"], st["done"])
                ka = va = None
            chunks_meta[str(rid)] = {"done": int(st["done"]),
                                     "pid": int(st["pid"])}
            arr = {"mk": mk, "mv": mv}
            if ka is not None:
                arr["ka"], arr["va"] = ka, va
            chunks_arr[str(rid)] = arr
    reg_meta, reg_arr = [], {}
    if s.paged and s.registry is not None:
        for key, e in s.registry._entries.items():
            if e.master_k is None:
                continue          # pool-only entry: dies with the process
            hx = key.hex()
            reg_meta.append({"key": hx, "n_tokens": int(e.n_tokens)})
            reg_arr[hx] = {"mk": e.master_k, "mv": e.master_v}
            if e.k_amax is not None:
                reg_arr[hx]["ka"], reg_arr[hx]["va"] = e.k_amax, e.v_amax
    mgr = s.srv.manager
    meta = {
        "round": s._round, "n": s._n,
        "seg_dt": s._seg_dt, "flush_idx": s._flush_idx,
        "reqs": {str(rid): {
            "tokens": [int(x) for x in np.asarray(r.tokens)],
            "max_new": int(r.max_new),
            "accuracy_critical": bool(r.accuracy_critical),
            "priority": int(r.priority),
            "deadline_ms": r.deadline_ms,
            "deadline_left": (None if s._deadline.get(rid) is None
                              else s._deadline[rid] - now),
        } for rid, r in s._reqs.items()},
        "results": _serialize_results(s.results),
        "done": [int(r) for r in s._done],
        "attempts": {str(r): int(a) for r, a in s._attempts.items()},
        "q_elapsed": {str(r): now - t0 for r, t0 in s._q_t0.items()},
        "quarantine": [[int(rdy), int(rid)] for rdy, rid in s._quarantine_q],
        "nf_rows": [int(r) for r in s._nf_rows],
        "to_reap": reap,
        "queues": s.policy.queue_state(),
        "rows": rows_meta, "chunks": chunks_meta, "registry": reg_meta,
        "manager": (None if mgr is None
                    else {"spent_j": float(mgr.spent_j),
                          "saver": bool(mgr._saver)}),
        "counters": {k: int(getattr(s, k)) for k in (
            "preemptions", "resumes", "cancelled", "expired", "shed_count",
            "failed", "recovered", "faults_detected",
            "alloc_injected_rounds")},
        "recovery_latency": [float(x) for x in s.recovery_latency],
        "events": [[int(p), int(n), bool(c)] for p, n, c in s.events],
        "admission_log": [int(r) for r in s.admission_log],
        # audit breadcrumbs only — physical ids are process-local
        "allocator": (None if not s.paged else
                      {"free": s.allocator.free_blocks,
                       "lru": s.allocator.lru_blocks,
                       "used": s.allocator.used_blocks}),
    }
    tree = {}
    if rows_arr:
        tree["rows"] = rows_arr
    if chunks_arr:
        tree["chunks"] = chunks_arr
    if reg_arr:
        tree["registry"] = reg_arr
    return tree, meta


# ----------------------------------------------------------------- recovery
def _corrupt_groups(corrupt_keys) -> set:
    """``("rows", "7")``-style prefixes of corrupt leaves: the fallback
    unit is a whole row/entry (one bad leaf poisons its group)."""
    return {tuple(k.split("/")[:2]) for k in corrupt_keys}


def _refill(s, rid: int, kind: str, info: dict) -> None:
    """Corruption fallback: re-prefill ``rid`` from its prompt (the PR-6
    quarantine discipline — tokens discarded, request re-queued at its
    class front, attempts and terminal-status semantics preserved)."""
    s.results[rid] = {"tokens": [], "profile_trace": []}
    s._q_t0.setdefault(rid, s.clock())
    if kind != "suspended":     # suspended rids already sit in the queue
        s.policy.push_front(rid, s._reqs[rid])
    info["refilled"].append(rid)


def _apply_checkpoint(s, tree, meta, pending: dict, info: dict) -> None:
    md = meta["metadata"]
    bad = _corrupt_groups(meta.get("corrupt_keys", []))
    now = s.clock()
    s._round = int(md["round"])
    s._n = int(md["n"])
    s._seg_dt = md["seg_dt"]
    s._flush_idx = int(md["flush_idx"])
    for rid_s, r in md["reqs"].items():
        rid = int(rid_s)
        s._reqs[rid] = Request(
            tokens=np.asarray(r["tokens"], np.int32),
            max_new=r["max_new"],
            accuracy_critical=r["accuracy_critical"],
            priority=r["priority"], deadline_ms=r["deadline_ms"])
        if r["deadline_left"] is not None:
            # the SLO clock does not tick while the process is down: the
            # remaining budget at the cut re-arms from recovery time
            s._deadline[rid] = now + r["deadline_left"]
        if s.paged and s.registry is not None:
            s._prefix_keys[rid] = prefix_keys(
                np.asarray(r["tokens"], np.int32), s.block_size)
    for rid_s, res in md["results"].items():
        r = {"tokens": list(res["tokens"]),
             "profile_trace": list(res["profile_trace"])}
        if "status" in res:
            r["status"] = RequestStatus(res["status"])
        if "reason" in res:
            r["reason"] = res["reason"]
        if "retries" in res:
            r["retries"] = res["retries"]
        s.results[int(rid_s)] = r
    s._done = [int(r) for r in md["done"]]
    s._attempts = {int(r): a for r, a in md["attempts"].items()}
    s._q_t0 = {int(r): now - el for r, el in md["q_elapsed"].items()}
    s._quarantine_q = [(rdy, rid) for rdy, rid in md["quarantine"]]
    s._nf_rows = [int(r) for r in md["nf_rows"]]
    s.policy.restore_queue_state(md["queues"])
    mgr = s.srv.manager
    if mgr is not None and md["manager"] is not None:
        mgr.spent_j = md["manager"]["spent_j"]
        mgr._saver = md["manager"]["saver"]
    for k, v in md["counters"].items():
        setattr(s, k, v)
    s.recovery_latency = list(md["recovery_latency"])
    s.events = [(p, n, c) for p, n, c in md["events"]]
    s.admission_log = list(md["admission_log"])
    # cancel/expire marks pending at the cut: their tokens are flushed
    # (the cut IS a flush boundary) — finalize now, blocks never existed
    for rid_s, status in md["to_reap"].items():
        s._finalize(int(rid_s), RequestStatus(status))
    for rid_s, rm in md["rows"].items():
        rid = int(rid_s)
        arr = tree.get("rows", {}).get(rid_s, {})
        int_kv = s.srv.scfg.kv_bits in (4, 8)
        if (("rows", rid_s) in bad or "mk" not in arr or "mv" not in arr
                or (int_kv and ("ka" not in arr or "ksc" not in arr))):
            _refill(s, rid, rm["kind"], info)
            continue
        s._suspended[rid] = RowSnapshot(
            rid=rid, n_done=int(rm["n_done"]),
            last_tok=int(rm["last_tok"]), pid=int(rm["pid"]),
            master_k=jnp.asarray(arr["mk"]), master_v=jnp.asarray(arr["mv"]),
            k_amax=(jnp.asarray(arr["ka"]) if "ka" in arr else None),
            v_amax=(jnp.asarray(arr["va"]) if "va" in arr else None),
            k_scale=(jnp.asarray(arr["ksc"]) if "ksc" in arr else None),
            v_scale=(jnp.asarray(arr["vsc"]) if "vsc" in arr else None))
        if rm["kind"] == "live":
            # a live row was NOT queued at the cut (suspended ones were,
            # by evict_row); it resumes through the normal admission path
            s.policy.push_front(rid, s._reqs[rid])
        info["resumed_rows"] += 1
    for rid_s, cm in md["chunks"].items():
        rid = int(rid_s)
        arr = tree.get("chunks", {}).get(rid_s, {})
        int_kv = s.srv.scfg.kv_bits in (4, 8)
        if (("chunks", rid_s) in bad or "mk" not in arr
                or (int_kv and "ka" not in arr)):
            _refill(s, rid, "chunk", info)
            continue
        pending[rid] = {"done": int(cm["done"]), "pid": int(cm["pid"]),
                        "mk": jnp.asarray(arr["mk"]),
                        "mv": jnp.asarray(arr["mv"]),
                        "ka": (jnp.asarray(arr["ka"])
                               if "ka" in arr else None),
                        "va": (jnp.asarray(arr["va"])
                               if "va" in arr else None)}
    if s.paged and s.registry is not None:
        for ent in md["registry"]:
            hx = ent["key"]
            if ("registry", hx) in bad:
                continue              # a registry entry is only a cache
            arr = tree.get("registry", {}).get(hx, {})
            if "mk" not in arr or "mv" not in arr:
                continue
            # masters-only re-registration: the entry's old pool blocks
            # died with the process; continuations replay from masters
            s.registry.register(
                bytes.fromhex(hx), ent["n_tokens"], None,
                jnp.asarray(arr["mk"]), jnp.asarray(arr["mv"]),
                (jnp.asarray(arr["ka"]) if "ka" in arr else None),
                (jnp.asarray(arr["va"]) if "va" in arr else None))


def _drop_everywhere(s, rid: int, pending: dict) -> None:
    """Remove a rid from every pre-admission structure (a replayed
    terminal record supersedes its checkpointed live/queued state)."""
    s.policy.remove(rid)
    s._suspended.pop(rid, None)
    pending.pop(rid, None)
    if rid in s._nf_rows:
        s._nf_rows.remove(rid)
    s._quarantine_q = [(rdy, r) for rdy, r in s._quarantine_q if r != rid]


def _replay_journal(s, path: str, pos: int, pending: dict,
                    info: dict) -> None:
    delivered: set = set()
    for off, rec in RequestJournal.scan(path):
        if off <= pos:
            continue
        t = rec["t"]
        if t == "submit":
            req = Request(tokens=np.asarray(rec["tokens"], np.int32),
                          max_new=rec["max_new"],
                          accuracy_critical=rec["accuracy_critical"],
                          priority=rec["priority"],
                          deadline_ms=rec["deadline_ms"])
            # admission control already ran pre-crash: its outcome is in
            # the journal (a shed request has a `final` record), so the
            # replayed submit must not re-decide it
            shed, s.shed = s.shed, None
            try:
                got = s.submit(req)
            finally:
                s.shed = shed
            assert got == rec["rid"], "journal replay rid drift"
            info["replayed"] += 1
        elif t == "cancel":
            rid = rec["rid"]
            if not s.cancel(rid) and rid in pending:
                pending.pop(rid)
                s._finalize(rid, RequestStatus.CANCELLED)
        elif t == "final":
            rid = rec["rid"]
            res = {"tokens": list(rec["tokens"]),
                   "profile_trace": list(rec["profile_trace"])}
            status = RequestStatus(rec["status"])
            res["status"] = status
            if rec.get("reason") is not None:
                res["reason"] = rec["reason"]
            if rec.get("retries") is not None:
                res["retries"] = rec["retries"]
            already = ("status" in s.results.get(rid, {})
                       and rid in s._done)
            s.results[rid] = res        # the journal's result is final
            if not already:
                _drop_everywhere(s, rid, pending)
                s._done.append(rid)
                if status is RequestStatus.CANCELLED:
                    s.cancelled += 1
                elif status is RequestStatus.EXPIRED:
                    s.expired += 1
                elif status is RequestStatus.SHED:
                    s.shed_count += 1
                elif status is RequestStatus.FAILED:
                    s.failed += 1
        elif t == "deliver":
            delivered.update(rec["rids"])
    for rid in delivered:               # exactly-once: caller owns these
        if rid in s._done:
            s._done.remove(rid)
        s.results.pop(rid, None)
        s._reqs.pop(rid, None)
        s._deadline.pop(rid, None)
        s._attempts.pop(rid, None)
        s._q_t0.pop(rid, None)
        if s.paged and s.registry is not None:
            s._prefix_keys.pop(rid, None)


def _restore_chunks(s, pending: dict, info: dict) -> None:
    """Re-materialize surviving mid-admission chunk rows: one master-replay
    wave per pinned profile rewrites each row's processed span
    (positions ``0..done-1``) into freshly allocated blocks — the same
    pure data movement as a resume, no token produced, nothing billed —
    then chunking continues from ``done`` at the next round."""
    if not pending:
        return
    from .scheduler import _next_pow2
    bs = s.block_size
    by_pid: dict[int, list] = {}
    for rid, st in pending.items():
        by_pid.setdefault(st["pid"], []).append((rid, st))
    for pid, items in by_pid.items():
        free = [sl for sl in range(s.n_slots)
                if s.slot_req[sl] is None and sl not in s._chunk_state]
        rows = []
        for rid, st in items:
            req = s._reqs[rid]
            blocks = s.allocator.alloc(
                s._blocks_needed(len(req.tokens), req.max_new))
            assert blocks is not None, "recovery pool smaller than original"
            rows.append((rid, free.pop(0), blocks, st))
        a = _next_pow2(len(rows))
        sb = _next_pow2(s.bucket_min)
        pp = bs * _next_pow2(max(-(-st["done"] // bs)
                                 for _, _, _, st in rows))
        nb_oob = s.allocator.n_blocks
        prompts = np.zeros((a, sb), np.int32)
        slen = np.zeros((a,), np.int32)
        plen_pre = np.zeros((a,), np.int32)
        sidx = np.full((a,), s.n_slots, np.int32)
        dest = np.full((a, s.n_lblk), nb_oob, np.int32)
        bt_rows = np.full((a, s.n_lblk), nb_oob, np.int32)
        for j, (rid, slot, blocks, st) in enumerate(rows):
            plen_pre[j] = st["done"]
            sidx[j] = slot
            dest[j, :len(blocks)] = blocks
            bt_rows[j, :len(blocks)] = blocks
        batch = {"tokens": jnp.asarray(prompts),
                 "prompt_len": jnp.asarray(slen)}
        s._call_continuation(
            s._admit_restore, pid, batch, sidx, dest, bt_rows, plen_pre,
            pp, [(st["done"], None, st["mk"], st["mv"], st["ka"], st["va"])
                 for _, _, _, st in rows], masters=True)
        for rid, slot, blocks, st in rows:
            # kv16-plain: the rewrite just made the pool its own master
            # again — later chunks pool-gather; keeping the restore-time
            # masters would freeze them at `done` and mis-register the
            # finished chain. Masters mode keeps accumulating as usual.
            keep_m = s.srv.masters_mode
            s._chunk_state[slot] = {
                "rid": rid, "blocks": blocks, "done": st["done"],
                "map": list(blocks), "entry": None, "n_shared": 0,
                "pid": pid, "mk": st["mk"] if keep_m else None,
                "mv": st["mv"] if keep_m else None,
                "ka": st["ka"] if keep_m else None,
                "va": st["va"] if keep_m else None}
            info["chunk_rows"] += 1


def recover(server, journal_dir: str, checkpoint_every: int = 0,
            keep: int = 3, **sched_kwargs):
    """Build a scheduler and restore it from ``journal_dir``.

    Recovery state machine (docs/serving.md §Durability):

    1. **restore** — newest committed checkpoint, ``strict=False``:
       corrupt leaves are dropped per-row, healthy rows keep their exact
       snapshots.
    2. **replay** — journal records past the checkpoint's byte position:
       submits re-enter the queue (same rids — ``_n`` was restored),
       cancels re-apply, ``final`` records override any checkpointed
       live/queued state, ``deliver`` records drop already-owned results.
    3. **resume** — chunk rows rewrite their processed span through the
       restore executable; live rows wait as suspended snapshots and
       re-admit through the normal resume wave at the next step.
    4. **re-checkpoint** — a fresh cut, so a crash during recovery
       replays the same prefix again (idempotent).

    Returns the scheduler, with ``sched.recover_info`` describing what
    recovery did (``resumed_rows``, ``chunk_rows``, ``replayed`` journal
    submits, ``refilled`` rids that fell back to re-prefill,
    ``corrupt_keys`` from the checkpoint manifest).

    The returned scheduler has a fresh :class:`Durability` attached to the
    SAME journal/checkpoint directory, so serving continues journaled.
    """
    from .scheduler import ContinuousScheduler
    t_start = time.monotonic()
    sched = ContinuousScheduler(server, **sched_kwargs)
    jpath = os.path.join(journal_dir, "journal.jsonl")
    cm = CheckpointManager(os.path.join(journal_dir, "checkpoints"),
                           keep=keep)
    info = {"resumed_rows": 0, "chunk_rows": 0, "replayed": 0,
            "refilled": [], "corrupt_keys": [], "journal_pos": 0}
    pending: dict = {}
    if cm.latest_step() is not None:
        tree, meta = cm.restore(strict=False)
        info["corrupt_keys"] = list(meta.get("corrupt_keys", []))
        info["journal_pos"] = int(meta["metadata"].get("journal_pos", 0))
        _apply_checkpoint(sched, tree, meta, pending, info)
    _replay_journal(sched, jpath, info["journal_pos"], pending, info)
    _restore_chunks(sched, pending, info)
    dur = Durability(sched, journal_dir, checkpoint_every=checkpoint_every,
                     keep=keep)
    dur.checkpoint()
    info["recovery_s"] = time.monotonic() - t_start
    sched.recover_info = info
    return sched

"""Scheduling policy layer: priority classes, profile binding, preemption.

The paper's runtime adaptivity (§4.4) is a *per-request-class* trade of
accuracy against energy — which the serving layer can only realize if the
scheduler knows about classes at all. This module is that knowledge,
factored out of the execution core (:class:`repro.serving.scheduler.
ContinuousScheduler`, which keeps only wave dispatch, segment running and
flush):

* :class:`PriorityClass` — one request class: an urgency ``level`` (lower =
  more urgent), a **profile binding** (``accuracy_critical`` pins the
  :class:`~repro.core.manager.ProfileManager` selection to the accuracy
  target even in the battery-saver regime — the paper's "critical
  circumstances" made first-class), and the preemption contract
  (``preemptible`` / ``can_preempt``).
* :class:`SchedulingPolicy` — the pluggable queue discipline. The execution
  core never touches request ordering directly: it asks the policy for the
  next admission candidate (:meth:`head`), reports waves for billing
  semantics (:meth:`wave_critical`), and hands over preemption decisions
  (:meth:`pick_victims`). :class:`FifoPolicy` reproduces the pre-policy
  scheduler exactly (single FIFO, no classes, no preemption);
  :class:`PriorityPolicy` runs per-class FIFOs with strict
  lowest-level-first admission.
* Victim selection is itself pluggable (``victim_picker``): the default
  picks the lowest class first and, within a class, the row with the
  fewest generated tokens — the cheapest row to suspend and resume, since
  the snapshot/replay cost of :meth:`ContinuousScheduler.evict_row` grows
  with the tokens processed. Selection is all-or-nothing: evicting rows
  without admitting the arrival would burn suspend/resume work for
  nothing.

Nothing in here touches the device: policies are pure host-side decision
objects, so swapping one (or unit-testing one) never recompiles anything.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, NamedTuple, Optional, Sequence

__all__ = ["PriorityClass", "RowState", "SchedulingPolicy", "FifoPolicy",
           "PriorityPolicy", "ShedPolicy", "default_classes",
           "default_victim_picker", "make_policy"]


@dataclasses.dataclass(frozen=True)
class PriorityClass:
    """One request priority class.

    ``level`` orders admission (lower = more urgent; class 0 is served
    first). ``accuracy_critical`` is the class's *profile binding*: every
    wave or decode step with a live row of this class selects profiles with
    ``accuracy_critical=True``, pinning the ProfileManager to the accuracy
    target even in battery-saver mode. ``preemptible`` marks rows of this
    class as evictable; ``can_preempt`` lets arrivals of this class evict
    strictly-lower classes when slots or KV blocks run dry. ``speculative``
    opts the class's rows into draft/verify speculative decode when the
    server runs with ``ServingConfig.speculate`` — rows of a class that
    opts out ride the same verify windows but advance exactly one token
    per window (the ``spec_on`` operand of ``decode_segment_spec``;
    delivered tokens are identical either way, speculation only changes
    throughput, so the default is on).
    """

    name: str
    level: int
    accuracy_critical: bool = False
    preemptible: bool = True
    can_preempt: bool = False
    speculative: bool = True


def default_classes(n: int) -> tuple[PriorityClass, ...]:
    """The stock ``n``-class ladder (``--priority-classes n``).

    One class degrades to the classless FIFO contract. Two gives
    ``critical`` (accuracy-pinned, non-preemptible, may preempt) over
    ``saver``. Three and more insert ``standard`` tiers in between —
    preemptible by critical arrivals but never preempting anyone.
    """
    if n <= 1:
        return (PriorityClass("standard", 0),)
    crit = PriorityClass("critical", 0, accuracy_critical=True,
                         preemptible=False, can_preempt=True)
    saver = PriorityClass("saver", n - 1)
    mids = tuple(PriorityClass(f"standard{i}" if n > 3 else "standard", i)
                 for i in range(1, n - 1))
    return (crit,) + mids + (saver,)


class RowState(NamedTuple):
    """Preemption-relevant view of one live pool row (host bookkeeping)."""

    slot: int
    rid: int
    level: int
    generated: int        # tokens emitted so far (snapshot/resume cost)
    blocks: int           # private blocks eviction would return to the pool
    preemptible: bool


def default_victim_picker(arrival_level: int, rows: Sequence[RowState],
                          need_slots: int, need_blocks: int
                          ) -> list[RowState]:
    """Lowest class first, fewest generated tokens first, all-or-nothing.

    Only rows of a *strictly lower* class (``level > arrival_level``) are
    candidates — equal-class traffic never preempts itself, so a class
    cannot starve under its own load. Returns the shortest victim prefix
    that frees ``need_slots`` slots and ``need_blocks`` blocks, or ``[]``
    if no prefix does (partial eviction would suspend rows without
    admitting anyone).
    """
    cands = sorted((r for r in rows
                    if r.preemptible and r.level > arrival_level),
                   key=lambda r: (-r.level, r.generated))
    out: list[RowState] = []
    got_blocks = 0
    for r in cands:
        if len(out) >= need_slots and got_blocks >= need_blocks:
            break
        out.append(r)
        got_blocks += r.blocks
    if len(out) >= need_slots and got_blocks >= need_blocks:
        return out
    return []


class SchedulingPolicy:
    """Queue discipline + class semantics behind the execution core.

    Subclasses own the pending-request ordering; the scheduler only ever
    calls :meth:`enqueue` / :meth:`head` / :meth:`pop_head` /
    :meth:`push_front` (the rollback/resume path re-inserts at the front of
    the request's class so relative order within a class is preserved).
    """

    classes: tuple[PriorityClass, ...] = (PriorityClass("standard", 0),)
    preemptive: bool = False

    def klass(self, request) -> PriorityClass:
        """The class a request belongs to (``request.priority`` clamped
        into the table — FIFO policies map everything to class 0)."""
        i = min(max(int(getattr(request, "priority", 0)), 0),
                len(self.classes) - 1)
        return self.classes[i]

    def bind_critical(self, request) -> bool:
        """Resolved accuracy-critical flag: the class's profile binding
        OR'd with the request's own flag (a critical request in a saver
        class still pins accuracy — the paper's per-request escape hatch)."""
        return bool(request.accuracy_critical
                    or self.klass(request).accuracy_critical)

    def wave_critical(self, requests) -> bool:
        """Profile binding of one admission wave (any bound row pins it)."""
        return any(self.bind_critical(r) for r in requests)

    def bind_speculative(self, request) -> bool:
        """Whether this request's rows speculate under a speculative server
        (the class's ``speculative`` flag; classless FIFOs always do)."""
        return bool(self.klass(request).speculative)

    # ---- queue discipline (subclass responsibility) ----------------------
    def enqueue(self, rid: int, request) -> None:
        raise NotImplementedError

    def head(self) -> Optional[int]:
        """Next admission candidate's rid (None when nothing waits)."""
        raise NotImplementedError

    def pop_head(self) -> int:
        raise NotImplementedError

    def push_front(self, rid: int, request) -> None:
        """Re-insert at the front of the request's class (rollback of a
        failed admission, or a suspended row queued for resume)."""
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    # ---- queue surgery (cancellation / expiry / shedding) ---------------
    def remove(self, rid: int) -> bool:
        """Remove a queued rid wherever it sits; False if not queued."""
        raise NotImplementedError

    def rids(self):
        """All queued rids, admission order (snapshot — safe to mutate
        the policy while iterating the returned list)."""
        raise NotImplementedError

    def shed_tail(self) -> Optional[tuple[int, int]]:
        """The ``(rid, level)`` load shedding would drop first: the
        *least* urgent queued request, last within its class. ``None``
        when the queue is empty."""
        raise NotImplementedError

    # ---- durability (serving/durability.py checkpoints) ------------------
    def queue_state(self) -> dict:
        """JSON-serializable snapshot of the queue discipline's mutable
        state (order, ages) — everything a process restart cannot rebuild
        from the request set alone."""
        raise NotImplementedError

    def restore_queue_state(self, state: dict) -> None:
        """Inverse of :meth:`queue_state` on a freshly built policy."""
        raise NotImplementedError

    # ---- aging -----------------------------------------------------------
    def age_tick(self) -> None:
        """One scheduler round passed: age queued requests (anti-starvation
        hook — the scheduler calls this every round; disciplines without
        aging ignore it)."""

    # ---- preemption ------------------------------------------------------
    def pick_victims(self, request, rows: Sequence[RowState],
                     need_slots: int, need_blocks: int) -> list[RowState]:
        """Victim rows to evict so ``request`` can admit; ``[]`` = don't."""
        return []


class FifoPolicy(SchedulingPolicy):
    """The pre-policy scheduler, verbatim: one FIFO, no classes, no
    preemption. ``priority`` fields are ignored; profile binding reduces to
    each request's own ``accuracy_critical`` flag."""

    def __init__(self):
        self.classes = (PriorityClass("standard", 0),)
        self._q: deque[int] = deque()

    def klass(self, request) -> PriorityClass:
        return self.classes[0]

    def enqueue(self, rid: int, request) -> None:
        self._q.append(rid)

    def head(self) -> Optional[int]:
        return self._q[0] if self._q else None

    def pop_head(self) -> int:
        return self._q.popleft()

    def push_front(self, rid: int, request) -> None:
        self._q.appendleft(rid)

    def __len__(self) -> int:
        return len(self._q)

    def remove(self, rid: int) -> bool:
        try:
            self._q.remove(rid)
            return True
        except ValueError:
            return False

    def rids(self):
        return list(self._q)

    def shed_tail(self) -> Optional[tuple[int, int]]:
        return (self._q[-1], 0) if self._q else None

    def queue_state(self) -> dict:
        return {"q": [int(r) for r in self._q]}

    def restore_queue_state(self, state: dict) -> None:
        self._q = deque(int(r) for r in state["q"])


class PriorityPolicy(SchedulingPolicy):
    """Per-class FIFOs, served strictly lowest-level-first.

    Within a class, order is submission order (resumed / rolled-back
    requests re-enter at the front of their class). ``preemptive`` arms
    :meth:`pick_victims`; ``victim_picker`` is the pluggable selection
    strategy (:func:`default_victim_picker` unless overridden).

    ``aging`` arms anti-starvation promotion: every scheduler round ages
    each queued request by one (:meth:`age_tick`), and a class head that
    has waited ``aging`` rounds is promoted ONE level up — appended to the
    tail of the next-more-urgent queue, behind that class's own backlog,
    with its age reset (climbing two levels takes two full ages). Under a
    sustained critical flood a saver request therefore reaches the front
    in bounded rounds instead of starving forever. Promotion moves queue
    *position only*: the request keeps its class for profile binding,
    billing and preemption (a promoted saver never pins the accuracy
    profile). ``aging=None`` (default) preserves strict
    lowest-level-first exactly.
    """

    def __init__(self, classes: Sequence[PriorityClass],
                 preemptive: bool = False,
                 victim_picker: Optional[Callable] = None,
                 aging: Optional[int] = None):
        assert classes, "at least one priority class"
        self.classes = tuple(sorted(classes, key=lambda c: c.level))
        assert [c.level for c in self.classes] == list(range(len(
            self.classes))), "class levels must be 0..n-1"
        self.preemptive = bool(preemptive)
        self.victim_picker = victim_picker or default_victim_picker
        assert aging is None or aging >= 1, "aging is rounds >= 1"
        self.aging = aging
        self._waited: dict[int, int] = {}     # rid -> rounds since enqueue
        self._q: dict[int, deque[int]] = {c.level: deque()
                                          for c in self.classes}

    def enqueue(self, rid: int, request) -> None:
        self._waited[rid] = 0
        self._q[self.klass(request).level].append(rid)

    def head(self) -> Optional[int]:
        for lvl in range(len(self.classes)):
            if self._q[lvl]:
                return self._q[lvl][0]
        return None

    def pop_head(self) -> int:
        for lvl in range(len(self.classes)):
            if self._q[lvl]:
                rid = self._q[lvl].popleft()
                self._waited.pop(rid, None)
                return rid
        raise IndexError("pop from empty policy queue")

    def push_front(self, rid: int, request) -> None:
        # rollback/resume re-entry: lands at the request's CLASS front
        # (a promotion earned before eviction is forfeited — the wait
        # counter restarts with the new queue residence)
        self._waited.setdefault(rid, 0)
        self._q[self.klass(request).level].appendleft(rid)

    def __len__(self) -> int:
        return sum(len(q) for q in self._q.values())

    def remove(self, rid: int) -> bool:
        for q in self._q.values():
            try:
                q.remove(rid)
                self._waited.pop(rid, None)
                return True
            except ValueError:
                continue
        return False

    def rids(self):
        return [r for lvl in range(len(self.classes))
                for r in self._q[lvl]]

    def shed_tail(self) -> Optional[tuple[int, int]]:
        for lvl in range(len(self.classes) - 1, -1, -1):
            if self._q[lvl]:
                return (self._q[lvl][-1], lvl)
        return None

    def queue_state(self) -> dict:
        return {"q": {str(lvl): [int(r) for r in q]
                      for lvl, q in self._q.items()},
                "waited": {str(r): int(w) for r, w in self._waited.items()}}

    def restore_queue_state(self, state: dict) -> None:
        # restores queue POSITION (including earned aging promotions) —
        # a promoted rid comes back in its promoted queue, not its class's
        self._q = {c.level: deque(int(r)
                                  for r in state["q"].get(str(c.level), []))
                   for c in self.classes}
        self._waited = {int(r): int(w)
                        for r, w in state.get("waited", {}).items()}

    def age_tick(self) -> None:
        if self.aging is None:
            return
        for q in self._q.values():
            for rid in q:
                self._waited[rid] = self._waited.get(rid, 0) + 1
        for lvl in range(1, len(self.classes)):
            q = self._q[lvl]
            if q and self._waited.get(q[0], 0) >= self.aging:
                rid = q.popleft()
                self._waited[rid] = 0
                self._q[lvl - 1].append(rid)

    def pick_victims(self, request, rows: Sequence[RowState],
                     need_slots: int, need_blocks: int) -> list[RowState]:
        if not self.preemptive:
            return []
        k = self.klass(request)
        if not k.can_preempt:
            return []
        return self.victim_picker(k.level, rows, need_slots, need_blocks)


@dataclasses.dataclass(frozen=True)
class ShedPolicy:
    """Graceful overload degradation thresholds.

    When either threshold trips at submission time, the scheduler sheds
    the *least* urgent queued request (class tail via
    :meth:`SchedulingPolicy.shed_tail`, or the arrival itself if it is no
    more urgent) with :class:`~repro.serving.engine.RequestStatus.SHED` —
    a structured refusal the client can retry elsewhere, instead of
    admitting work that will blow every deadline in the queue.

    * ``max_queue`` — queue-depth cap: shed while more than this many
      requests wait.
    * ``max_predicted_miss`` — deadline-pressure cap: shed when more than
      this many queued requests are already predicted (by the scheduler's
      per-segment wall-time EMA) to miss their deadlines.

    ``None`` disables a threshold; the default instance never sheds.
    """

    max_queue: Optional[int] = None
    max_predicted_miss: Optional[int] = None

    def triggered(self, queue_depth: int, predicted_misses: int) -> bool:
        """True when the current load calls for shedding one request."""
        if self.max_queue is not None and queue_depth > self.max_queue:
            return True
        return (self.max_predicted_miss is not None
                and predicted_misses > self.max_predicted_miss)


def make_policy(scfg) -> SchedulingPolicy:
    """Policy for a :class:`~repro.serving.engine.ServingConfig`:
    ``priority_classes > 1`` (or ``preemption``) builds the stock
    :class:`PriorityPolicy` ladder, anything else the exact legacy
    :class:`FifoPolicy`."""
    n = int(getattr(scfg, "priority_classes", 1) or 1)
    if n > 1 or getattr(scfg, "preemption", False):
        return PriorityPolicy(default_classes(max(2, n)),
                              preemptive=bool(scfg.preemption),
                              aging=getattr(scfg, "aging", None))
    return FifoPolicy()

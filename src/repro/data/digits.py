"""Synthetic MNIST-like digit dataset (deterministic, offline).

MNIST itself is not available in this container (DESIGN §8.5); we render
seven-segment-style digit glyphs at 28×28 with randomized geometry
(shift/thickness/contrast) and additive noise. The task keeps the properties
the paper's Table 1 depends on: 10 classes, high float accuracy, and
accuracy that *degrades gracefully* under aggressive quantization.

Everything is generated with numpy from an integer seed — runs are bit-exact
reproducible across restarts (needed by the checkpoint/restart tests).
"""
from __future__ import annotations

import numpy as np

__all__ = ["SEGMENTS", "render_digit", "make_dataset", "batches"]

# classic 7-segment truth table: (top, tl, tr, mid, bl, br, bottom)
SEGMENTS = {
    0: (1, 1, 1, 0, 1, 1, 1),
    1: (0, 0, 1, 0, 0, 1, 0),
    2: (1, 0, 1, 1, 1, 0, 1),
    3: (1, 0, 1, 1, 0, 1, 1),
    4: (0, 1, 1, 1, 0, 1, 0),
    5: (1, 1, 0, 1, 0, 1, 1),
    6: (1, 1, 0, 1, 1, 1, 1),
    7: (1, 0, 1, 0, 0, 1, 0),
    8: (1, 1, 1, 1, 1, 1, 1),
    9: (1, 1, 1, 1, 0, 1, 1),
}


_DIFFICULTY = {
    # (thickness lo/hi, jitter, noise σ, contrast lo/range)
    "easy": (2, 4, 3, 0.25, 0.6, 0.4),
    "hard": (2, 4, 4, 0.35, 0.50, 0.40),  # Table-1 regime: quantization-sensitive
}


def render_digit(digit: int, rng: np.random.Generator, size: int = 28,
                 difficulty: str = "easy") -> np.ndarray:
    """One noisy glyph image in [0, 1], shape [size, size]."""
    th_lo, th_hi, jit, sigma, c_lo, c_rng = _DIFFICULTY[difficulty]
    img = np.zeros((size, size), np.float32)
    th = rng.integers(th_lo, th_hi)          # stroke thickness
    dx = int(rng.integers(-jit, jit + 1))    # jitter
    dy = int(rng.integers(-jit, jit + 1))
    x0, x1 = 8 + dx, 20 + dx                 # glyph box columns
    y0, ym, y1 = 4 + dy, 14 + dy, 24 + dy    # rows: top / middle / bottom

    def hseg(y, on):
        if on:
            img[max(y, 0):min(y + th, size), max(x0, 0):min(x1, size)] = 1.0

    def vseg(ya, yb, x, on):
        if on:
            img[max(ya, 0):min(yb, size), max(x, 0):min(x + th, size)] = 1.0

    top, tl, tr, mid, bl, br, bot = SEGMENTS[digit]
    hseg(y0, top)
    hseg(ym, mid)
    hseg(y1 - th + 1, bot)
    vseg(y0, ym, x0, tl)
    vseg(y0, ym, x1 - th, tr)
    vseg(ym, y1, x0, bl)
    vseg(ym, y1, x1 - th, br)

    contrast = c_lo + c_rng * rng.random()
    img = img * contrast + rng.normal(0.0, sigma, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def make_dataset(n: int, seed: int, size: int = 28, difficulty: str = "easy"):
    """Returns (images [n, size, size, 1] f32, labels [n] i32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n).astype(np.int32)
    imgs = np.stack([render_digit(int(l), rng, size, difficulty)
                     for l in labels])
    return imgs[..., None].astype(np.float32), labels


def batches(images: np.ndarray, labels: np.ndarray, batch_size: int, seed: int):
    """Infinite deterministic shuffled batch iterator."""
    n = len(labels)
    rng = np.random.default_rng(seed)
    while True:
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sel = order[i:i + batch_size]
            yield images[sel], labels[sel]

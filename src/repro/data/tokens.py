"""Deterministic synthetic token pipeline for LM training/serving drivers.

Sequences follow a fixed random permutation chain with ε-noise:
``x_{t+1} = perm[x_t]`` with probability ``1 − ε`` else uniform — so
next-token prediction is learnable to ``1 − ε`` accuracy and training-loss
curves are meaningful without any external corpus. Sharded iteration is
host-deterministic: batch ``i`` is a pure function of ``(seed, i)``, which is
what makes checkpoint/restart bit-exact and elastic re-sharding trivial
(every host can regenerate any global batch slice).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["TokenStream"]


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    noise: float = 0.1

    def _perm(self) -> np.ndarray:
        return np.random.default_rng(self.seed).permutation(self.vocab)

    def batch_at(self, step: int) -> dict:
        """Global batch for ``step`` — stateless, restart-safe."""
        rng = np.random.default_rng((self.seed + 1) * 1_000_003 + step)
        perm = self._perm()
        x = np.empty((self.batch, self.seq_len + 1), np.int32)
        x[:, 0] = rng.integers(0, self.vocab, self.batch)
        for t in range(self.seq_len):
            nxt = perm[x[:, t]]
            flip = rng.random(self.batch) < self.noise
            nxt = np.where(flip, rng.integers(0, self.vocab, self.batch), nxt)
            x[:, t + 1] = nxt
        return {"tokens": x[:, :-1], "labels": x[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

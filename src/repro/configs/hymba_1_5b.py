"""hymba-1.5b — hybrid 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
parallel attention + Mamba heads per layer, ssm_state=16 [arXiv:2411.13676; hf].
Sliding-window attention (1024) everywhere; Hymba's 3 global-attention layers
are mapped to SWA for the scan-uniform stack (DESIGN §4)."""
from .common import ModelConfig, SSMConfig, smoke_of

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv=5, d_ff=5504, vocab=32001,
    head_dim=64, rope_theta=1e4, sliding_window=1024,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, d_conv=4,
                  n_groups=1, chunk=256),
)
SMOKE = smoke_of(CONFIG)

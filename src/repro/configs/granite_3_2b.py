"""granite-3-2b — dense 40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155,
GQA, tied embeddings [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from .common import ModelConfig, smoke_of

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv=8, d_ff=8192, vocab=49155,
    head_dim=64, rope_theta=1e4, tie_embeddings=True,
)
SMOKE = smoke_of(CONFIG)

"""qwen2-vl-2b — VLM backbone 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE, tied embeddings [arXiv:2409.12191; hf].
Vision frontend is a stub: input_specs() supplies precomputed patch
embeddings (brief §ARCHITECTURES)."""
from .common import ModelConfig, smoke_of

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv=2, d_ff=8960, vocab=151936,
    head_dim=128, rope_theta=1e6, qkv_bias=True,
    mrope=True, mrope_sections=(16, 24, 24),
    frontend="vision", n_patches=256, tie_embeddings=True,
)
SMOKE = smoke_of(CONFIG, mrope_sections=(2, 3, 3))

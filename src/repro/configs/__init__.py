"""Architecture registry: the 10 assigned archs + the paper's tiny CNN.

``get_config(name)`` / ``get_smoke(name)`` resolve by arch id (``--arch``);
``ARCHS`` lists all ids; ``SHAPES`` / ``shape_applicable`` come from common.
"""
from __future__ import annotations

import importlib

from .common import SHAPES, Shape, shape_applicable, smoke_of

_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen2-72b": "qwen2_72b",
    "glm4-9b": "glm4_9b",
    "granite-3-2b": "granite_3_2b",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "mamba2-130m": "mamba2_130m",
    "hymba-1.5b": "hymba_1_5b",
    "hubert-xlarge": "hubert_xlarge",
}

ARCHS = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(f".{_MODULES[name]}", __package__)


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


__all__ = ["ARCHS", "get_config", "get_smoke", "SHAPES", "Shape",
           "shape_applicable", "smoke_of"]

"""mamba2-130m — attention-free SSM 24L d_model=768 vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060; unverified].
n_heads records the SSD value-head count (d_inner/head_dim = 1536/64 = 24)."""
from .common import ModelConfig, SSMConfig, smoke_of

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv=24, d_ff=0, vocab=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4,
                  n_groups=1, chunk=256),
)
SMOKE = smoke_of(CONFIG)

"""qwen2-moe-a2.7b — 24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936,
MoE: 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from .common import ModelConfig, MoEConfig, smoke_of

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv=16, d_ff=1408, vocab=151936,
    head_dim=128, rope_theta=1e6, qkv_bias=True,
    moe=MoEConfig(n_routed=60, top_k=4, n_shared=4, d_expert=1408,
                  capacity_factor=1.25, groups=16),
)
SMOKE = smoke_of(CONFIG)

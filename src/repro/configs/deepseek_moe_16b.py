"""deepseek-moe-16b — 28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE: 2 shared + 64 routed top-6, fine-grained [arXiv:2401.06066; hf]."""
from .common import ModelConfig, MoEConfig, smoke_of

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_ff=1408, vocab=102400,
    head_dim=128, rope_theta=1e4,
    moe=MoEConfig(n_routed=64, top_k=6, n_shared=2, d_expert=1408,
                  capacity_factor=1.25, groups=16),
)
SMOKE = smoke_of(CONFIG)

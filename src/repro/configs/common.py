"""Shared helpers for architecture configs + the input-shape registry.

Every assigned architecture file defines ``CONFIG`` (the exact published
configuration from the brief) and ``SMOKE`` (a reduced same-family variant for
CPU smoke tests: forward/train step, shape + finiteness asserts). The full
configs are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig
from repro.models.transformer import ModelConfig

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "Shape", "SHAPES",
           "shape_applicable", "smoke_of"]


@dataclasses.dataclass(frozen=True)
class Shape:
    """One assigned input shape (brief: LM shapes are seq_len × global_batch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """Brief-mandated skips (documented in DESIGN §4)."""
    if shape.kind == "decode" and not cfg.causal:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        sub_quadratic = cfg.family == "ssm" or (
            cfg.family == "hybrid" and cfg.sliding_window > 0)
        if not sub_quadratic:
            return False, "long_500k needs sub-quadratic attention (full-attention arch)"
    return True, ""


def smoke_of(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=max(1, min(cfg.n_kv, 2)),
        d_ff=128,
        vocab=512,
        head_dim=16,
        n_patches=8 if cfg.n_patches else 0,
        feature_dim=32,
        loss_chunk=32,
        attn_block_k=32,
        sliding_window=16 if cfg.sliding_window else 0,
        remat=False,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_routed=8, top_k=2,
                              n_shared=min(cfg.moe.n_shared, 1),
                              d_expert=32, capacity_factor=1.5, groups=2)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2,
                              d_conv=4, n_groups=1, chunk=16)
        kw["n_heads"] = 8   # d_inner 128 / head_dim 16
        kw["n_kv"] = 8 if cfg.family == "ssm" else 2
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)

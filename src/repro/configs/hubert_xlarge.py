"""hubert-xlarge — audio encoder-only 48L d_model=1280 16H d_ff=5120
vocab=504 (masked-unit targets) [arXiv:2106.07447; unverified].
Conv waveform frontend is a stub: input_specs() supplies precomputed frame
embeddings (brief §ARCHITECTURES). No decode step (encoder-only)."""
from .common import ModelConfig, smoke_of

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv=16, d_ff=5120, vocab=504,
    head_dim=80, causal=False, norm="ln", act="gelu",
    frontend="audio", feature_dim=512,
)
SMOKE = smoke_of(CONFIG, head_dim=16)

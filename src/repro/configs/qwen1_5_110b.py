"""qwen1.5-110b — dense 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias [hf:Qwen/Qwen1.5-0.5B (family); hf]."""
from .common import ModelConfig, smoke_of

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_ff=49152, vocab=152064,
    head_dim=128, rope_theta=1e6, qkv_bias=True,
)
SMOKE = smoke_of(CONFIG)

"""Pallas TPU kernel: fused dequant(int8/int4) × bf16 matmul (+ fused requant).

The paper's hot spot is the quantized conv MAC bound to cheap fixed-point
hardware; the LM-family analogue is the projection matmul with weight-only
integer storage. The kernel keeps the paper's two wins:

* **data approximation** — weights travel HBM→VMEM as int8 (or int4 packed
  two-per-byte) and are dequantized *in VMEM*, so HBM traffic shrinks 2–4×
  versus bf16 (the memory-roofline win reported in EXPERIMENTS §Perf);
* **inter-layer precision boundary** — the optional fused requant clamps the
  f32 accumulator onto the next layer's ``Ax`` fixed-point grid before it ever
  leaves VMEM (the streaming-architecture FIFO-width analogue).

Grid: ``(M/bm, N/bn, K/bk)`` with K innermost; an f32 VMEM scratch accumulates
across the K loop and is flushed (optionally requantized) on the last K step.
Tile sides are multiples of 128 to align with the MXU systolic array; defaults
keep the working set (x-tile + w-tile + acc) well under VMEM:

  bm=256, bk=512, bn=256 → 256·512·2B + 512·256·1B + 256·256·4B ≈ 0.6 MiB.

Validated in ``interpret=True`` mode against ``ref.qmatmul_ref`` (CPU has no
MXU; the TPU path is the deployment target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

__all__ = ["qmatmul_pallas", "DEFAULT_BLOCKS"]

DEFAULT_BLOCKS = (256, 512, 256)  # (bm, bk, bn)


def _unpack_int4_tile(p: jax.Array) -> jax.Array:
    """Unpack a ``[bk, bn//2]`` int8 tile of packed int4 → ``[bk, bn]`` int8.

    Layout matches :func:`repro.core.qtypes.pack_int4`: low nibble = even
    column. Arithmetic shifts sign-extend the nibbles.
    """
    lo = (p << 4) >> 4
    hi = p >> 4
    bk, half = p.shape
    out = jnp.stack([lo, hi], axis=-1)          # [bk, half, 2]
    return out.reshape(bk, half * 2)


def _qmatmul_kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *,
                    bits: int, n_k: int,
                    out_bits: int | None, out_scale: float | None):
    """One (m, n, k) grid step: acc += x_tile @ dequant(w_tile)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_q = w_ref[...]
    if bits <= 4:
        w_q = _unpack_int4_tile(w_q)
    # Dequant in VMEM: int carrier → f32 → per-channel scale → bf16 MXU input.
    w = (w_q.astype(jnp.float32) * scale_ref[...][None, :]).astype(jnp.bfloat16)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.bfloat16), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        acc = acc_ref[...]
        if out_bits is not None:
            # Fused static fixed-point requant onto the consumer's Ax grid.
            qmax = 2.0 ** (out_bits - 1) - 1.0
            qmin = -(2.0 ** (out_bits - 1))
            r = acc / out_scale
            q = jnp.clip(jnp.sign(r) * jnp.floor(jnp.abs(r) + 0.5), qmin, qmax)
            acc = q * out_scale
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "blocks", "out_bits", "out_scale", "interpret", "out_dtype"),
)
def qmatmul_pallas(x: jax.Array, w_q: jax.Array, scale: jax.Array, *,
                   bits: int = 8,
                   blocks: tuple[int, int, int] = DEFAULT_BLOCKS,
                   out_bits: int | None = None,
                   out_scale: float | None = None,
                   out_dtype=jnp.float32,
                   interpret: bool = False) -> jax.Array:
    """``x[M,K] @ dequant(w_q, scale)[K,N] -> [M,N]``.

    ``w_q``: int8 ``[K, N]`` for 5..8-bit weights, or packed int4 ``[K, N//2]``
    for ≤4-bit. ``scale``: per-output-channel ``[N]`` f32 (wrappers broadcast
    scalars). Shapes must divide the block sizes — ``ops.qmatmul`` pads.
    """
    m, k = x.shape
    bm, bk, bn = blocks
    if bits <= 4:
        kw, n_half = w_q.shape
        n = n_half * 2
        w_block = (bk, bn // 2)
        w_index = lambda i, j, kk: (kk, j)
    else:
        kw, n = w_q.shape
        w_block = (bk, bn)
        w_index = lambda i, j, kk: (kk, j)
    assert kw == k, f"contraction mismatch {kw} vs {k}"
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        f"shapes ({m},{k},{n}) must divide blocks {blocks}"
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    kernel = functools.partial(_qmatmul_kernel, bits=bits, n_k=n_k,
                               out_bits=out_bits, out_scale=out_scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec(w_block, w_index),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w_q, scale)

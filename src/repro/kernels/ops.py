"""Jit'd public wrappers around the Pallas kernels.

``qmatmul`` is the deployment entry point used by ``models.layers.QLinear`` in
native mode: it consumes a :class:`repro.core.quantizers.QTensor`, handles
padding to MXU-aligned block multiples, broadcasts scalar scales, auto-selects
``interpret=True`` off-TPU (this container), and exposes a ``custom_vjp`` so a
frozen-quantized model can still be fine-tuned (gradient flows to activations
only — weights are integer carriers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import QTensor
from . import ref
from .qmatmul import DEFAULT_BLOCKS, qmatmul_pallas

__all__ = ["qmatmul", "qmatmul_qt"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _pick_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Shrink default blocks for small problems; keep MXU alignment when big."""
    bm, bk, bn = DEFAULT_BLOCKS
    bm = min(bm, _round_up(m, 8))
    bk = min(bk, _round_up(k, 128))
    bn = min(bn, _round_up(n, 128))
    return bm, bk, bn


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def qmatmul(x: jax.Array, w_q: jax.Array, scale: jax.Array,
            bits: int = 8,
            out_bits: int | None = None,
            out_scale: float | None = None,
            interpret: bool | None = None) -> jax.Array:
    """``x[..., K] @ dequant(w_q)[K, N]`` via the Pallas kernel.

    Leading dims of ``x`` are flattened to M. ``w_q`` int8 ``[K, N]`` (bits 5–8)
    or packed int4 ``[K, N//2]`` (bits ≤ 4). ``scale`` scalar or ``[N]``.
    """
    return _qmatmul_impl(x, w_q, scale, bits, out_bits, out_scale, interpret)


def _qmatmul_impl(x, w_q, scale, bits, out_bits, out_scale, interpret):
    interp = (not _on_tpu()) if interpret is None else interpret
    *lead, k = x.shape
    m = int(np.prod(lead)) if lead else 1
    n = w_q.shape[-1] * (2 if bits <= 4 else 1)
    x2 = x.reshape(m, k)
    scale_v = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(-1), (n,))

    bm, bk, bn = _pick_blocks(m, k, n)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w_q, ((0, kp - k), (0, (np_ - n) // (2 if bits <= 4 else 1))))
    sp = jnp.pad(scale_v, (0, np_ - n), constant_values=1.0)

    y = qmatmul_pallas(x2, wp, sp, bits=bits, blocks=(bm, bk, bn),
                       out_bits=out_bits, out_scale=out_scale,
                       interpret=interp)
    return y[:m, :n].reshape(*lead, n)


def _qmatmul_fwd(x, w_q, scale, bits, out_bits, out_scale, interpret):
    y = _qmatmul_impl(x, w_q, scale, bits, out_bits, out_scale, interpret)
    return y, (x, w_q, scale)


def _qmatmul_bwd(bits, out_bits, out_scale, interpret, res, g):
    x, w_q, scale = res
    w = ref.dequant_ref(w_q, jnp.broadcast_to(
        jnp.asarray(scale, jnp.float32).reshape(-1),
        (w_q.shape[-1] * (2 if bits <= 4 else 1),)), bits)
    dx = jnp.einsum("...n,kn->...k", g.astype(jnp.float32), w).astype(x.dtype)
    # Integer carriers / calibrated scales take no gradient (frozen weights).
    dw = np.zeros(w_q.shape, jax.dtypes.float0)
    ds = jnp.zeros_like(jnp.asarray(scale, jnp.float32))
    return dx, dw, ds


qmatmul.defvjp(_qmatmul_fwd, _qmatmul_bwd)


def qmatmul_qt(x: jax.Array, qt: QTensor, *,
               out_bits: int | None = None, out_scale: float | None = None,
               interpret: bool | None = None) -> jax.Array:
    """Convenience overload taking the :class:`QTensor` from ``quantize_native``."""
    return qmatmul(x, qt.data, jnp.asarray(qt.scale, jnp.float32).reshape(-1),
                   qt.bits, out_bits, out_scale, interpret)

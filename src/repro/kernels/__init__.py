"""Pallas TPU kernels for the paper's compute hot spots (quantized MACs).

- ``qmatmul`` — fused dequant(int8/int4-packed) × bf16 matmul with optional
  fused fixed-point requant of the output (``ops.qmatmul`` is the wrapper).
- ``qkv_attention`` — decode attention over an int8-quantized KV cache.

``ref.py`` holds the pure-jnp oracles; kernels are validated in interpret
mode on CPU (TPU v5e is the deployment target).
"""
from jax.experimental.pallas import tpu as _pltpu

# Compat alias: jax < 0.5 exposes ``TPUCompilerParams``, newer releases renamed
# it ``CompilerParams``. Kernels import this symbol from the package so either
# jax works. Defined before the submodule imports below (they depend on it).
CompilerParams = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams

from .ops import qmatmul, qmatmul_qt
from .qmatmul import qmatmul_pallas, DEFAULT_BLOCKS
from .qkv_attention import qkv_attention_pallas
from .paged_attention import paged_attention_pallas
from .aquant import aquant_pallas

__all__ = ["qmatmul", "qmatmul_qt", "qmatmul_pallas", "qkv_attention_pallas",
           "paged_attention_pallas", "aquant_pallas", "DEFAULT_BLOCKS",
           "CompilerParams"]

"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package must match its oracle here to float tolerance
across the shape/dtype sweeps in ``tests/test_kernels.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qtypes import unpack_int4

__all__ = ["qmatmul_ref", "dequant_ref", "requant_ref", "qkv_attention_ref",
           "paged_attention_ref"]


def dequant_ref(w_q: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Dequantize an int8 carrier (packed two-per-byte when bits<=4) to f32.

    ``scale`` broadcasts against the dequantized ``[K, N]``: scalar, ``[N]``
    per-output-channel, or anything jnp-broadcastable.
    """
    q = unpack_int4(w_q) if bits <= 4 else w_q
    return q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)


def qmatmul_ref(x: jax.Array, w_q: jax.Array, scale: jax.Array, bits: int,
                out_scale: jax.Array | None = None,
                out_bits: int | None = None) -> jax.Array:
    """Oracle for the fused dequant-matmul: ``x @ dequant(w)`` (+ fused requant).

    Matches the kernel's numerics: x is cast to bf16 (MXU input precision),
    the product accumulates in f32, optional static fixed-point requant of the
    output (the paper's inter-layer activation-precision boundary).
    """
    w = dequant_ref(w_q, scale, bits).astype(jnp.bfloat16)
    acc = jnp.dot(x.astype(jnp.bfloat16), w, preferred_element_type=jnp.float32)
    if out_scale is not None:
        assert out_bits is not None
        acc = requant_ref(acc, out_scale, out_bits)
    return acc


def requant_ref(acc: jax.Array, out_scale: jax.Array, out_bits: int) -> jax.Array:
    """Static fixed-point requant: clip(round(acc/s)) * s at ``out_bits``."""
    qmax = 2.0 ** (out_bits - 1) - 1.0
    qmin = -(2.0 ** (out_bits - 1))
    s = jnp.asarray(out_scale, jnp.float32)
    r = acc / s
    q = jnp.clip(jnp.sign(r) * jnp.floor(jnp.abs(r) + 0.5), qmin, qmax)
    return q * s


def qkv_attention_ref(q: jax.Array, k_q: jax.Array, v_q: jax.Array,
                      k_scale: jax.Array, v_scale: jax.Array) -> jax.Array:
    """Oracle for int8-KV-cache attention (decode): softmax(q kᵀ)·v with
    int8-quantized K/V dequantized on the fly.

    Shapes: q ``[B, H, 1, D]``; k_q/v_q ``[B, H, S, D]`` int8; scales broadcast
    (per-head ``[B, H, 1, 1]`` or scalar). Returns ``[B, H, 1, D]`` f32.
    """
    kf = k_q.astype(jnp.float32) * jnp.asarray(k_scale, jnp.float32)
    vf = v_q.astype(jnp.float32) * jnp.asarray(v_scale, jnp.float32)
    scores = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32), kf)
    scores = scores / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqs,bhsd->bhqd", p, vf)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        k_scale: jax.Array, v_scale: jax.Array,
                        token_idx: jax.Array, block_table: jax.Array,
                        pos: jax.Array, *, bits: int = 16,
                        window: int = 0) -> jax.Array:
    """Oracle for the in-place paged decode-attention kernel.

    The reference path is the one the kernel replaces: a dense gather of each
    row's blocks through its table (unmapped entries fill with zeros /
    ``token_idx`` −1) followed by the masked-softmax decode attention of
    ``repro.models.attention.decode_attention`` — including the int8 fast
    path's operation order (contract on the int grid, scale the scores) and
    the kv4 packed path's (unpack the nibbles, dequantize, then contract).

    q ``[B, Hkv, Hg, D]``; k/v pool ``[n_blocks, bs, Hkv, D]``; returns
    ``[B, Hkv, Hg, D]`` f32. ``window <= 0`` = full attention.
    """
    b, hkv, hg, d = q.shape
    n_blocks, bs = token_idx.shape
    _, n_lblk = block_table.shape
    NEG_INF = -1e30
    # both "unmapped" sentinels (< 0, >= n_blocks) must miss the pool: OOB
    # positives already fill, but jnp.take wraps negatives — normalize them
    bt = jnp.where(block_table < 0, n_blocks, block_table)

    def gather(pool, fill):
        g = jnp.take(pool, bt, axis=0, mode="fill", fill_value=fill)
        return g.reshape(b, n_lblk * bs, *pool.shape[2:])

    if bits == 4:
        # packed pool: gather the half-width bytes (fill 0 unpacks to zeros),
        # unpack, and dequantize *before* the contraction — exactly
        # decode_attention's kv4 (dequantize-first) operation order
        kf = unpack_int4(gather(k_pool, 0)).astype(jnp.float32) \
            * jnp.asarray(k_scale, jnp.float32)[:, None, :, None]
        vf = unpack_int4(gather(v_pool, 0)).astype(jnp.float32) \
            * jnp.asarray(v_scale, jnp.float32)[:, None, :, None]
    else:
        kf = gather(k_pool, 0).astype(jnp.float32)       # [B, S, Hkv, D]
        vf = gather(v_pool, 0).astype(jnp.float32)
    tidx = gather(token_idx, -1)                         # [B, S]
    qh = q.astype(jnp.float32) * d ** -0.5
    scores = jnp.einsum("bkgd,bskd->bkgs", qh, kf)
    if bits == 8:
        scores = scores * jnp.asarray(k_scale, jnp.float32)[:, :, None, None]
    win = window if window > 0 else n_lblk * bs + 1
    keep = (tidx >= 0) & (tidx <= pos[:, None]) & (pos[:, None] - tidx < win)
    scores = jnp.where(keep[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    if bits == 8:
        out = out * jnp.asarray(v_scale, jnp.float32)[:, :, None, None]
    # rows with no attendable key flush exact zeros, like the kernel — a
    # plain softmax would emit the uniform mean of whatever the gather
    # fetched (zeros for unmapped tables, junk V for mapped-but-masked
    # ones); pinning both paths to zero keeps kernel/oracle identity total
    return jnp.where(keep.any(-1)[:, None, None, None], out, 0.0)


def aquant_ref(x: jax.Array, bits: int = 8, po2: bool = True) -> jax.Array:
    """Oracle for the fused activation-quantization kernel: dynamic max-abs
    scale, po2 rounding, signed non-symmetric grid — fake_quant numerics."""
    from repro.core.qtypes import QuantSpec
    from repro.core.quantizers import fake_quant
    return fake_quant(x, QuantSpec(bits=bits, po2_scale=po2))

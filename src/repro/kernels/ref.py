"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package must match its oracle here to float tolerance
across the shape/dtype sweeps in ``tests/test_kernels.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qtypes import unpack_int4

__all__ = ["qmatmul_ref", "dequant_ref", "requant_ref", "qkv_attention_ref"]


def dequant_ref(w_q: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Dequantize an int8 carrier (packed two-per-byte when bits<=4) to f32.

    ``scale`` broadcasts against the dequantized ``[K, N]``: scalar, ``[N]``
    per-output-channel, or anything jnp-broadcastable.
    """
    q = unpack_int4(w_q) if bits <= 4 else w_q
    return q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)


def qmatmul_ref(x: jax.Array, w_q: jax.Array, scale: jax.Array, bits: int,
                out_scale: jax.Array | None = None,
                out_bits: int | None = None) -> jax.Array:
    """Oracle for the fused dequant-matmul: ``x @ dequant(w)`` (+ fused requant).

    Matches the kernel's numerics: x is cast to bf16 (MXU input precision),
    the product accumulates in f32, optional static fixed-point requant of the
    output (the paper's inter-layer activation-precision boundary).
    """
    w = dequant_ref(w_q, scale, bits).astype(jnp.bfloat16)
    acc = jnp.dot(x.astype(jnp.bfloat16), w, preferred_element_type=jnp.float32)
    if out_scale is not None:
        assert out_bits is not None
        acc = requant_ref(acc, out_scale, out_bits)
    return acc


def requant_ref(acc: jax.Array, out_scale: jax.Array, out_bits: int) -> jax.Array:
    """Static fixed-point requant: clip(round(acc/s)) * s at ``out_bits``."""
    qmax = 2.0 ** (out_bits - 1) - 1.0
    qmin = -(2.0 ** (out_bits - 1))
    s = jnp.asarray(out_scale, jnp.float32)
    r = acc / s
    q = jnp.clip(jnp.sign(r) * jnp.floor(jnp.abs(r) + 0.5), qmin, qmax)
    return q * s


def qkv_attention_ref(q: jax.Array, k_q: jax.Array, v_q: jax.Array,
                      k_scale: jax.Array, v_scale: jax.Array) -> jax.Array:
    """Oracle for int8-KV-cache attention (decode): softmax(q kᵀ)·v with
    int8-quantized K/V dequantized on the fly.

    Shapes: q ``[B, H, 1, D]``; k_q/v_q ``[B, H, S, D]`` int8; scales broadcast
    (per-head ``[B, H, 1, 1]`` or scalar). Returns ``[B, H, 1, D]`` f32.
    """
    kf = k_q.astype(jnp.float32) * jnp.asarray(k_scale, jnp.float32)
    vf = v_q.astype(jnp.float32) * jnp.asarray(v_scale, jnp.float32)
    scores = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32), kf)
    scores = scores / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqs,bhsd->bhqd", p, vf)


def aquant_ref(x: jax.Array, bits: int = 8, po2: bool = True) -> jax.Array:
    """Oracle for the fused activation-quantization kernel: dynamic max-abs
    scale, po2 rounding, signed non-symmetric grid — fake_quant numerics."""
    from repro.core.qtypes import QuantSpec
    from repro.core.quantizers import fake_quant
    return fake_quant(x, QuantSpec(bits=bits, po2_scale=po2))

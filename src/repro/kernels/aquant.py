"""Pallas TPU kernel: fused dynamic activation quantization (the ``Ax`` side).

At every quantized layer boundary the QAT/serving path computes
``amax → scale → clip(round(x/s))·s`` over the activation tensor. Unfused,
that is three full HBM round-trips of ``x``; this kernel does the row-tiled
two-phase version in VMEM:

  phase 1 (grid pass 1): per-row-block max|x| → partial amax accumulator
  phase 2 (grid pass 2): quantize the same blocks against the final scale

A single ``pl.pallas_call`` with a 2×-length grid walks the row blocks twice
(sequential grid on TPU); the scalar amax lives in SMEM scratch between the
passes, so ``x`` streams HBM→VMEM exactly twice (once per phase) instead of
three+ times, and the rounding grid matches ``fake_quant`` bit-exactly
(po2 scale, round-half-away-from-zero, signed non-symmetric range).

Oracle: ``ref.aquant_ref`` (== core.quantizers.fake_quant numerics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

__all__ = ["aquant_pallas"]


def _kernel(x_ref, o_ref, amax_ref, *, n_blocks: int, bits: int, po2: bool):
    i = pl.program_id(0)
    phase1 = i < n_blocks

    @pl.when(i == 0)
    def _init():
        amax_ref[0] = 1e-9

    @pl.when(phase1)
    def _reduce():
        amax_ref[0] = jnp.maximum(amax_ref[0], jnp.max(jnp.abs(x_ref[...])))

    @pl.when(jnp.logical_not(phase1))
    def _quantize():
        qmax = 2.0 ** (bits - 1) - 1.0
        qmin = -(2.0 ** (bits - 1))
        scale = amax_ref[0] / (-qmin)
        if po2:
            scale = jnp.exp2(jnp.ceil(jnp.log2(scale)))
        r = x_ref[...].astype(jnp.float32) / scale
        q = jnp.clip(jnp.sign(r) * jnp.floor(jnp.abs(r) + 0.5), qmin, qmax)
        o_ref[...] = (q * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "po2", "block_rows",
                                             "interpret"))
def aquant_pallas(x: jax.Array, *, bits: int = 8, po2: bool = True,
                  block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """Fake-quantize ``x [M, N]`` onto the dynamic ``bits`` grid (float out)."""
    m, n = x.shape
    br = min(block_rows, m)
    pad = (-m) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n_blocks = (m + pad) // br

    kernel = functools.partial(_kernel, n_blocks=n_blocks, bits=bits, po2=po2)
    out = pl.pallas_call(
        kernel,
        grid=(2 * n_blocks,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i % n_blocks, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i % n_blocks, 0)),
        out_shape=jax.ShapeDtypeStruct((m + pad, n), x.dtype),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(x)
    return out[:m]

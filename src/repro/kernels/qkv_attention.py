"""Pallas TPU kernel: decode attention over an int8-quantized KV cache.

Decode (one new token against an S-long cache) is memory-roofline-bound: the
whole KV cache streams HBM→VMEM per step. Storing K/V on the paper's 8-bit
grid halves that traffic vs bf16 — the serving-side twin of the weight-only
``qmatmul`` kernel — and the dequant happens in VMEM right before the MXU.

Layout (GQA-native): queries grouped by KV head.
  q   [G, Hg, D]   bf16/f32 — G = batch×kv_heads groups, Hg = q-heads/kv-head
  k_q [G, S, D]    int8, per-group scale [G]
  v_q [G, S, D]    int8, per-group scale [G]
  len [G]          valid cache length per group (int32, SMEM)

Grid ``(G, S/bs)`` with the S axis sequential; online-softmax scratch
(running max ``m``, denominator ``l``, accumulator) lives in VMEM across the
S loop and is flushed on the last block. Validated in interpret mode against
``ref.qkv_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

__all__ = ["qkv_attention_pallas"]

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, bs: int, n_s: int, sm_scale: float):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                       # [Hg, D]
    k = k_ref[0].astype(jnp.float32) * ks_ref[0]           # [bs, D] dequant in VMEM
    v = v_ref[0].astype(jnp.float32) * vs_ref[0]

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale  # [Hg, bs]
    col = s * bs + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(col < len_ref[pl.program_id(0)], scores, NEG_INF)

    m_prev = m_ref[...]                                    # [Hg, 1]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                            # [Hg, bs]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def qkv_attention_pallas(q: jax.Array, k_q: jax.Array, v_q: jax.Array,
                         k_scale: jax.Array, v_scale: jax.Array,
                         lengths: jax.Array, *,
                         block_s: int = 512,
                         interpret: bool = False) -> jax.Array:
    """Softmax(q·dequant(K)ᵀ)·dequant(V) per GQA group; see module docstring."""
    g, hg, d = q.shape
    _, s, _ = k_q.shape
    bs = min(block_s, s)
    assert s % bs == 0, f"S={s} must divide block_s={bs} (wrapper pads)"
    n_s = s // bs

    kernel = functools.partial(_kernel, bs=bs, n_s=n_s, sm_scale=1.0 / d**0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, n_s),
        in_specs=[
            # index maps get the prefetched scalar ref as a trailing arg
            pl.BlockSpec((1, hg, d), lambda b, s_, L: (b, 0, 0)),
            pl.BlockSpec((1, bs, d), lambda b, s_, L: (b, s_, 0)),
            pl.BlockSpec((1, bs, d), lambda b, s_, L: (b, s_, 0)),
            pl.BlockSpec((1,), lambda b, s_, L: (b,)),
            pl.BlockSpec((1,), lambda b, s_, L: (b,)),
        ],
        out_specs=pl.BlockSpec((1, hg, d), lambda b, s_, L: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hg, 1), jnp.float32),
            pltpu.VMEM((hg, 1), jnp.float32),
            pltpu.VMEM((hg, d), jnp.float32),
        ],
    )
    # Scalar-prefetch arg: per-group valid lengths, one row per grid b.
    len_arg = lengths.astype(jnp.int32)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, hg, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(len_arg, q, k_q, v_q,
      jnp.asarray(k_scale, jnp.float32).reshape(g),
      jnp.asarray(v_scale, jnp.float32).reshape(g))

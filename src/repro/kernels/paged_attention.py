"""Pallas TPU kernel: decode attention reading paged KV blocks **in place**.

The gather-view serving path (`repro.models.attention.paged_view`) rebuilds a
dense ``[B, n_lblk*bs]`` copy of every row's KV before `decode_attention` can
run — per segment that is a full extra round-trip of the pool through HBM, and
the fold-back at segment exit doubles it. This kernel deletes both copies: the
per-row ``block_table`` rides in as a **scalar-prefetch** operand, the
BlockSpec index maps resolve each grid step's logical block to its physical
pool block, and the DMA engine streams exactly the mapped blocks HBM→VMEM.
Unmapped table entries (``< 0`` or ``>= n_blocks`` — free rows, retired rows,
copy-on-write guards) are clamped for the DMA and masked to ``-inf`` in the
scores, so a dead row reads garbage bytes but contributes nothing.

Layout (matches :class:`repro.models.attention.PagedKVCache`):
  q        [B, Hkv, Hg, D]   f32/bf16 — one decode token per row
  k/v pool [n_blocks, bs, Hkv, D]     bf16 (kv16) or int8 (kv8);
           [n_blocks, bs, Hkv, D/2]   int8 at kv4 — two nibbles per byte,
           unpacked in VMEM inside the kernel (low nibble = even index)
  tidx     [n_blocks, bs]    int32 absolute token index per slot, −1 = empty
  scales   [B, Hkv]          f32 per-row dequant scales (kv8/kv4)
  bt       [B * n_lblk]      int32 flattened block table (scalar prefetch)
  pos      [B]               int32 current absolute position (scalar prefetch)

Grid ``(B, Hkv, n_lblk)`` with the logical-block axis sequential;
online-softmax scratch (running max ``m``, denominator ``l``, accumulator)
lives in VMEM across the block loop and is flushed on the last block. The
int8 path contracts on the int grid and folds the per-(B,Hkv) scale into the
scores/output afterwards — the exact operation order of the jnp
``decode_attention`` int8 fast path, so the two stay numerically aligned.
The int4 path DMAs the packed half-width block, unpacks the nibbles in VMEM
and dequantizes **before** the contraction — `decode_attention`'s kv4
(dequantize-first) order — so kv4 streams half of kv8's pool bytes per step.
Validated in interpret mode against ``ref.paged_attention_ref`` and the
gather-view oracle (``tests/test_paged_attention_kernel.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.qtypes import unpack_int4
from repro.kernels import CompilerParams

__all__ = ["paged_attention_pallas", "paged_attention_pallas_multi"]

NEG_INF = -1e30


def _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, tidx_ref, ks_ref, vs_ref,
            o_ref, m_ref, l_ref, acc_ref, *,
            n_lblk: int, n_blocks: int, bits: int, window: int,
            sm_scale: float):
    b = pl.program_id(0)
    lb = pl.program_id(2)

    @pl.when(lb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    entry = bt_ref[b * n_lblk + lb]
    mapped = (entry >= 0) & (entry < n_blocks)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # [Hg, D]
    if bits == 4:
        # packed nibbles: unpack in VMEM and dequantize before the dot —
        # decode_attention's kv4 (dequantize-first) operation order
        k = unpack_int4(k_ref[0, :, 0]).astype(jnp.float32) * ks_ref[0, 0]
    else:
        k = k_ref[0, :, 0].astype(jnp.float32)              # [bs, D]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [Hg, bs]
    if bits == 8:
        # int-grid contraction, scale folded after — decode_attention's order
        scores = scores * ks_ref[0, 0]

    tidx = tidx_ref[0]                                      # [bs]
    p_b = pos_ref[b]
    keep = mapped & (tidx >= 0) & (tidx <= p_b) & (p_b - tidx < window)
    scores = jnp.where(keep[None, :], scores, NEG_INF)

    m_prev = m_ref[...]                                     # [Hg, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # explicit zero on masked columns: with every key masked so far,
    # exp(NEG_INF − NEG_INF) would otherwise contribute 1 per dead slot
    p = jnp.where(keep[None, :], jnp.exp(scores - m_new), 0.0)  # [Hg, bs]
    if bits == 4:
        v = unpack_int4(v_ref[0, :, 0]).astype(jnp.float32) * vs_ref[0, 0]
    else:
        v = v_ref[0, :, 0].astype(jnp.float32)              # [bs, D]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(lb == n_lblk - 1)
    def _flush():
        # rows with no attendable key flush exact zeros; the ref oracle pins
        # the same corner to zero (an unmapped table's gather-fill would
        # yield zeros under a uniform softmax anyway), so dead rows agree
        # across backends bit-for-bit
        any_valid = m_ref[...] > NEG_INF * 0.5
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        if bits == 8:
            out = out * vs_ref[0, 0]
        o_ref[0, 0] = jnp.where(any_valid, out, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bits", "window", "interpret"))
def paged_attention_pallas(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           k_scale: jax.Array, v_scale: jax.Array,
                           token_idx: jax.Array, block_table: jax.Array,
                           pos: jax.Array, *, bits: int = 16,
                           window: int = 0,
                           interpret: bool = False) -> jax.Array:
    """In-place paged decode attention; see module docstring for layout.

    ``window <= 0`` means full attention. Returns ``[B, Hkv, Hg, D]`` f32.
    """
    assert bits in (4, 8, 16), \
        f"paged kernel supports kv16/kv8/kv4, got kv{bits}"
    b, hkv, hg, d = q.shape
    n_blocks, bs, _, dk = k_pool.shape   # dk = D (kv8/kv16) or D/2 (kv4 packed)
    assert dk == (d // 2 if bits == 4 else d)
    _, n_lblk = block_table.shape
    win = window if window > 0 else n_lblk * bs + 1

    kernel = functools.partial(
        _kernel, n_lblk=n_lblk, n_blocks=n_blocks, bits=bits, window=win,
        sm_scale=1.0 / d ** 0.5)

    def phys(lb_idx, bt):
        # block-table indirection happens HERE, in the index map: the grid
        # cell's DMA source is the physical pool block the table names.
        # Unmapped entries clamp to a resident block (the bytes are fetched
        # but masked off in the kernel body) — the DMA must stay in bounds.
        return jnp.clip(bt[lb_idx], 0, n_blocks - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # (block_table, pos)
        grid=(b, hkv, n_lblk),
        in_specs=[
            pl.BlockSpec((1, 1, hg, d), lambda r, h, lb, bt, p: (r, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, dk),
                         lambda r, h, lb, bt, p:
                         (phys(r * n_lblk + lb, bt), 0, h, 0)),
            pl.BlockSpec((1, bs, 1, dk),
                         lambda r, h, lb, bt, p:
                         (phys(r * n_lblk + lb, bt), 0, h, 0)),
            pl.BlockSpec((1, bs),
                         lambda r, h, lb, bt, p:
                         (phys(r * n_lblk + lb, bt), 0)),
            pl.BlockSpec((1, 1), lambda r, h, lb, bt, p: (r, h)),
            pl.BlockSpec((1, 1), lambda r, h, lb, bt, p: (r, h)),
        ],
        out_specs=pl.BlockSpec((1, 1, hg, d),
                               lambda r, h, lb, bt, p: (r, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hg, 1), jnp.float32),
            pltpu.VMEM((hg, 1), jnp.float32),
            pltpu.VMEM((hg, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, hg, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_table.reshape(-1).astype(jnp.int32), pos.astype(jnp.int32),
      q, k_pool, v_pool, token_idx,
      jnp.asarray(k_scale, jnp.float32).reshape(b, hkv),
      jnp.asarray(v_scale, jnp.float32).reshape(b, hkv))


def _kernel_multi(bt_ref, pos_ref, q_ref, k_ref, v_ref, tidx_ref, ks_ref,
                  vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_lblk: int, n_blocks: int, bits: int, window: int,
                  sm_scale: float, w: int, hg: int):
    """W-query variant: the draft/verify window's W queries fold into the
    head-group compute dim (``[W*Hg, D]`` q block, ``[W*Hg, bs]`` scores),
    so the block loop, DMA pattern, and online-softmax structure are the
    single-query kernel's unchanged. Query ``wi = row // hg`` sits at
    absolute position ``pos + wi`` (per-query causal mask) and folds the
    per-position int8 scale ladder ``ks/vs [W]``."""
    b = pl.program_id(0)
    lb = pl.program_id(2)

    @pl.when(lb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    entry = bt_ref[b * n_lblk + lb]
    mapped = (entry >= 0) & (entry < n_blocks)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # [W*Hg, D]
    k = k_ref[0, :, 0].astype(jnp.float32)                  # [bs, D]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [W*Hg, bs]
    bs_ = scores.shape[-1]
    if bits == 8:
        ks = ks_ref[0, 0]                                   # [W]
        scores = (scores.reshape(w, hg, bs_)
                  * ks[:, None, None]).reshape(w * hg, bs_)

    tidx = tidx_ref[0]                                      # [bs]
    qp = pos_ref[b] + jax.lax.broadcasted_iota(jnp.int32, (w, 1), 0)
    keep = (mapped & (tidx[None, :] >= 0) & (tidx[None, :] <= qp)
            & (qp - tidx[None, :] < window))                # [W, bs]
    keep_q = jnp.broadcast_to(keep[:, None, :],
                              (w, hg, bs_)).reshape(w * hg, bs_)
    scores = jnp.where(keep_q, scores, NEG_INF)

    m_prev = m_ref[...]                                     # [W*Hg, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(keep_q, jnp.exp(scores - m_new), 0.0)     # [W*Hg, bs]
    v = v_ref[0, :, 0].astype(jnp.float32)                  # [bs, D]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(lb == n_lblk - 1)
    def _flush():
        any_valid = m_ref[...] > NEG_INF * 0.5
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        if bits == 8:
            vs = vs_ref[0, 0]                               # [W]
            d_ = out.shape[-1]
            out = (out.reshape(w, hg, d_)
                   * vs[:, None, None]).reshape(w * hg, d_)
        o_ref[0, 0] = jnp.where(any_valid, out, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bits", "window", "interpret"))
def paged_attention_pallas_multi(q: jax.Array, k_pool: jax.Array,
                                 v_pool: jax.Array, k_ladder: jax.Array,
                                 v_ladder: jax.Array, token_idx: jax.Array,
                                 block_table: jax.Array, pos: jax.Array, *,
                                 bits: int = 16, window: int = 0,
                                 interpret: bool = False) -> jax.Array:
    """In-place paged attention for a W-query speculative window.

    q ``[B, W, Hkv, Hg, D]`` — query ``j`` at absolute position
    ``pos + j``; ``k_ladder``/``v_ladder`` ``[B, W, Hkv]`` are the
    per-position int8 dequant scale ladders (ignored at kv16).
    ``window <= 0`` means full attention. Returns ``[B, W, Hkv, Hg, D]``
    f32. Same grid/scalar-prefetch structure as
    :func:`paged_attention_pallas` — W rides in the q block, not the grid.
    """
    assert bits in (8, 16), f"paged kernel supports kv16/kv8, got kv{bits}"
    b, w, hkv, hg, d = q.shape
    n_blocks, bs, _, _ = k_pool.shape
    _, n_lblk = block_table.shape
    # full-attention sentinel must exceed max(qpos - tidx) = pos + w - 1
    win = window if window > 0 else n_lblk * bs + w

    kernel = functools.partial(
        _kernel_multi, n_lblk=n_lblk, n_blocks=n_blocks, bits=bits,
        window=win, sm_scale=1.0 / d ** 0.5, w=w, hg=hg)

    def phys(lb_idx, bt):
        return jnp.clip(bt[lb_idx], 0, n_blocks - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # (block_table, pos)
        grid=(b, hkv, n_lblk),
        in_specs=[
            pl.BlockSpec((1, 1, w * hg, d),
                         lambda r, h, lb, bt, p: (r, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda r, h, lb, bt, p:
                         (phys(r * n_lblk + lb, bt), 0, h, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda r, h, lb, bt, p:
                         (phys(r * n_lblk + lb, bt), 0, h, 0)),
            pl.BlockSpec((1, bs),
                         lambda r, h, lb, bt, p:
                         (phys(r * n_lblk + lb, bt), 0)),
            pl.BlockSpec((1, 1, w), lambda r, h, lb, bt, p: (r, h, 0)),
            pl.BlockSpec((1, 1, w), lambda r, h, lb, bt, p: (r, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w * hg, d),
                               lambda r, h, lb, bt, p: (r, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((w * hg, 1), jnp.float32),
            pltpu.VMEM((w * hg, 1), jnp.float32),
            pltpu.VMEM((w * hg, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, w * hg, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_table.reshape(-1).astype(jnp.int32), pos.astype(jnp.int32),
      q.transpose(0, 2, 1, 3, 4).reshape(b, hkv, w * hg, d),
      k_pool, v_pool, token_idx,
      jnp.asarray(k_ladder, jnp.float32).transpose(0, 2, 1),
      jnp.asarray(v_ladder, jnp.float32).transpose(0, 2, 1))
    return out.reshape(b, hkv, w, hg, d).transpose(0, 2, 1, 3, 4)

"""Runtime execution settings.

``compute_dtype`` is bf16 on TPU (MXU-native) but f32 on CPU, where XLA's
DotThunk cannot *execute* bf16×bf16→f32 (lowering works — the dry-run forces
bf16 via :func:`set_compute_dtype` so the compiled HLO matches the TPU target's
byte counts, but never runs the executable).
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

__all__ = ["compute_dtype", "set_compute_dtype", "use_compute_dtype"]

_OVERRIDE = None


def compute_dtype():
    if _OVERRIDE is not None:
        return _OVERRIDE
    return jnp.bfloat16 if jax.default_backend() == "tpu" else jnp.float32


def set_compute_dtype(dt) -> None:
    global _OVERRIDE
    _OVERRIDE = dt


@contextlib.contextmanager
def use_compute_dtype(dt):
    global _OVERRIDE
    prev = _OVERRIDE
    _OVERRIDE = dt
    try:
        yield
    finally:
        _OVERRIDE = prev

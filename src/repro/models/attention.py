"""Attention: GQA with blockwise online-softmax (train/prefill) and cached decode.

Design notes (DESIGN §5):

* **Blockwise train/prefill** — ``lax.scan`` over KV blocks with an online
  softmax; the full ``[S, S]`` score matrix never materializes, so 32k-token
  prefill fits the per-device memory budget and the HLO stays compact for the
  multi-pod dry-run.
* **Window-as-data** — the causal window rides in as a traced int32 (`>= S`
  means full attention), so hybrid stacks (Hymba) mix SWA/global layers inside
  one ``lax.scan`` over layers, and the merged adaptive engine stays
  branch-free.
* **Decode** — one-token attention against a (optionally int8-quantized) KV
  cache; ring buffer for SWA. The Pallas ``qkv_attention`` kernel is the TPU
  deployment path for the int8 cache; the jnp path here has identical
  numerics/roofline and is what the dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .pshard import constrain

__all__ = ["gqa_attention", "swa_attention", "decode_attention", "KVCache",
           "init_kv_cache", "update_kv_cache",
           "PagedKVCache", "init_paged_kv_cache", "update_paged_kv_cache",
           "paged_view", "paged_decode_attention", "prefix_attention",
           "kv_refine"]

NEG_INF = -1e30


def kv_refine(x: jax.Array, eff_bits: jax.Array) -> jax.Array:
    """Per-layer precision-policy fake-quant of fresh K/V projections.

    ``eff_bits`` is a traced int32 scalar — one entry of the searched
    per-layer bit-width schedule (``kv_table[profile, layer]``), so
    switching schedules never retraces. Applied at the attention boundary
    (immediately after the QKV projection) in **every** path that births
    K/V — cold prefill, continuation/chunked prefill suffixes, and decode
    steps — so attention reads, cache writes, and collected full-precision
    masters all see the same refined values; replayed prefix masters are
    already refined and must never pass through here again (fake-quant is
    not bit-stable under scale recomputation).

    Numerics: deterministic symmetric fake-quant on a per-position grid —
    ``amax`` over the head dim, ``qmax = 2^(bits-1) - 1``, round-to-nearest,
    clip. ``eff_bits >= 16`` is an exact passthrough (`jnp.where` with the
    f32 round-trip of ``x``), which is what pins a critical-class profile
    row of all-16 entries token-identical to the no-policy baseline.
    """
    eff = jnp.asarray(eff_bits, jnp.int32)
    qmax = jnp.exp2(jnp.minimum(eff, 15).astype(jnp.float32) - 1.0) - 1.0
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-9) / qmax
    fq = jnp.clip(jnp.round(xf / scale), -qmax, qmax) * scale
    return jnp.where(eff >= 16, xf, fq).astype(x.dtype)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: jax.Array | int | None = None,
                  q_offset: jax.Array | int = 0,
                  block_k: int = 512,
                  unroll: bool = False,
                  kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Blockwise GQA attention.

    q ``[B, S, H, D]``; k/v ``[B, Skv, Hkv, D]``; returns ``[B, S, H, D]``.
    ``window``: traced or static int; positions further back than ``window``
    are masked (full attention when ``window >= Skv``). ``q_offset`` shifts
    query positions (prefill continuation). ``kv_valid`` ``[B, Skv]`` bool
    masks per-row invalid keys (left-pad slots of ragged batches), exactly
    like the block-pad index check masks the block-padding keys.
    """
    b, s, h, d = q.shape
    _, skv, hkv, _ = k.shape
    assert h % hkv == 0
    hg = h // hkv
    bk = min(block_k, skv)
    # pad kv to a block multiple; padded keys are masked by the index check
    pad = (-skv) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_blk = (skv + pad) // bk
    kvv_blocks = None
    if kv_valid is not None:
        kvv = jnp.pad(kv_valid, ((0, 0), (0, pad))) if pad else kv_valid
        kvv_blocks = kvv.reshape(b, n_blk, bk).transpose(1, 0, 2)  # [n_blk,B,bk]

    # bf16 until the score einsum (f32 accumulation preserved via
    # preferred_element_type): the S-resharding permutes then move half the
    # bytes (§Perf iteration 3)
    qh = (q * (d ** -0.5)).astype(q.dtype).reshape(b, s, hkv, hg, d)
    qh = qh.transpose(0, 2, 3, 1, 4)                     # [B, Hkv, Hg, S, D]
    # sequence-sharded attention compute: S over "tp" (GQA head counts rarely
    # divide the model axis); KV replicated across the s-shards (§Perf iter 1)
    qh = constrain(qh, "dp", None, None, "tp", None)
    kb = k.transpose(0, 2, 1, 3).reshape(b, hkv, n_blk, bk, d)
    vb = v.transpose(0, 2, 1, 3).reshape(b, hkv, n_blk, bk, d)
    kb = constrain(kb, "dp", None, None, None, None)
    vb = constrain(vb, "dp", None, None, None, None)

    win = jnp.asarray(skv + s if window is None else window, jnp.int32)
    qpos = jnp.asarray(q_offset, jnp.int32) + jnp.arange(s, dtype=jnp.int32)

    def body(carry, blk):
        m, l, acc = carry
        if kvv_blocks is None:
            kblk, vblk, j0 = blk                          # [B,Hkv,bk,D], scalar
            kvb = None
        else:
            kblk, vblk, j0, kvb = blk                     # kvb [B, bk]
        scores = jnp.einsum("bkgsd,bkud->bkgsu", qh, kblk.astype(qh.dtype),
                            preferred_element_type=jnp.float32)
        jpos = j0 + jnp.arange(bk, dtype=jnp.int32)       # global kv indices
        valid = jpos[None, :] < skv                       # [1, bk] (pad mask)
        if causal:
            keep = (jpos[None, :] <= qpos[:, None]) & \
                   (qpos[:, None] - jpos[None, :] < win) & valid
        else:
            keep = jnp.broadcast_to(valid, (s, bk))
        if kvb is not None:                               # per-row ragged mask
            keep = keep[None, :, :] & kvb[:, None, :]     # [B, s, bk]
            scores = jnp.where(keep[:, None, None], scores, NEG_INF)
        else:
            scores = jnp.where(keep[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bkgsu,bkud->bkgsd", p,
                                           vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = constrain(jnp.full((b, hkv, hg, s, 1), NEG_INF, jnp.float32),
                   "dp", None, None, "tp", None)
    l0 = constrain(jnp.zeros((b, hkv, hg, s, 1), jnp.float32),
                   "dp", None, None, "tp", None)
    a0 = constrain(jnp.zeros((b, hkv, hg, s, d), jnp.float32),
                   "dp", None, None, "tp", None)
    j0s = jnp.arange(n_blk, dtype=jnp.int32) * bk
    xs = (kb.transpose(2, 0, 1, 3, 4), vb.transpose(2, 0, 1, 3, 4), j0s)
    if kvv_blocks is not None:
        xs = xs + (kvv_blocks,)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs,
                                  unroll=n_blk if unroll else 1)
    # cast before the transpose/reshape so the S→residual reshard moves bf16
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)


def swa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int, block_q: int = 512,
                  q_offset: int = 0,
                  kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Sliding-window attention with **block skipping** (§Perf iteration):
    each q block only touches the ``window + block_q`` keys it can see, so
    FLOPs scale with ``S·(window+bq)`` instead of ``S²`` (21× at S=32k,
    w=1024). Requires a *static* window (architectural, not profile-driven).

    q ``[B, S, H, D]``, k/v ``[B, S, Hkv, D]`` (self-attention lengths equal).
    ``kv_valid`` ``[B, S]`` bool masks per-row left-pad keys (ragged batches).
    """
    b, s, h, d = q.shape
    _, skv, hkv, _ = k.shape
    assert s == skv and q_offset == 0, "swa path is self-attention prefill"
    hg = h // hkv
    bq = min(block_q, s)
    pad_q = (-s) % bq
    nq = (s + pad_q) // bq
    w = window
    width = w + bq                     # static kv slice per q block

    qh = (q.astype(jnp.float32) * d ** -0.5)
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    qh = qh.reshape(b, nq, bq, hkv, hg, d).transpose(1, 0, 3, 4, 2, 5)
    # [nq, B, Hkv, Hg, bq, D]
    qh = constrain(qh, None, "dp", None, None, "tp", None)

    # left-pad keys by `w` so block i's visible range starts at index i·bq
    kp = jnp.pad(k.astype(jnp.float32), ((0, 0), (w, pad_q), (0, 0), (0, 0)))
    vp = jnp.pad(v.astype(jnp.float32), ((0, 0), (w, pad_q), (0, 0), (0, 0)))
    kp = constrain(kp, "dp", None, None, None)
    vp = constrain(vp, "dp", None, None, None)
    kvp = (None if kv_valid is None
           else jnp.pad(kv_valid, ((0, 0), (w, pad_q))))  # pads are invalid

    def one_block(i, q_blk):
        ks = jax.lax.dynamic_slice_in_dim(kp, i * bq, width, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, i * bq, width, axis=1)
        ks = ks.transpose(0, 2, 1, 3)                    # [B, Hkv, W, D]
        vs = vs.transpose(0, 2, 1, 3)
        scores = jnp.einsum("bkgsd,bkud->bkgsu", q_blk, ks)
        qpos = i * bq + jnp.arange(bq, dtype=jnp.int32)  # global q indices
        jpos = i * bq - w + jnp.arange(width, dtype=jnp.int32)
        keep = ((jpos[None, :] >= 0) & (jpos[None, :] <= qpos[:, None])
                & (qpos[:, None] - jpos[None, :] < w)
                & (qpos[:, None] < s) & (jpos[None, :] < s))
        if kvp is not None:                              # per-row ragged mask
            kvs = jax.lax.dynamic_slice_in_dim(kvp, i * bq, width, axis=1)
            scores = jnp.where(keep[None, None, None]
                               & kvs[:, None, None, None, :], scores, NEG_INF)
        else:
            scores = jnp.where(keep[None, None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bkgsu,bkud->bkgsd", p, vs)

    out = jax.lax.map(lambda iq: one_block(iq[0], iq[1]),
                      (jnp.arange(nq, dtype=jnp.int32), qh))
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, (s + pad_q), h, d)
    return out[:, :s].astype(q.dtype)


# ---------------------------------------------------------------------------
# decode path with KV cache (ring buffer for SWA, optional int8 storage)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Per-layer-stacked KV cache.

    ``k``/``v``: ``[B, S_slots, Hkv, D]`` — bf16; int8 when 8-bit quantized;
    int4 packs two values per byte along D (``[B, S_slots, Hkv, D/2]``,
    ``bits`` static field = 4). ``k_scale``/``v_scale`` are per ``[B, Hkv]``
    dequant scales. ``token_idx``: ``[B, S_slots]`` absolute token index per
    slot, −1 = empty (doubles as the ring-buffer validity mask).
    """

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array
    v_scale: jax.Array
    token_idx: jax.Array
    bits: int = 16  # static (pytree aux)


# `bits` must be aux data (static), not a traced leaf; keyed registration
# keeps the "kv/k"-style paths the sharding rules match on.
jax.tree_util.register_pytree_with_keys(
    KVCache,
    lambda c: ([(jax.tree_util.GetAttrKey(n), getattr(c, n))
                for n in ("k", "v", "k_scale", "v_scale", "token_idx")],
               (c.bits,)),
    lambda aux, ch: KVCache(*ch, bits=aux[0]),
)


def init_kv_cache(batch: int, slots: int, hkv: int, d: int, *,
                  bits: int = 16, dtype=jnp.bfloat16) -> KVCache:
    if bits == 4:
        assert d % 2 == 0
        shape = (batch, slots, hkv, d // 2)
        cdt = jnp.int8
    else:
        shape = (batch, slots, hkv, d)
        cdt = jnp.int8 if bits == 8 else dtype
    return KVCache(
        k=jnp.zeros(shape, cdt),
        v=jnp.zeros(shape, cdt),
        k_scale=jnp.ones((batch, hkv), jnp.float32),
        v_scale=jnp.ones((batch, hkv), jnp.float32),
        token_idx=jnp.full((batch, slots), -1, jnp.int32),
        bits=bits,
    )


def _quantize_kv(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Quantize new K/V rows onto the cache's running per-(B,Hkv) int grid
    (int4 packed two-per-byte along D)."""
    from repro.core.qtypes import pack_int4
    s = scale[:, None, :, None]
    qmax = 127 if bits == 8 else 7
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -qmax, qmax)
    if bits == 4:
        return pack_int4(q.astype(jnp.int8))
    return q.astype(jnp.int8)


def _dequantize_kv(data: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    from repro.core.qtypes import unpack_int4
    q = unpack_int4(data) if bits == 4 else data
    return q.astype(jnp.float32) * scale[:, None, :, None]


def _kv_step_quantize(cache, k_new: jax.Array, v_new: jax.Array):
    """Decode-step scale update + row quantization, shared by the contiguous
    and paged cache writers — they must stay bit-identical (the paged
    cache's token-identity to the contiguous path rides on this block), so
    it exists exactly once. Returns ``(k_scale, v_scale, k_row, v_row)``.

    Int caches keep a running max-abs scale (monotone → previously written
    rows stay valid); bf16 caches just cast.
    """
    if cache.bits in (4, 8):
        qmax = 127.0 if cache.bits == 8 else 7.0
        k_amax = jnp.max(jnp.abs(k_new.astype(jnp.float32)), axis=(1, 3))
        v_amax = jnp.max(jnp.abs(v_new.astype(jnp.float32)), axis=(1, 3))
        k_scale = jnp.maximum(cache.k_scale, k_amax / qmax + 1e-9)
        v_scale = jnp.maximum(cache.v_scale, v_amax / qmax + 1e-9)
        k_row = _quantize_kv(k_new, k_scale, cache.bits)[:, 0]
        v_row = _quantize_kv(v_new, v_scale, cache.bits)[:, 0]
    else:
        k_scale, v_scale = cache.k_scale, cache.v_scale
        k_row = k_new[:, 0].astype(cache.k.dtype)
        v_row = v_new[:, 0].astype(cache.v.dtype)
    return k_scale, v_scale, k_row, v_row


def _kv_window_quantize(cache, k_new: jax.Array, v_new: jax.Array):
    """W-token generalization of :func:`_kv_step_quantize` for speculative
    draft/verify windows. ``k_new``/``v_new`` are ``[B, W, Hkv, D]``.

    Int caches get a per-position **scale ladder** ``[B, W, Hkv]``:
    ``ladder[:, j]`` is exactly the running-max scale the greedy stepwise
    path would hold *after* folding position ``j`` (``cummax`` over the
    window's per-position amax, floored at the cache's current scale — max
    is associative, so this is bit-identical to folding one step at a
    time). Position ``j``'s rows are quantized under ``ladder[:, j]``, and
    the caller commits ``ladder[:, m-1]`` as the cache scale once the
    accepted count ``m`` is known — rejected tail positions never pollute
    the committed scale. Returns ``(k_ladder, v_ladder, k_rows, v_rows)``.

    int4 is not supported here (speculation is gated to kv8/kv16 upstream,
    see ``transformer.supports_speculation``).
    """
    b, w = k_new.shape[:2]
    if cache.bits in (4, 8):
        assert cache.bits == 8, "speculative windows require kv8/kv16"
        qmax = 127.0
        k_amax = jnp.max(jnp.abs(k_new.astype(jnp.float32)), axis=3)
        v_amax = jnp.max(jnp.abs(v_new.astype(jnp.float32)), axis=3)
        k_lad = jnp.maximum(cache.k_scale[:, None],
                            jax.lax.cummax(k_amax / qmax + 1e-9, axis=1))
        v_lad = jnp.maximum(cache.v_scale[:, None],
                            jax.lax.cummax(v_amax / qmax + 1e-9, axis=1))

        def quant(x, lad):
            q = jnp.round(x.astype(jnp.float32) / lad[..., None])
            return jnp.clip(q, -qmax, qmax).astype(jnp.int8)

        k_rows, v_rows = quant(k_new, k_lad), quant(v_new, v_lad)
    else:
        k_lad = jnp.broadcast_to(cache.k_scale[:, None],
                                 (b, w) + cache.k_scale.shape[1:])
        v_lad = jnp.broadcast_to(cache.v_scale[:, None],
                                 (b, w) + cache.v_scale.shape[1:])
        k_rows = k_new.astype(cache.k.dtype)
        v_rows = v_new.astype(cache.v.dtype)
    return k_lad, v_lad, k_rows, v_rows


def update_kv_cache_window(cache: KVCache, k_new: jax.Array,
                           v_new: jax.Array, pos: jax.Array):
    """Write a W-token draft/verify window at ring slots
    ``(pos + j) % slots`` for ``j in [0, W)``.

    The cache's committed ``k_scale``/``v_scale`` are left **unchanged** —
    the caller commits the per-position ladder entry of the last *accepted*
    position after the verify pass (rollback-free: rejected tail slots hold
    junk that the next window's write span always covers before any query
    reads it). Returns ``(cache', k_ladder, v_ladder)``.
    """
    b, slots = cache.token_idx.shape
    w = k_new.shape[1]
    qpos = pos[:, None] + jnp.arange(w, dtype=pos.dtype)[None]   # [B, W]
    slot = (qpos % slots).astype(jnp.int32)
    k_lad, v_lad, k_rows, v_rows = _kv_window_quantize(cache, k_new, v_new)
    bidx = jnp.arange(b)[:, None]
    new = KVCache(
        k=cache.k.at[bidx, slot].set(k_rows),
        v=cache.v.at[bidx, slot].set(v_rows),
        k_scale=cache.k_scale,
        v_scale=cache.v_scale,
        token_idx=cache.token_idx.at[bidx, slot].set(qpos.astype(jnp.int32)),
        bits=cache.bits,
    )
    return new, k_lad, v_lad


def update_kv_cache(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                    pos: jax.Array) -> KVCache:
    """Write one decode step (``k_new [B, 1, Hkv, D]``) at ring slot
    ``pos % slots``; updates running scales for int caches on the fly."""
    b, slots = cache.token_idx.shape
    slot = (pos % slots).astype(jnp.int32)                 # [B]
    k_scale, v_scale, k_row, v_row = _kv_step_quantize(cache, k_new, v_new)
    bidx = jnp.arange(b)
    return KVCache(
        k=cache.k.at[bidx, slot].set(k_row),
        v=cache.v.at[bidx, slot].set(v_row),
        k_scale=k_scale,
        v_scale=v_scale,
        token_idx=cache.token_idx.at[bidx, slot].set(pos.astype(jnp.int32)),
        bits=cache.bits,
    )


# ---------------------------------------------------------------------------
# paged KV cache: global block pool + per-row block tables (vLLM-style)
# ---------------------------------------------------------------------------

class PagedKVCache(NamedTuple):
    """Per-layer-stacked *paged* KV cache: a global pool of fixed-size blocks.

    Instead of reserving a contiguous ``[B, S_slots]`` row per slot, K/V live
    in a shared pool of ``n_blocks`` physical blocks of ``block_size`` tokens
    each, and every pool row maps its *logical* blocks onto physical ones
    through ``block_table`` — an int32 array, i.e. **data**, so remapping rows
    at admission/retirement never retraces or recompiles anything.

    ``k``/``v``: ``[n_blocks, bs, Hkv, D]`` (int8 when 8-bit quantized; int4
    packs two values per byte along D). ``token_idx``: ``[n_blocks, bs]``
    absolute token index per pool slot, −1 = empty — the same validity
    sentinel the contiguous :class:`KVCache` uses, so the dense per-row view
    built by :func:`paged_view` drops straight into
    :func:`decode_attention`. ``k_scale``/``v_scale`` stay per *row*
    (``[B, Hkv]``), carrying the exact running-max semantics of the
    contiguous cache — what keeps paged decode bit-identical to it at int KV
    precisions. ``block_table``: ``[B, n_lblk]``; entries ``>= n_blocks``
    (out of bounds) mean "unmapped" — reads of them fill with empty slots and
    writes to them are dropped, which is both the free-row representation and
    the copy-on-write guard for shared prefix blocks.
    """

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array
    v_scale: jax.Array
    token_idx: jax.Array
    block_table: jax.Array
    bits: int = 16  # static (pytree aux)


jax.tree_util.register_pytree_with_keys(
    PagedKVCache,
    lambda c: ([(jax.tree_util.GetAttrKey(n), getattr(c, n))
                for n in ("k", "v", "k_scale", "v_scale", "token_idx",
                          "block_table")],
               (c.bits,)),
    lambda aux, ch: PagedKVCache(*ch, bits=aux[0]),
)


def init_paged_kv_cache(batch: int, n_blocks: int, block_size: int,
                        n_lblk: int, hkv: int, d: int, *,
                        bits: int = 16, dtype=jnp.bfloat16) -> PagedKVCache:
    """Empty pool: ``n_blocks`` physical blocks, every row's table unmapped.

    ``n_lblk`` logical blocks per row bound each row's *virtual* sequence
    length at ``n_lblk * block_size`` slots (the analogue of the contiguous
    cache's ``slots``); the pool is sized independently — that decoupling of
    logical capacity from physical allocation is the entire point.
    """
    if bits == 4:
        assert d % 2 == 0
        shape = (n_blocks, block_size, hkv, d // 2)
        cdt = jnp.int8
    else:
        shape = (n_blocks, block_size, hkv, d)
        cdt = jnp.int8 if bits == 8 else dtype
    return PagedKVCache(
        k=jnp.zeros(shape, cdt),
        v=jnp.zeros(shape, cdt),
        k_scale=jnp.ones((batch, hkv), jnp.float32),
        v_scale=jnp.ones((batch, hkv), jnp.float32),
        token_idx=jnp.full((n_blocks, block_size), -1, jnp.int32),
        block_table=jnp.full((batch, n_lblk), n_blocks, jnp.int32),
        bits=bits,
    )


def paged_view(cache: PagedKVCache) -> KVCache:
    """Dense per-row gather view: ``[B, n_lblk*bs, ...]`` :class:`KVCache`.

    One gather per field, keyed off the block table; unmapped logical blocks
    fill with zeros / ``token_idx`` −1, i.e. *empty* slots, exactly the
    contiguous cache's pad representation (``kv_valid`` masking in attention
    falls out of ``token_idx`` as usual). Because a row's logical block
    ``l`` holds the tokens the contiguous ring would keep at slots
    ``[l*bs, (l+1)*bs)``, the view reconstructs the contiguous layout
    byte-for-byte and :func:`decode_attention` runs on it unchanged — paged
    decode stays token-identical to the contiguous path by construction.
    """
    b, n_lblk = cache.block_table.shape
    bs = cache.k.shape[1]

    def gather(pool, fill):
        g = jnp.take(pool, cache.block_table, axis=0, mode="fill",
                     fill_value=fill)                 # [B, n_lblk, bs, ...]
        return g.reshape(b, n_lblk * bs, *pool.shape[2:])

    return KVCache(
        k=gather(cache.k, 0), v=gather(cache.v, 0),
        k_scale=cache.k_scale, v_scale=cache.v_scale,
        token_idx=gather(cache.token_idx, -1),
        bits=cache.bits,
    )


def update_paged_kv_cache(cache: PagedKVCache, k_new: jax.Array,
                          v_new: jax.Array, pos: jax.Array) -> PagedKVCache:
    """Write one decode step through the block table.

    Virtual ring slot ``pos % (n_lblk*bs)`` resolves to physical block
    ``block_table[row, slot // bs]``, offset ``slot % bs`` — identical
    placement to the contiguous ring, so the gathered view stays
    bit-identical. Rows whose mapping is unmapped (retired rows whose table
    was cleared, never-admitted free rows) scatter with ``mode="drop"`` —
    a dead row can never write into a block that has been handed to another
    request. Scale updates share :func:`update_kv_cache`'s code exactly.
    """
    b, n_lblk = cache.block_table.shape
    bs = cache.k.shape[1]
    slot = (pos % (n_lblk * bs)).astype(jnp.int32)            # [B] virtual
    phys = jnp.take_along_axis(cache.block_table,
                               (slot // bs)[:, None], axis=1)[:, 0]
    off = slot % bs
    k_scale, v_scale, k_row, v_row = _kv_step_quantize(cache, k_new, v_new)
    return PagedKVCache(
        k=cache.k.at[phys, off].set(k_row, mode="drop"),
        v=cache.v.at[phys, off].set(v_row, mode="drop"),
        k_scale=k_scale, v_scale=v_scale,
        token_idx=cache.token_idx.at[phys, off].set(pos.astype(jnp.int32),
                                                    mode="drop"),
        block_table=cache.block_table,
        bits=cache.bits,
    )


def update_paged_kv_cache_window(cache: PagedKVCache, k_new: jax.Array,
                                 v_new: jax.Array, pos: jax.Array):
    """Paged counterpart of :func:`update_kv_cache_window`: scatter a
    W-token window through the block table with ``mode="drop"``.

    Placement matches :func:`update_paged_kv_cache` per position, so the
    gathered view stays bit-identical to the contiguous window writer.
    Two drop guards protect the pool: unmapped table entries (dead /
    CoW-guarded rows) drop as usual, and window positions past the row's
    virtual capacity are redirected to the unmapped sentinel instead of
    ring-wrapping — a speculative tail must never wrap onto logical block
    0, which may be a *shared* prefix master. Returns
    ``(cache', k_ladder, v_ladder)`` with committed scales unchanged.
    """
    b, n_lblk = cache.block_table.shape
    n_blocks, bs = cache.k.shape[0], cache.k.shape[1]
    w = k_new.shape[1]
    cap = n_lblk * bs
    qpos = pos[:, None] + jnp.arange(w, dtype=pos.dtype)[None]   # [B, W]
    slot = (qpos % cap).astype(jnp.int32)
    phys = jnp.take_along_axis(cache.block_table, slot // bs, axis=1)
    phys = jnp.where(qpos < cap, phys, n_blocks)        # no wrap onto masters
    off = slot % bs
    k_lad, v_lad, k_rows, v_rows = _kv_window_quantize(cache, k_new, v_new)
    new = PagedKVCache(
        k=cache.k.at[phys, off].set(k_rows, mode="drop"),
        v=cache.v.at[phys, off].set(v_rows, mode="drop"),
        k_scale=cache.k_scale, v_scale=cache.v_scale,
        token_idx=cache.token_idx.at[phys, off].set(qpos.astype(jnp.int32),
                                                    mode="drop"),
        block_table=cache.block_table,
        bits=cache.bits,
    )
    return new, k_lad, v_lad


def paged_decode_attention(q: jax.Array, cache: PagedKVCache, pos: jax.Array,
                           *, window: int | None = None,
                           interpret: bool | None = None) -> jax.Array:
    """One-token attention **in place** against the paged block pool.

    The Pallas serving hot path: no ``[B, n_lblk*bs]`` gather view is ever
    materialized — the kernel's BlockSpec index maps resolve each logical
    block through ``cache.block_table`` (scalar-prefetched) and stream only
    the mapped physical blocks. Masking falls out of the pool's per-slot
    ``token_idx`` exactly as in :func:`decode_attention`, so ring wraparound
    and unmapped (free/retired/CoW-guarded) table entries are safe by the
    same argument. q ``[B, 1, H, D]`` → ``[B, 1, H, D]``; ``pos [B]`` is the
    current absolute position. ``window`` must be static (``None`` / ``>=
    slots`` = full attention). ``interpret=None`` auto-selects interpret
    mode off-TPU (the CPU oracle path); :func:`paged_view` +
    :func:`decode_attention` remains the gather-backend oracle.
    """
    from repro.kernels.paged_attention import paged_attention_pallas
    b, _, h, d = q.shape
    _, bs, hkv, _ = cache.k.shape
    hg = h // hkv
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    slots = cache.block_table.shape[1] * bs
    win = 0 if window is None or int(window) > slots else int(window)
    out = paged_attention_pallas(
        q.reshape(b, hkv, hg, d), cache.k, cache.v,
        cache.k_scale, cache.v_scale, cache.token_idx, cache.block_table,
        pos, bits=cache.bits, window=win, interpret=bool(interpret))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def paged_decode_attention_window(q: jax.Array, cache: PagedKVCache,
                                  pos: jax.Array, k_ladder: jax.Array,
                                  v_ladder: jax.Array, *,
                                  window: int | None = None,
                                  interpret: bool | None = None) -> jax.Array:
    """W-query speculative window attention **in place** against the pool.

    The multi-query analogue of :func:`paged_decode_attention`: q
    ``[B, W, H, D]`` with query ``j`` at absolute position ``pos + j`` and
    per-query int8 scale ladders ``[B, W, Hkv]`` (see
    :func:`decode_attention_window` for the ladder semantics). Streams only
    mapped physical blocks via the scalar-prefetched block table — still no
    dense gather view.
    """
    from repro.kernels.paged_attention import paged_attention_pallas_multi
    b, w, h, d = q.shape
    _, bs, hkv, _ = cache.k.shape
    hg = h // hkv
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    slots = cache.block_table.shape[1] * bs
    win = 0 if window is None or int(window) > slots else int(window)
    out = paged_attention_pallas_multi(
        q.reshape(b, w, hkv, hg, d), cache.k, cache.v,
        k_ladder, v_ladder, cache.token_idx, cache.block_table,
        pos, bits=cache.bits, window=win, interpret=bool(interpret))
    return out.reshape(b, w, h, d).astype(q.dtype)


def prefix_attention(q: jax.Array, k_pre: jax.Array, v_pre: jax.Array,
                     k_suf: jax.Array, v_suf: jax.Array, *,
                     positions: jax.Array, prefix_len: jax.Array,
                     suffix_valid: jax.Array) -> jax.Array:
    """Continuation-prefill attention: suffix queries vs [prefix ++ suffix] keys.

    The shared-prefix admission path prefills only the *suffix* of a prompt
    whose prefix KV already exists; each suffix query must still attend over
    the full causal history. ``q``/``k_suf``/``v_suf`` are the suffix
    projections (``[B, S, H|Hkv, D]``, rows left-padded); ``k_pre``/``v_pre``
    ``[B, Pp, Hkv, D]`` hold the prefix keys/values (zero-padded past
    ``prefix_len[row]``); ``positions [B, S]`` are the suffix tokens'
    absolute positions (``prefix_len + local index``; negative on pads) and
    ``suffix_valid [B, S]`` masks the pads. Prefix keys sit at absolute
    positions ``0..prefix_len−1`` by construction — the logical-position
    invariant that makes a prefix shareable at all. Admission waves are small
    (``S``, ``Pp`` ≤ a few hundred), so a dense masked softmax is used rather
    than the blockwise online form. Full causal attention only — sliding-
    window stacks don't take the shared-prefix path.
    """
    b, s, h, d = q.shape
    _, pp, hkv, _ = k_pre.shape
    hg = h // hkv
    qh = (q.astype(jnp.float32) * d ** -0.5).reshape(b, s, hkv, hg, d)
    qh = qh.transpose(0, 2, 3, 1, 4)                      # [B, Hkv, Hg, S, D]
    kc = jnp.concatenate([k_pre, k_suf], axis=1).astype(jnp.float32)
    vc = jnp.concatenate([v_pre, v_suf], axis=1).astype(jnp.float32)
    kc = kc.transpose(0, 2, 1, 3)                         # [B, Hkv, Pp+S, D]
    vc = vc.transpose(0, 2, 1, 3)
    scores = jnp.einsum("bkgsd,bkud->bkgsu", qh, kc)
    ppos = jnp.arange(pp, dtype=jnp.int32)
    keep_pre = (ppos[None, None, :] < prefix_len[:, None, None]) & \
               (ppos[None, None, :] <= positions[:, :, None])    # [B, S, Pp]
    kqpos = positions                                      # suffix key pos
    keep_suf = suffix_valid[:, None, :] & \
               (kqpos[:, None, :] <= positions[:, :, None])      # [B, S, S]
    keep = jnp.concatenate([keep_pre, keep_suf], axis=-1)  # [B, S, Pp+S]
    scores = jnp.where(keep[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsu,bkud->bkgsd", p, vc)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d).astype(q.dtype)


def decode_attention(q: jax.Array, cache: KVCache, pos: jax.Array, *,
                     window: jax.Array | int | None = None) -> jax.Array:
    """One-token attention vs the cache. q ``[B, 1, H, D]`` → ``[B, 1, H, D]``.

    ``pos [B]`` is the current absolute position (the new token's index);
    masking uses the per-slot ``token_idx`` so ring-buffer wraparound is safe.
    """
    b, _, h, d = q.shape
    _, slots, hkv, _ = cache.k.shape
    hg = h // hkv
    qh = (q.astype(jnp.float32) * d ** -0.5).reshape(b, hkv, hg, d)
    if cache.bits == 8:
        # int8 fast path: contract on the int grid and fold the per-(B,Hkv)
        # dequant scale into the result — the same layout/order the Pallas
        # ``qkv_attention`` kernel uses, and no cache-sized scaled temporary
        # inside the decode scan.
        scores = jnp.einsum("bkgd,bskd->bkgs", qh, cache.k.astype(jnp.float32))
        scores = scores * cache.k_scale[:, :, None, None]
    else:
        if cache.bits == 4:
            kf = _dequantize_kv(cache.k, cache.k_scale, cache.bits)
        else:
            kf = cache.k.astype(jnp.float32)
        scores = jnp.einsum("bkgd,bskd->bkgs", qh, kf)     # [B,Hkv,Hg,slots]
    win = jnp.asarray(slots + 1 if window is None else window, jnp.int32)
    tidx = cache.token_idx                                  # [B, slots]
    keep = (tidx >= 0) & (tidx <= pos[:, None]) & (pos[:, None] - tidx < win)
    scores = jnp.where(keep[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    if cache.bits == 8:
        out = jnp.einsum("bkgs,bskd->bkgd", p, cache.v.astype(jnp.float32))
        out = out * cache.v_scale[:, :, None, None]
    else:
        vf = (_dequantize_kv(cache.v, cache.v_scale, cache.bits)
              if cache.bits == 4 else cache.v.astype(jnp.float32))
        out = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def decode_attention_window(q: jax.Array, cache: KVCache, pos: jax.Array,
                            k_ladder: jax.Array, v_ladder: jax.Array, *,
                            window: jax.Array | int | None = None
                            ) -> jax.Array:
    """W-query attention vs the cache for a speculative draft/verify window.

    q ``[B, W, H, D]`` → ``[B, W, H, D]``; query ``j`` sits at absolute
    position ``pos + j`` and attends causally with the same per-slot
    ``token_idx`` mask as :func:`decode_attention`, restricted to
    ``token_idx <= pos + j``. Int8 caches fold the per-position scale
    **ladder** (``[B, W, Hkv]``): query ``j`` dequantizes every entry under
    ``ladder[:, j]`` — exactly the current-scale fold the greedy stepwise
    path applies after writing position ``j`` — which is what keeps a
    W-wide verify pass bit-identical to W greedy steps.
    """
    b, w, h, d = q.shape
    _, slots, hkv, _ = cache.k.shape
    hg = h // hkv
    qh = (q.astype(jnp.float32) * d ** -0.5).reshape(b, w, hkv, hg, d)
    if cache.bits == 8:
        scores = jnp.einsum("bwkgd,bskd->bwkgs", qh,
                            cache.k.astype(jnp.float32))
        scores = scores * k_ladder[..., None, None]
    else:
        assert cache.bits == 16, "speculative windows require kv8/kv16"
        scores = jnp.einsum("bwkgd,bskd->bwkgs", qh,
                            cache.k.astype(jnp.float32))
    qpos = pos[:, None] + jnp.arange(w, dtype=pos.dtype)[None]    # [B, W]
    win = jnp.asarray(slots + 1 if window is None else window, jnp.int32)
    tidx = cache.token_idx                                        # [B, slots]
    keep = ((tidx[:, None] >= 0) & (tidx[:, None] <= qpos[:, :, None])
            & (qpos[:, :, None] - tidx[:, None] < win))           # [B, W, S]
    scores = jnp.where(keep[:, :, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bwkgs,bskd->bwkgd", p, cache.v.astype(jnp.float32))
    if cache.bits == 8:
        out = out * v_ladder[..., None, None]
    return out.reshape(b, w, h, d).astype(q.dtype)

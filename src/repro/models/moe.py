"""Fine-grained Mixture-of-Experts (DeepSeekMoE / Qwen2-MoE style).

Shared experts (always-on dense FFNs) + routed experts with top-k gating and
**grouped capacity dispatch**:

* tokens are reshaped into ``G`` groups (aligned with the data-parallel axis)
  so routing/cumsum/scatter stay group-local — vmapped, no cross-shard prefix
  sums (GShard's grouping, DESIGN §8.4);
* each (group, expert) has capacity ``C = ceil(T_g·k/E · cf)``; assignments are
  scatter/gathered through an ``[G, E, C, d]`` buffer — compute is
  ``E·C``-bounded (≈ active-FLOPs), never the ``O(T·E·C)`` one-hot einsum;
* expert weights are stacked ``[E, d, f]`` so expert parallelism is one
  sharding rule (E over the ``model`` axis).

Router: softmax gating with top-k renormalization + GShard load-balance aux
loss (+ z-loss). All expert matmuls run through the quantized path with the
``expert_in``/``expert_out``/``shared_*``/``router`` quant sites.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import fake_quant_dynamic
from repro.runtime import compute_dtype
from .layers import SIGNED_SYM, init_linear, qlinear
from .pshard import constrain

__all__ = ["MoEConfig", "init_moe", "moe_ffn"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    n_shared: int
    d_expert: int              # per-expert FFN width (fine-grained)
    capacity_factor: float = 1.25
    groups: int = 16           # dispatch groups; align with the data axis
    aux_coef: float = 0.01
    z_coef: float = 1e-3


def init_moe(key: jax.Array, d_model: int, cfg: MoEConfig) -> dict:
    kr, ke1, ke2, ks1, ks2 = jax.random.split(key, 5)
    E, f = cfg.n_routed, cfg.d_expert
    s = 1.0 / np.sqrt(d_model)
    p = {
        "router": init_linear(kr, d_model, E, scale=0.02),
        # stacked routed experts, gated FFN: w_in [E, d, 2f], w_out [E, f, d]
        "w_in": jax.random.normal(ke1, (E, d_model, 2 * f), jnp.float32) * s,
        "w_out": jax.random.normal(ke2, (E, f, d_model), jnp.float32) / np.sqrt(f),
    }
    if cfg.n_shared:
        fs = f * cfg.n_shared
        p["shared_in"] = init_linear(ks1, d_model, 2 * fs)
        p["shared_out"] = init_linear(ks2, fs, d_model)
    return p


def _qmat(w, bits_aw: jax.Array) -> jax.Array:
    from repro.core.quantizers import QTensor, dequantize
    if isinstance(w, QTensor):  # native deployment path
        return dequantize(w, compute_dtype())
    return fake_quant_dynamic(w, bits_aw[1], SIGNED_SYM).astype(compute_dtype())


def moe_ffn(params: dict, x: jax.Array, bits: dict, cfg: MoEConfig,
            token_valid: Optional[jax.Array] = None):
    """x ``[B, S, d]`` → (y ``[B, S, d]``, aux_losses dict).

    ``bits`` maps site → int32[2]: ``router``, ``expert_in``, ``expert_out``,
    ``shared_in``, ``shared_out``. ``token_valid`` ``[B, S]`` bool (serving):
    invalid tokens (batch-pad rows / left-pad slots / retired decode rows) are
    dropped from the capacity dispatch — they neither advance the per-expert
    cumsum ranks nor occupy buffer slots, so expert capacity is effectively
    allocated from the *live* tokens only and pad rows can never displace a
    real token's routing.
    """
    b, s, d = x.shape
    E, k, G = cfg.n_routed, cfg.top_k, cfg.groups
    t = b * s
    assert t % G == 0, f"tokens {t} must divide groups {G}"
    tg = t // G
    cap = int(np.ceil(tg * k / E * cfg.capacity_factor))

    xg = x.reshape(G, tg, d)

    # ---- router (quantized like any other site) ----
    logits = qlinear(params["router"], xg, bits["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # [G, tg, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # [G, tg, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # GShard aux: mean prob per expert × fraction of tokens routed per expert
    me = probs.mean(axis=(0, 1))                             # [E]
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0 / (t * k))
    aux = {"load_balance": E * jnp.sum(me * ce) * cfg.aux_coef,
           "router_z": cfg.z_coef * jnp.mean(
               jax.nn.logsumexp(logits, axis=-1) ** 2)}

    # ---- group-local capacity dispatch (vmapped over G) ----
    def dispatch(xg_, idx_, val_, tv_):
        flat_e = idx_.reshape(-1)                            # [tg*k]
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [tg*k, E]
        if tv_ is not None:
            flat_tv = jnp.repeat(tv_, k)                     # [tg*k]
            onehot = onehot * flat_tv[:, None].astype(jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1                 # rank within expert
        pos_in_e = jnp.sum(pos * onehot, axis=-1)            # [tg*k]
        keep = pos_in_e < cap
        if tv_ is not None:
            keep = keep & flat_tv
        buf_idx = jnp.where(keep, flat_e * cap + pos_in_e, E * cap)  # overflow row
        x_rep = jnp.repeat(xg_, k, axis=0)                   # [tg*k, d]
        buf = jnp.zeros((E * cap + 1, d), xg_.dtype).at[buf_idx].set(x_rep)
        return buf[:-1].reshape(E, cap, d), buf_idx, keep

    if token_valid is not None:
        tvg = token_valid.reshape(G, tg)
        buf, buf_idx, keep = jax.vmap(dispatch)(xg, gate_idx, gate_vals, tvg)
    else:
        buf, buf_idx, keep = jax.vmap(
            lambda a, b_, c: dispatch(a, b_, c, None))(xg, gate_idx, gate_vals)
    # buf: [G, E, cap, d] — groups on dp, experts on tp (EP); falls back to
    # capacity-sharding when E doesn't divide the model axis (e.g. 60 experts)
    buf = constrain(buf, "dp", "tp", None, None)

    # ---- expert compute (batched matmul; E shards over the model axis) ----
    cdt = compute_dtype()
    a_bits_in = bits["expert_in"][0]
    h = fake_quant_dynamic(buf, a_bits_in, SIGNED_SYM).astype(cdt)
    w_in = _qmat(params["w_in"], bits["expert_in"])          # [E, d, 2f]
    h = jnp.einsum("gecd,edf->gecf", h, w_in, preferred_element_type=jnp.float32)
    g_, u_ = jnp.split(h, 2, axis=-1)
    h = (jax.nn.silu(g_) * u_).astype(cdt)
    h = fake_quant_dynamic(h, bits["expert_out"][0], SIGNED_SYM).astype(cdt)
    h = constrain(h, "dp", "tp", None, None)
    w_out = _qmat(params["w_out"], bits["expert_out"])       # [E, f, d]
    out_buf = jnp.einsum("gecf,efd->gecd", h, w_out,
                         preferred_element_type=jnp.float32)  # [G, E, cap, d]
    out_buf = constrain(out_buf, "dp", "tp", None, None)

    # ---- combine ----
    def combine(out_buf_, buf_idx_, keep_, val_):
        flat = out_buf_.reshape(E * cap, d)
        flat = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)], axis=0)
        y_rep = flat[jnp.minimum(buf_idx_, E * cap)] * keep_[:, None]
        return (y_rep.reshape(tg, k, d) *
                val_[..., None].astype(flat.dtype)).sum(axis=1)

    y = jax.vmap(combine)(out_buf, buf_idx, keep, gate_vals)  # [G, tg, d]
    y = y.reshape(b, s, d).astype(x.dtype)

    # ---- shared experts (dense path) ----
    if "shared_in" in params:
        hsh = qlinear(params["shared_in"], x, bits["shared_in"])
        gsh, ush = jnp.split(hsh, 2, axis=-1)
        y = y + qlinear(params["shared_out"],
                        jax.nn.silu(gsh) * ush, bits["shared_out"])
    return y, aux

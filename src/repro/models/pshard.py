"""Logical activation-sharding constraints (hillclimb §Perf iteration 1).

GSPMD propagates shardings from weights/inputs, but the reshapes inside
attention / SSD / MoE give it too much freedom: the dry-run baseline shows
multi-TB per-device resharding collectives and partially *replicated* compute
(flops/device ≫ flops/devices). ``constrain`` pins the batch ("dp") and
head/feature ("tp") dims of the hot intermediates.

Model code stays mesh-agnostic: it names logical axes only. The launcher
calls :func:`enable` with the physical mesh (dp = pod+data axes), and
constraints silently no-op when disabled (unit tests, single-device runs) or
when a dim doesn't divide its axis (e.g. 50 Hymba heads on 16-way TP — the
P-dim shards instead where the call site says so).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["enable", "disable", "constrain", "enabled"]

_STATE: dict = {"mesh": None, "dp": None, "tp": None}


def enable(mesh, dp_axes, tp_axis: str = "model") -> None:
    _STATE.update(mesh=mesh, dp=dp_axes, tp=tp_axis)


def disable() -> None:
    _STATE.update(mesh=None, dp=None, tp=None)


def enabled() -> bool:
    return _STATE["mesh"] is not None


def _axis_size(mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """``logical`` entries: "dp" | "tp" | None, one per dim of ``x``."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    entries = []
    for dim, name in zip(x.shape, logical):
        ax = _STATE.get(name) if name else None
        if ax is not None and dim % _axis_size(mesh, ax) == 0 and dim > 1:
            entries.append(ax)
        else:
            entries.append(None)
    if not any(e is not None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))

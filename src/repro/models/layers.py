"""Quantization-aware building blocks.

Every matmul in the model zoo runs through :func:`qlinear` so the paper's
per-layer ``Ax-Wy`` profiles apply uniformly to all ten architectures. Two
execution modes share one parameter layout:

* **fake mode** (QAT / paper-faithful semantics): master weights stay float;
  activations and weights are fake-quantized with *traced* bit-widths
  (``bits_aw`` is data → the merged adaptive engine is branch-free).
* **native mode** (serving): weights are pre-quantized integer carriers
  (:class:`QTensor`); compute dequantizes on the fly (Pallas kernel on TPU,
  jnp reference elsewhere — identical roofline terms).

``bits_aw`` is an int32 ``[2]`` (a_bits, w_bits); bits ≥ 17 = float passthrough.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import (QTensor, dequantize, fake_quant_dynamic,
                                   fake_quant_dynamic_token)
from repro.core.qtypes import QuantSpec
from repro.core.quantizers import quantize_native
from repro.runtime import compute_dtype as _default_compute_dtype

__all__ = [
    "qlinear", "init_linear", "quantize_linear_native",
    "rms_norm", "layer_norm", "init_norm",
    "embed_lookup", "init_embed",
    "SIGNED_SYM",
]

SIGNED_SYM = np.array([1, 0], np.int32)  # fixed (signed, non-symmetric) grid


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------

def init_linear(key: jax.Array, d_in: int, d_out: int, *, bias: bool = False,
                scale: float | None = None, dtype=jnp.float32) -> dict:
    s = (1.0 / np.sqrt(d_in)) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def qlinear(params: dict, x: jax.Array, bits_aw: jax.Array, *,
            compute_dtype=None) -> jax.Array:
    """Quantization-aware linear: fake mode (float master weights) or native
    mode (integer carriers), switched on the parameter layout.

    Fake mode keys: ``w`` [in,out] (+ ``b``). Native keys: ``wq`` (QTensor
    leaves as ``wq_data``/``wq_scale`` + static bits in ``wq_bits``) (+ ``b``).

    Activations quantize **per token** (trailing-axis amax): each row's grid
    depends only on that row, so decode numerics are invariant to batch
    composition and to the speculative verify width (invariant 11). Weights
    keep the per-tensor grid.
    """
    if compute_dtype is None:
        compute_dtype = _default_compute_dtype()
    if "wfq" in params:
        # Decode-scan fast path: the weight image was fake-quanted *once*
        # ahead of the loop (per profile — transformer.prequant_decode_weights)
        # instead of every step. Activations still quantize in-loop (their
        # scale depends on runtime data).
        xq = fake_quant_dynamic_token(x, bits_aw[0], SIGNED_SYM)
        y = jnp.dot(xq.astype(compute_dtype), params["wfq"].astype(compute_dtype),
                    preferred_element_type=jnp.float32)
    elif "w" in params:
        a_bits, w_bits = bits_aw[0], bits_aw[1]
        xq = fake_quant_dynamic_token(x, a_bits, SIGNED_SYM)
        wq = fake_quant_dynamic(params["w"], w_bits, SIGNED_SYM)
        y = jnp.dot(xq.astype(compute_dtype), wq.astype(compute_dtype),
                    preferred_element_type=jnp.float32)
    else:
        # Native: activations still honor the profile's a_bits (bits-as-data);
        # weights are already on their integer grid.
        a_bits = bits_aw[0]
        xq = fake_quant_dynamic_token(x, a_bits, SIGNED_SYM)
        w = dequantize(params["wq"], compute_dtype)
        y = jnp.dot(xq.astype(compute_dtype), w, preferred_element_type=jnp.float32)
    if "b" in params:
        y = y + params["b"].astype(jnp.float32)
    return y.astype(compute_dtype)


def quantize_linear_native(params: dict, w_bits: int = 8) -> dict:
    """Convert a fake-mode linear to native integer storage (deployment)."""
    spec = QuantSpec(bits=w_bits, per_channel=True, channel_axis=-1, po2_scale=False)
    out = {"wq": quantize_native(params["w"], spec)}
    if "b" in params:
        out["b"] = params["b"]
    return out


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(d: int, *, bias: bool = False) -> dict:
    p = {"g": jnp.ones((d,), jnp.float32)}
    if bias:
        p["b"] = jnp.zeros((d,), jnp.float32)
    return p


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["g"]
    return y.astype(x.dtype)


def layer_norm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["g"] + params.get("b", 0.0)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------

def init_embed(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"w": jax.random.normal(key, (vocab, d), jnp.float32).astype(dtype) * 0.02}


def embed_lookup(params: dict, ids: jax.Array, bits_aw: jax.Array,
                 compute_dtype=None) -> jax.Array:
    """Embedding gather with weight-only quantization (a_bits doesn't apply to
    an integer gather — the paper's data approximation acts on the table)."""
    if compute_dtype is None:
        compute_dtype = _default_compute_dtype()
    if "wq" in params:  # native: gather int rows, dequant after (HBM win)
        from repro.core.qtypes import unpack_int4
        qt: QTensor = params["wq"]
        rows = jnp.take(qt.data, ids, axis=0)
        if qt.bits <= 4:
            rows = unpack_int4(rows)
        return (rows.astype(jnp.float32) * qt.scale).astype(compute_dtype)
    if "wfq" in params:  # decode scan: table fake-quanted ahead of the loop
        return jnp.take(params["wfq"].astype(compute_dtype), ids, axis=0)
    w = fake_quant_dynamic(params["w"], bits_aw[1], SIGNED_SYM)
    return jnp.take(w.astype(compute_dtype), ids, axis=0)

"""Mamba2 — State-Space Duality (SSD) block, chunked matmul form + recurrent decode.

The chunked SSD algorithm (Dao & Gu, 2024) is MXU-friendly by construction:
intra-chunk terms are ``[Q, Q]``/``[Q, N]`` matmuls and the inter-chunk
recurrence is a short ``lax.scan`` over ``S/Q`` chunk states — exactly the
compute shape TPUs want, so the paper's GPU-oriented kernels are *adapted*
(DESIGN §2) rather than ported. Decode is the O(1)-state recurrence, which is
what makes the ``long_500k`` cell runnable for SSM/hybrid archs.

Projections run through the quantized path (sites ``ssm_in`` / ``ssm_out``).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import init_linear, qlinear, rms_norm
from .pshard import constrain

__all__ = ["SSMConfig", "init_ssm", "ssd_forward", "ssm_decode_step", "SSMState",
           "init_ssm_state", "ssm_prefill_state"]


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim

    def conv_dim(self, d_model: int) -> int:
        return self.d_inner(d_model) + 2 * self.n_groups * self.d_state


def init_ssm(key: jax.Array, d_model: int, cfg: SSMConfig) -> dict:
    ki, ko, kc, kd = jax.random.split(key, 4)
    di = cfg.d_inner(d_model)
    h = cfg.n_heads(d_model)
    cd = cfg.conv_dim(d_model)
    # in_proj → [z (gate), xBC (conv'd), dt] ; out_proj back to d_model
    return {
        "in_proj": init_linear(ki, d_model, 2 * di + 2 * cfg.n_groups * cfg.d_state + h),
        "out_proj": init_linear(ko, di, d_model),
        "conv_w": jax.random.normal(kc, (cfg.d_conv, cd), jnp.float32) / np.sqrt(cfg.d_conv),
        "conv_b": jnp.zeros((cd,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 1e-2, jnp.float32))),  # softplus⁻¹
        "norm_g": jnp.ones((di,), jnp.float32),
    }


def _split_proj(proj: jax.Array, d_model: int, cfg: SSMConfig):
    di = cfg.d_inner(d_model)
    gn = cfg.n_groups * cfg.d_state
    h = cfg.n_heads(d_model)
    z, xbc, dt = jnp.split(proj, [di, di + di + 2 * gn], axis=-1)
    return z, xbc, dt  # xbc = [x, B, C] pre-conv


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular segment sums: out[..., i, j] = Σ_{j<k<=i} a[..., k]."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, out, -jnp.inf)


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time: xbc [B, S, C], w [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_forward(params: dict, x: jax.Array, bits_in: jax.Array,
                bits_out: jax.Array, cfg: SSMConfig,
                return_final_state: bool = False,
                unroll: bool = False,
                valid: "jax.Array | None" = None):
    """Chunked SSD over a full sequence. x ``[B, S, d_model]`` → same shape.

    Optionally returns the final recurrent state (for prefill → decode
    handoff): ``(h [B, H, P, N], conv_tail [B, K-1, convdim])``.

    ``valid`` ``[B, S]`` bool marks real tokens of a left-padded ragged batch.
    Pad steps must not touch the recurrence: their inputs are zeroed (so the
    causal conv sees the same implicit zero left-context as an unpadded run,
    and the handed-off ``conv_tail`` pads are exactly zero) and their ``dt`` is
    zero-masked (decay ``exp(0)=1`` → state passthrough, zero input
    contribution) — the same trick the chunk padding below already uses.
    """
    bsz, s_real, d_model = x.shape
    if valid is not None:
        x = jnp.where(valid[..., None], x, 0).astype(x.dtype)
    di = cfg.d_inner(d_model)
    h_heads = cfg.n_heads(d_model)
    p_dim = cfg.head_dim
    n = cfg.d_state
    g = cfg.n_groups
    q = min(cfg.chunk, s_real)
    pad = (-s_real) % q
    s = s_real + pad
    nc = s // q

    proj = qlinear(params["in_proj"], x, bits_in)
    z, xbc, dt = _split_proj(proj, d_model, cfg)
    conv_tail = xbc[:, max(0, s_real - (cfg.d_conv - 1)):s_real, :]
    if s_real < cfg.d_conv - 1:
        # short-prompt prefill: left-pad to the fixed [B, K-1, convdim] window
        # so the handed-off SSMState matches init_ssm_state's aval (decode
        # scans carry the state — shapes must be static across steps)
        conv_tail = jnp.pad(conv_tail,
                            ((0, 0), (cfg.d_conv - 1 - s_real, 0), (0, 0)))
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    if pad:  # pad to a chunk multiple; dt is zero-masked there, so the
        # recurrent state passes through padded steps unchanged.
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
        xbc = jnp.pad(xbc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xs, b_, c_ = jnp.split(xbc, [di, di + g * n], axis=-1)

    xh = xs.reshape(bsz, s, h_heads, p_dim).astype(jnp.float32)
    # SSD head counts (24/50) rarely divide the TP axis; shard the head *dim*
    # P instead so the chunk matmuls parallelize (§Perf iteration)
    xh = constrain(xh, "dp", None, None, "tp")
    b_ = b_.reshape(bsz, s, g, n).astype(jnp.float32)
    c_ = c_.reshape(bsz, s, g, n).astype(jnp.float32)
    # broadcast groups → heads
    rep = h_heads // g
    bh = jnp.repeat(b_, rep, axis=2)                     # [B, S, H, N]
    ch = jnp.repeat(c_, rep, axis=2)

    a = -jnp.exp(params["A_log"].astype(jnp.float32))    # [H], negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    if valid is not None:  # ragged rows: pad steps pass the state through
        vmask = valid.astype(jnp.float32)
        if pad:
            vmask = jnp.pad(vmask, ((0, 0), (0, pad)))
        dt = dt * vmask[:, :, None]
    elif pad:  # dt→0 on padded steps: decay=exp(0)=1, input contribution 0
        cmask = (jnp.arange(s) < s_real).astype(jnp.float32)[None, :, None]
        dt = dt * cmask
    da = dt * a                                          # [B, S, H]
    xdt = xh * dt[..., None]                             # dt-weighted input

    # chunk
    def chunked(t):  # [B, S, ...] -> [B, nc, Q, ...]
        return t.reshape(bsz, nc, q, *t.shape[2:])
    xc, bc, cc = chunked(xdt), chunked(bh), chunked(ch)
    dac = chunked(da).transpose(0, 3, 1, 2)              # [B, H, nc, Q]

    # intra-chunk (diagonal blocks)
    l_mat = jnp.exp(_segsum(dac))                        # [B, H, nc, Q, Q]
    l_mat = constrain(l_mat, "dp", None, None, "tp", None)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", cc, bc, l_mat, xc)
    y_diag = constrain(y_diag, "dp", None, "tp", None, None)

    # chunk states and inter-chunk recurrence
    dac_cum = jnp.cumsum(dac, axis=-1)                   # [B, H, nc, Q]
    decay_states = jnp.exp(dac_cum[..., -1:] - dac_cum)  # [B, H, nc, Q]
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", bc, decay_states, xc)
    chunk_decay = jnp.exp(dac_cum[..., -1])              # [B, H, nc]

    def scan_fn(h_prev, inp):
        st, dec = inp                                    # [B,H,P,N], [B,H]
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((bsz, h_heads, p_dim, n), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
        unroll=nc if unroll else 1)
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)           # [B, nc, H, P, N]

    # inter-chunk contribution
    state_decay = jnp.exp(dac_cum)                       # [B, H, nc, Q]
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", cc, h_prevs, state_decay)
    y_off = constrain(y_off, "dp", None, "tp", None, None)

    y = (y_diag + y_off).reshape(bsz, s, h_heads, p_dim)
    y = y + xh * params["D"][None, None, :, None]        # skip
    y = y.reshape(bsz, s, di)[:, :s_real]                # trim chunk padding
    y = y * jax.nn.silu(z[:, :s_real].astype(jnp.float32))  # gate
    y = rms_norm({"g": params["norm_g"]}, y)
    out = qlinear(params["out_proj"], y.astype(x.dtype), bits_out)
    if return_final_state:
        return out, (h_final, conv_tail)
    return out


class SSMState(NamedTuple):
    """Decode-time recurrent state: SSD state + causal-conv tail window."""

    h: jax.Array          # [B, H, P, N] f32
    conv: jax.Array       # [B, K-1, convdim]


def init_ssm_state(batch: int, d_model: int, cfg: SSMConfig) -> SSMState:
    return SSMState(
        h=jnp.zeros((batch, cfg.n_heads(d_model), cfg.head_dim, cfg.d_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim(d_model)), jnp.float32),
    )


def ssm_prefill_state(final_state, batch, d_model, cfg: SSMConfig) -> SSMState:
    h_final, conv_tail = final_state
    return SSMState(h=h_final, conv=conv_tail.astype(jnp.float32))


def ssm_decode_step(params: dict, x: jax.Array, state: SSMState,
                    bits_in: jax.Array, bits_out: jax.Array, cfg: SSMConfig):
    """One-token recurrent step. x ``[B, 1, d_model]`` → (y, new_state)."""
    bsz, _, d_model = x.shape
    di = cfg.d_inner(d_model)
    h_heads = cfg.n_heads(d_model)
    p_dim, n, g = cfg.head_dim, cfg.d_state, cfg.n_groups

    proj = qlinear(params["in_proj"], x, bits_in)[:, 0]   # [B, ...]
    z, xbc, dt = _split_proj(proj, d_model, cfg)

    # conv window update
    window = jnp.concatenate([state.conv, xbc[:, None, :].astype(jnp.float32)], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"]) + params["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xs, b_, c_ = jnp.split(conv_out, [di, di + g * n], axis=-1)
    xh = xs.reshape(bsz, h_heads, p_dim).astype(jnp.float32)
    rep = h_heads // g
    bh = jnp.repeat(b_.reshape(bsz, g, n), rep, axis=1).astype(jnp.float32)
    ch = jnp.repeat(c_.reshape(bsz, g, n), rep, axis=1).astype(jnp.float32)

    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B, H]
    dec = jnp.exp(dt * a)                                 # [B, H]
    h_new = state.h * dec[..., None, None] + \
        jnp.einsum("bhp,bhn->bhpn", xh * dt[..., None], bh)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, ch) + xh * params["D"][None, :, None]
    y = y.reshape(bsz, di) * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm({"g": params["norm_g"]}, y)
    out = qlinear(params["out_proj"], y[:, None, :].astype(x.dtype), bits_out)
    return out, SSMState(h=h_new, conv=new_conv)

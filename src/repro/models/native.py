"""Fake-mode → native-mode parameter conversion (deployment quantization).

Walks a parameter tree and replaces every quantizable weight with its integer
carrier (:class:`QTensor`): linears become ``{"wq": QTensor, ...}``, stacked
MoE expert tensors become QTensors directly. Works under ``jax.eval_shape``,
which is how the dry-run builds abstract native parameter trees without ever
allocating the full model.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.qtypes import QuantSpec
from repro.core.quantizers import QTensor, quantize_native

__all__ = ["to_native", "NATIVE_SITES"]

# dict-valued linear sites (hold {"w": ..}) and raw-array MoE sites
_LINEAR_KEYS = {"qkv", "attn_out", "w_in", "w_out", "shared_in", "shared_out",
                "router", "in_proj", "out_proj", "lm_head", "embed", "mlp"}
_RAW_KEYS = {"w_in", "w_out"}  # inside "moe": stacked [L, E, ...] arrays
NATIVE_SITES = tuple(sorted(_LINEAR_KEYS))


def _quant(w: jax.Array, w_bits: int, stacked: bool) -> QTensor:
    spec = QuantSpec(bits=w_bits, per_channel=True, channel_axis=-1,
                     po2_scale=False)
    if stacked:  # layer-stacked [L, ...]: per-layer scales (scan leaf dims!)
        return jax.vmap(lambda wl: quantize_native(wl, spec))(w)
    return quantize_native(w, spec)


def to_native(params: Any, w_bits: int = 8, *, quant_embed: bool = True) -> Any:
    """Convert recursively; norms/biases/conv/SSM-scalars stay float."""

    def walk(node, name: str, stacked: bool):
        if isinstance(node, dict):
            if "w" in node and name in _LINEAR_KEYS:
                if name == "embed" and not quant_embed:
                    return node
                out = {k: v for k, v in node.items() if k != "w"}
                out["wq"] = _quant(node["w"], w_bits, stacked)
                return out
            out = {}
            for k, v in node.items():
                st = stacked or k == "layers"
                if name == "moe" and k in _RAW_KEYS and not isinstance(v, dict):
                    out[k] = _quant(v, w_bits, stacked)
                else:
                    out[k] = walk(v, k, st)
            return out
        return node

    return walk(params, "", False)

"""Feed-forward blocks: gated (SwiGLU) dense MLP, through quantized linears."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_linear, qlinear
from .pshard import constrain

__all__ = ["init_mlp", "mlp"]


def init_mlp(key: jax.Array, d_model: int, d_ff: int, *, gated: bool = True,
             act: str = "silu") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_in": init_linear(k1, d_model, d_ff * (2 if gated else 1)),
        "w_out": init_linear(k2, d_ff, d_model),
    }
    return p


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


def mlp(params: dict, x: jax.Array, bits_in: jax.Array, bits_out: jax.Array, *,
        gated: bool = True, act: str = "silu") -> jax.Array:
    """``bits_in``/``bits_out`` are the (a,w) int32 pairs of the two quant sites
    (``mlp_in``, ``mlp_out``) — gate and up projections share one site, like
    the paper's per-layer (not per-tensor) precision."""
    h = qlinear(params["w_in"], x, bits_in)
    if gated:
        g, u = jnp.split(h, 2, axis=-1)
        h = _act(act, g) * u
    else:
        h = _act(act, h)
    if h.ndim == 3:  # keep d_ff on the TP axis (Megatron col→row)
        h = constrain(h, "dp", None, "tp")
    return qlinear(params["w_out"], h, bits_out)

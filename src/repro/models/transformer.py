"""LM-family model assembly: dense / MoE / SSM / hybrid / VLM / audio.

One configurable stack covers all ten assigned architectures. Invariants:

* every matmul runs through the quantized path (the paper's technique applies
  uniformly; per-layer precision arrives as the traced ``bits_row`` of the
  adaptive engine);
* layers are stacked and executed with ``lax.scan`` (+ optional remat) so the
  HLO is depth-independent — an 80-layer 110B config lowers as fast as a 2-layer
  smoke config (DESIGN §8.2);
* attention windows and per-layer bit-widths are *data*, so one traced program
  serves every profile of the merged engine.

Public entry points:
  ``init_params``        — parameter pytree (stacked layers)
  ``quant_layer_names``  — names for building profiles / the bits table
  ``forward``            — hidden states over a full sequence (train/prefill)
  ``train_loss``         — chunked-vocab xent + MoE aux losses
  ``init_caches`` / ``decode_step`` / ``prefill`` — serving path
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .attention import (KVCache, PagedKVCache, decode_attention,
                        decode_attention_window, gqa_attention, init_kv_cache,
                        init_paged_kv_cache, kv_refine,
                        paged_decode_attention,
                        paged_decode_attention_window, paged_view,
                        prefix_attention, swa_attention, update_kv_cache,
                        update_kv_cache_window, update_paged_kv_cache,
                        update_paged_kv_cache_window)
from .pshard import constrain
from .layers import (embed_lookup, init_embed, init_linear, init_norm,
                     layer_norm, qlinear, rms_norm)
from .mlp import init_mlp, mlp
from .moe import MoEConfig, init_moe, moe_ffn
from .rotary import apply_mrope, apply_rope, text_mrope_positions
from .ssm import (SSMConfig, SSMState, init_ssm, init_ssm_state,
                  ssd_forward, ssm_decode_step)

__all__ = ["ModelConfig", "init_params", "quant_layer_names", "forward",
           "train_loss", "init_caches", "init_paged_caches", "decode_step",
           "decode_many", "decode_segment", "prefill", "prefill_extend",
           "forward_extend", "cache_bytes", "supports_prefix_sharing",
           "paged_row_masters", "amax_for_scale",
           "prequant_decode_weights", "overlay_params",
           "param_count", "active_param_count"]


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_theta: float = 1e6
    qkv_bias: bool = False
    mrope: bool = False
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    sliding_window: int = 0          # 0 = full attention
    causal: bool = True              # False → encoder-only (audio)
    act: str = "silu"
    norm: str = "rms"                # rms | ln
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[str] = None   # audio | vision (stub, DESIGN §4)
    n_patches: int = 0               # VLM vision-prefix length
    feature_dim: int = 512           # audio stub frame-embedding dim
    tie_embeddings: bool = False
    remat: bool = True
    loss_chunk: int = 1024           # seq positions per logits chunk
    attn_block_k: int = 512
    # analysis knobs (dry-run roofline extrapolation; DESIGN §7):
    scan_layers: bool = True         # False → python loop (depth-unrolled HLO)
    unroll_inner: bool = False       # unroll attention/SSD/loss scans
    # §Perf hillclimb knobs (defaults = optimized; dryrun flags restore baseline)
    remat_policy: str = "nothing"    # nothing | dots (save matmul outputs)
    swa_block_skip: bool = True      # block-skipping sliding-window attention

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def has_attn(self) -> bool:
        return self.family in ("dense", "moe", "hybrid", "vlm", "audio")

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_mlp(self) -> bool:
        return self.family in ("dense", "hybrid", "vlm", "audio")

    def window(self, skv: int) -> int:
        return self.sliding_window if self.sliding_window else skv + 1


# quantization sites per family (paper granularity: per layer, per block kind)
_SITES = {
    "dense": ("qkv", "attn_out", "mlp_in", "mlp_out"),
    "vlm": ("qkv", "attn_out", "mlp_in", "mlp_out"),
    "audio": ("qkv", "attn_out", "mlp_in", "mlp_out"),
    "moe": ("qkv", "attn_out", "router", "expert_in", "expert_out",
            "shared_in", "shared_out"),
    "ssm": ("ssm_in", "ssm_out"),
    "hybrid": ("qkv", "attn_out", "ssm_in", "ssm_out", "mlp_in", "mlp_out"),
}
_GLOBAL_SITES = ("embed", "lm_head")


def sites(cfg: ModelConfig) -> tuple[str, ...]:
    return _SITES[cfg.family]


def quant_layer_names(cfg: ModelConfig) -> tuple[str, ...]:
    """Names for Profile construction: globals + per-depth per-site."""
    return _GLOBAL_SITES + tuple(
        f"L{i}.{s}" for i in range(cfg.n_layers) for s in sites(cfg))


def split_bits(cfg: ModelConfig, bits_row: jax.Array):
    """bits_row [2 + L*S, 2] → (embed [2], lm_head [2], layers [L, S, 2])."""
    ns = len(sites(cfg))
    return (bits_row[0], bits_row[1],
            bits_row[2:].reshape(cfg.n_layers, ns, 2))


def _site_idx(cfg: ModelConfig, name: str) -> int:
    return sites(cfg).index(name)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {}
    d, hd = cfg.d_model, cfg.hd
    if cfg.has_attn:
        qkv_out = (cfg.n_heads + 2 * cfg.n_kv) * hd
        p["qkv"] = init_linear(ks[0], d, qkv_out, bias=cfg.qkv_bias)
        p["attn_out"] = init_linear(ks[1], cfg.n_heads * hd, d)
        p["norm_attn"] = init_norm(d, bias=cfg.norm == "ln")
    if cfg.has_ssm:
        p["ssm"] = init_ssm(ks[2], d, cfg.ssm)
        if cfg.family == "ssm":
            p["norm_ssm"] = init_norm(d, bias=False)
    if cfg.family == "hybrid":
        # parallel-head fusion norms (Hymba): per-path output norms
        p["norm_attn_out"] = init_norm(d)
        p["norm_ssm_out"] = init_norm(d)
    if cfg.has_mlp:
        p["mlp"] = init_mlp(ks[3], d, cfg.d_ff, gated=cfg.act == "silu", act=cfg.act)
        p["norm_mlp"] = init_norm(d, bias=cfg.norm == "ln")
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[4], d, cfg.moe)
        p["norm_mlp"] = init_norm(d)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(cfg, k))(layer_keys)
    p = {
        "layers": layers,
        "norm_f": init_norm(cfg.d_model, bias=cfg.norm == "ln"),
    }
    if cfg.frontend == "audio":
        p["embed"] = init_linear(k_emb, cfg.feature_dim, cfg.d_model)
    else:
        p["embed"] = init_embed(k_emb, cfg.vocab, cfg.d_model)
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(k_head, cfg.d_model, cfg.vocab, scale=0.02)
    return p


def param_count(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))


def active_param_count(cfg: ModelConfig, params) -> int:
    """MoE-aware: routed experts count at top_k/E (for MODEL_FLOPS = 6·N_active·D)."""
    total = param_count(params)
    if cfg.family != "moe":
        return total
    e, k = cfg.moe.n_routed, cfg.moe.top_k
    routed = cfg.n_layers * e * 3 * cfg.moe.d_expert * cfg.d_model
    return total - routed + int(routed * k / e)


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------

def _norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    return layer_norm(p, x) if cfg.norm == "ln" else rms_norm(p, x)


def _attn_qkv(cfg: ModelConfig, lp: dict, x: jax.Array, lb: jax.Array,
              positions: jax.Array):
    """Project + rope. Returns q [B,S,H,hd], k/v [B,S,Hkv,hd]."""
    b, s, _ = x.shape
    hd = cfg.hd
    qkv = qlinear(lp["qkv"], x, lb[_site_idx(cfg, "qkv")])
    q, k, v = jnp.split(
        qkv, [cfg.n_heads * hd, (cfg.n_heads + cfg.n_kv) * hd], axis=-1)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv, hd)
    v = v.reshape(b, s, cfg.n_kv, hd)
    if cfg.mrope:
        pos3 = text_mrope_positions(positions)
        q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # NB: no head-axis constraint here — attention internals are S-sharded
    # (see gqa_attention); a conflicting H→tp pin forces SPMD full remat.
    return q, k, v


def _attend(cfg: ModelConfig, q, k, v, s: int, kv_valid=None):
    """Dispatch: block-skipping SWA (exact, S·window FLOPs) vs masked blockwise."""
    if (cfg.sliding_window and cfg.causal and cfg.swa_block_skip
            and s > cfg.sliding_window and q.shape[1] == k.shape[1]):
        return swa_attention(q, k, v, window=cfg.sliding_window,
                             block_q=cfg.attn_block_k, kv_valid=kv_valid)
    return gqa_attention(q, k, v, causal=cfg.causal, window=cfg.window(s),
                         block_k=cfg.attn_block_k, unroll=cfg.unroll_inner,
                         kv_valid=kv_valid)


def _layer_forward(cfg: ModelConfig, lp: dict, lb: jax.Array, x: jax.Array,
                   positions: jax.Array, collect_kv: bool,
                   collect_ssm: bool, valid: Optional[jax.Array] = None,
                   kv_eff: Optional[jax.Array] = None):
    """One layer over a full sequence. Returns (x, aux, collected).

    ``valid`` ``[B, S]`` bool marks real tokens of a left-padded ragged batch
    (None = every token real): pad keys are masked out of attention, pad steps
    are masked out of the SSM recurrence, and pad tokens are dropped from the
    MoE capacity dispatch — a ragged row computes exactly what it would solo.
    ``kv_eff`` (traced int32 scalar, optional) is this layer's precision-
    policy bit-width: fresh K/V are refined (:func:`~repro.models.attention.
    kv_refine`) right after the QKV projection, so attention reads AND the
    collected cache/master values see the same refined tensors.
    """
    b, s, d = x.shape
    aux = jnp.zeros((), jnp.float32)
    collected = ()

    if cfg.family == "hybrid":
        xin = _norm(cfg, lp["norm_attn"], x)
        q, k, v = _attn_qkv(cfg, lp, xin, lb, positions)
        if kv_eff is not None:
            k, v = kv_refine(k, kv_eff), kv_refine(v, kv_eff)
        attn = _attend(cfg, q, k, v, s, kv_valid=valid)
        attn = qlinear(lp["attn_out"], attn.reshape(b, s, -1),
                       lb[_site_idx(cfg, "attn_out")])
        ssm_call = partial(ssd_forward, lp["ssm"], xin,
                           lb[_site_idx(cfg, "ssm_in")],
                           lb[_site_idx(cfg, "ssm_out")], cfg.ssm,
                           unroll=cfg.unroll_inner, valid=valid)
        if collect_ssm:
            ssm_out, fin = ssm_call(return_final_state=True)
        else:
            ssm_out, fin = ssm_call(), None
        y = 0.5 * (rms_norm(lp["norm_attn_out"], attn)
                   + rms_norm(lp["norm_ssm_out"], ssm_out))
        x = x + y
        x = x + mlp(lp["mlp"], _norm(cfg, lp["norm_mlp"], x),
                    lb[_site_idx(cfg, "mlp_in")], lb[_site_idx(cfg, "mlp_out")],
                    gated=cfg.act == "silu", act=cfg.act)
        if collect_kv or collect_ssm:
            collected = ((k, v) if collect_kv else None,
                         fin if collect_ssm else None)
        return x, aux, collected

    if cfg.family == "ssm":
        xin = _norm(cfg, lp["norm_ssm"], x)
        call = partial(ssd_forward, lp["ssm"], xin,
                       lb[_site_idx(cfg, "ssm_in")],
                       lb[_site_idx(cfg, "ssm_out")], cfg.ssm,
                       unroll=cfg.unroll_inner, valid=valid)
        if collect_ssm:
            y, fin = call(return_final_state=True)
            collected = (None, fin)
        else:
            y = call()
        return x + y, aux, collected

    # attention families: dense / moe / vlm / audio
    xin = _norm(cfg, lp["norm_attn"], x)
    q, k, v = _attn_qkv(cfg, lp, xin, lb, positions)
    if kv_eff is not None:
        k, v = kv_refine(k, kv_eff), kv_refine(v, kv_eff)
    attn = _attend(cfg, q, k, v, s, kv_valid=valid)
    x = x + qlinear(lp["attn_out"], attn.reshape(b, s, -1),
                    lb[_site_idx(cfg, "attn_out")])
    x = constrain(x, "dp", None, None)
    xm = _norm(cfg, lp["norm_mlp"], x)
    if cfg.family == "moe":
        bits = {name: lb[_site_idx(cfg, name)]
                for name in ("router", "expert_in", "expert_out",
                             "shared_in", "shared_out")}
        y, moe_aux = moe_ffn(lp["moe"], xm, bits, cfg.moe, token_valid=valid)
        aux = aux + moe_aux["load_balance"] + moe_aux["router_z"]
    else:
        y = mlp(lp["mlp"], xm, lb[_site_idx(cfg, "mlp_in")],
                lb[_site_idx(cfg, "mlp_out")],
                gated=cfg.act == "silu", act=cfg.act)
    x = x + y
    if collect_kv:
        collected = ((k, v), None)
    return x, aux, collected


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ModelConfig, params: dict, bits_row: jax.Array,
                  batch: dict) -> tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Tokens/features/patches → initial hidden states + positions + validity.

    ``batch["prompt_len"]`` (``[B]`` int32, optional) marks ragged rows that
    were left-padded to a common length: row ``i``'s real tokens occupy the
    *last* ``prompt_len[i]`` columns. Each row then gets per-row position
    offsets (``positions = arange(S) - pad``, so real tokens count 0..len−1
    exactly as they would solo) and a ``valid`` mask over its real tokens; pad
    embeddings are zeroed so pad junk never inflates activation-quant scales.
    Without ``prompt_len`` the behavior (and lowering) is unchanged.
    """
    eb, _, _ = split_bits(cfg, bits_row)
    if cfg.frontend == "audio":
        x = qlinear(params["embed"], batch["features"], eb)
        b, s = x.shape[:2]
    else:
        x = embed_lookup(params["embed"], batch["tokens"], eb)
        b, s = batch["tokens"].shape
        if cfg.frontend == "vision" and cfg.n_patches:
            # vision prefix: precomputed patch embeddings replace the first
            # n_patches positions (frontend stub per the brief)
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x[:, cfg.n_patches:]], axis=1)
    plen = batch.get("prompt_len")
    if plen is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        valid = None
    else:
        pad = s - jnp.asarray(plen, jnp.int32)               # [B] left-pad
        positions = jnp.arange(s, dtype=jnp.int32)[None] - pad[:, None]
        valid = positions >= 0                               # [B, S]
        x = jnp.where(valid[..., None], x, 0).astype(x.dtype)
    return constrain(x, "dp", None, None), positions, valid


def forward(params: dict, cfg: ModelConfig, bits_row: jax.Array, batch: dict,
            collect: bool = False, kv_sched: Optional[jax.Array] = None):
    """Backbone over a full sequence.

    Returns (hidden [B,S,d], aux_loss, collected) where ``collected`` stacks
    per-layer (kv, ssm_final) when ``collect`` (prefill → cache handoff).
    ``kv_sched`` (``int32[L]``, optional, *data*) is a per-layer KV
    precision-policy row — each layer's fresh K/V are refined at its entry's
    bit-width before attention/collection; ``None`` keeps the lowering
    byte-identical to the policy-free path (the scan xs tuple is unchanged).
    """
    x, positions, valid = _embed_inputs(cfg, params, bits_row, batch)
    _, _, layer_bits = split_bits(cfg, bits_row)

    def body(carry, xs):
        x, aux = carry
        if kv_sched is None:
            lp, lb = xs
            ke = None
        else:
            lp, lb, ke = xs
        x, a, col = _layer_forward(cfg, lp, lb, x, positions,
                                   collect_kv=collect and cfg.has_attn,
                                   collect_ssm=collect and cfg.has_ssm,
                                   valid=valid, kv_eff=ke)
        return (x, aux + a), col

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(body, policy=_remat_policy(cfg))
    carry0 = (x, jnp.zeros((), jnp.float32))
    xs_all = ((params["layers"], layer_bits) if kv_sched is None
              else (params["layers"], layer_bits,
                    jnp.asarray(kv_sched, jnp.int32)))
    if cfg.scan_layers:
        (x, aux), collected = jax.lax.scan(body_fn, carry0, xs_all)
    else:  # depth-unrolled variant (roofline analysis lowering)
        carry = carry0
        cols = []
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[l], params["layers"])
            xs_l = ((lp, layer_bits[l]) if kv_sched is None
                    else (lp, layer_bits[l],
                          jnp.asarray(kv_sched, jnp.int32)[l]))
            carry, col = body_fn(carry, xs_l)
            cols.append(col)
        (x, aux) = carry
        collected = jax.tree.map(lambda *xs: jnp.stack(xs), *cols) if cols and cols[0] else ()
    x = _norm(cfg, params["norm_f"], x)
    return x, aux, collected


def _remat_policy(cfg: ModelConfig):
    """'nothing' = recompute everything in bwd (min memory, +fwd FLOPs);
    'dots' = save matmul outputs (−recompute FLOPs, +memory) — §Perf knob."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _lm_head_params(cfg: ModelConfig, params: dict) -> dict:
    if cfg.tie_embeddings:
        emb = params["embed"]
        if "wq" in emb:  # native deployment: dequantize the tied table
            from repro.core.quantizers import dequantize
            return {"w": dequantize(emb["wq"], jnp.float32).T}
        return {"w": emb["w"].T}
    return params["lm_head"]


def _logits(cfg: ModelConfig, params: dict, bits_row: jax.Array,
            h: jax.Array) -> jax.Array:
    _, hb, _ = split_bits(cfg, bits_row)
    return qlinear(_lm_head_params(cfg, params), h, hb).astype(jnp.float32)


def chunked_xent(cfg: ModelConfig, params: dict, bits_row: jax.Array,
                 hidden: jax.Array, labels: jax.Array) -> jax.Array:
    """Cross-entropy with seq-chunked logits — the full [B,S,V] tensor never
    materializes (DESIGN §5; V up to 152k makes it ~300 TB otherwise)."""
    b, s, d = hidden.shape
    c = min(cfg.loss_chunk, s)
    assert s % c == 0
    hc = hidden.reshape(b, s // c, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, s // c, c).transpose(1, 0, 2)

    def chunk_loss(carry, xs):
        h_, l_ = xs
        logits = constrain(_logits(cfg, params, bits_row, h_),
                           "dp", None, "tp")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l_, 0)[..., None], axis=-1)[..., 0]
        mask = (l_ >= 0).astype(jnp.float32)
        nll = (logz - gold) * mask
        tot, cnt = carry
        return (tot + nll.sum(), cnt + mask.sum()), None

    fn = chunk_loss
    if cfg.remat:
        fn = jax.checkpoint(chunk_loss, policy=_remat_policy(cfg))
    (tot, cnt), _ = jax.lax.scan(fn, (jnp.zeros(()), jnp.zeros(())), (hc, lc),
                                 unroll=(s // c) if cfg.unroll_inner else 1)
    return tot / jnp.maximum(cnt, 1.0)


def train_loss(params: dict, cfg: ModelConfig, bits_row: jax.Array,
               batch: dict):
    """Next-token (or frame-classification) loss + MoE aux. Returns (loss, metrics)."""
    hidden, aux, _ = forward(params, cfg, bits_row, batch)
    if cfg.causal:
        labels = batch["labels"]          # already shifted by the data pipeline
    else:
        labels = batch["labels"]          # frame targets (audio)
    loss = chunked_xent(cfg, params, bits_row, hidden, labels)
    total = loss + aux
    return total, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: caches, prefill, decode
# ---------------------------------------------------------------------------

def _stack_layerwise(fn, n_layers: int):
    """init helper: build per-layer cache pytrees stacked on axis 0."""
    one = fn()
    return jax.tree.map(lambda l: jnp.broadcast_to(l[None], (n_layers, *l.shape)).copy(), one)


def init_caches(cfg: ModelConfig, batch: int, slots: int, *,
                kv_bits: int = 16) -> dict:
    """Decode caches, stacked [L, ...]. ``slots`` bounds the attention window
    (SWA archs allocate only their window — what makes hymba long_500k O(W))."""
    caches: dict[str, Any] = {}
    if cfg.has_attn:
        eff = min(slots, cfg.sliding_window) if cfg.sliding_window else slots
        dt = jnp.float32 if kv_bits == 32 else jnp.bfloat16
        caches["kv"] = _stack_layerwise(
            lambda: init_kv_cache(batch, eff, cfg.n_kv, cfg.hd, bits=kv_bits,
                                  dtype=dt),
            cfg.n_layers)
    if cfg.has_ssm:
        caches["ssm"] = _stack_layerwise(
            lambda: init_ssm_state(batch, cfg.d_model, cfg.ssm), cfg.n_layers)
    return caches


def paged_block_size(cfg: ModelConfig, slots: int, block_size: int) -> int:
    """Largest block size ≤ ``block_size`` compatible with ``cfg``.

    Sliding-window stacks ring-wrap at the window, so exact equivalence
    with the contiguous ring requires the block size to divide the window
    (a non-divisor request degrades to the largest divisor). Full-attention
    stacks never wrap within a valid request — their virtual row just
    rounds up to a whole number of blocks — so any block size works.
    """
    bs = max(1, int(block_size))
    if cfg.sliding_window:
        eff = min(slots, cfg.sliding_window)
        while eff % bs and bs > 1:
            bs -= 1
    return bs


def init_paged_caches(cfg: ModelConfig, batch: int, slots: int, *,
                      kv_bits: int = 16, block_size: int = 16,
                      pool_blocks: Optional[int] = None) -> dict:
    """Paged decode caches: the KV pool is a global set of fixed-size blocks.

    Same contract as :func:`init_caches` (stacked ``[L, ...]``, scanned over
    layers), but attention state is a :class:`repro.models.attention.
    PagedKVCache`: ``pool_blocks`` physical blocks of ``block_size`` tokens
    shared by all ``batch`` rows, each row owning a ``[n_lblk]`` block table
    (``n_lblk = ceil(eff_slots / block_size)``). ``pool_blocks=None``
    provisions ``batch * n_lblk`` — exactly the contiguous footprint; a
    scheduler that shares prefixes or admits short rows can provision far
    less. SSM state is O(1) per row and stays dense, as in
    :func:`init_caches`.
    """
    caches: dict[str, Any] = {}
    if cfg.has_attn:
        eff = min(slots, cfg.sliding_window) if cfg.sliding_window else slots
        bs = paged_block_size(cfg, slots, block_size)
        n_lblk = -(-eff // bs)
        nb = batch * n_lblk if pool_blocks is None else int(pool_blocks)
        dt = jnp.float32 if kv_bits == 32 else jnp.bfloat16
        caches["kv"] = _stack_layerwise(
            lambda: init_paged_kv_cache(batch, nb, bs, n_lblk, cfg.n_kv,
                                        cfg.hd, bits=kv_bits, dtype=dt),
            cfg.n_layers)
    if cfg.has_ssm:
        caches["ssm"] = _stack_layerwise(
            lambda: init_ssm_state(batch, cfg.d_model, cfg.ssm), cfg.n_layers)
    return caches


def cache_bytes(caches) -> int:
    """Device bytes held by a cache pytree (KV pools, block tables, scales,
    SSM state) — the serving bench's KV-memory-footprint metric."""
    return sum(int(x.nbytes) for x in jax.tree_util.tree_leaves(caches))


def supports_prefix_sharing(cfg: ModelConfig) -> bool:
    """Whether the shared-prefix admission path is exact for this stack.

    Requires full causal attention with per-position state only: the prefix
    KV is position-addressed, so any row can map it. Sliding-window stacks
    ring-wrap (a shared block would eventually be overwritten), SSM stacks
    carry a recurrent state that is not per-position, and MoE capacity
    dispatch couples tokens across the batch — those families take the cold
    paged path instead (still paged, just no cross-request block mapping).
    """
    return (cfg.has_attn and not cfg.has_ssm and cfg.family != "moe"
            and not cfg.sliding_window and cfg.causal)


def supports_speculation(cfg: ModelConfig, kv_bits: int = 16) -> bool:
    """Whether draft/verify speculative decoding is exact for this stack.

    Same structural requirements as prefix sharing — full causal attention
    with per-position state only. SSM recurrences and MoE capacity dispatch
    couple a window's positions to batch/sequence state a rejected draft
    cannot roll back, and a sliding-window ring could wrap a speculative
    tail onto live slots. Additionally requires kv16/kv8: the int4 packed
    cache has no per-query dequant ladder (see
    ``attention.decode_attention_window``).
    """
    return supports_prefix_sharing(cfg) and kv_bits in (8, 16)


def paged_row_masters(kv_pool, slot: int, block_ids, n_tok: int):
    """Full-precision K/V masters of one paged row's first ``n_tok`` tokens.

    The preemption snapshot: gathers the row's mapped pool blocks
    (``block_ids``, logical order — shared CoW prefix blocks included) and
    returns ``(mk, mv)`` as ``[L, n_tok, Hkv, hd]`` float32, dequantized
    under the row's *current* per-``[L, Hkv]`` scales. For int KV that is
    not the historically-written value of early tokens (the running-max
    scale moved after they were quantized) — it is exactly the value whose
    re-quantization under the same scale reproduces the stored ints
    bit-for-bit, which is the roundtrip :meth:`ContinuousScheduler.
    evict_row`/resume needs: replaying these masters through the
    continuation-prefill executable rebuilds the row's cache state
    byte-identically. Runs eagerly on the host side between segments (a
    handful of gathers per eviction — preemption is the exceptional path);
    row *eviction* itself needs no dispatch beyond the existing
    fixed-shape table clear, exactly like in-graph retirement.
    """
    from .attention import _dequantize_kv
    bs = kv_pool.k.shape[2]                  # [L, n_blocks, bs, Hkv, hd]
    nb = -(-n_tok // bs)
    bids = jnp.asarray(np.asarray(list(block_ids)[:nb], np.int32)
                       .reshape(nb))

    def gather(pool, scale):
        g = jnp.take(pool, bids, axis=1)     # [L, nb, bs, Hkv, hd']
        g = g.reshape(g.shape[0], nb * bs, *g.shape[3:])[:, :n_tok]
        if kv_pool.bits in (4, 8):
            return _dequantize_kv(g, scale, kv_pool.bits)
        return g.astype(jnp.float32)

    return (gather(kv_pool.k, kv_pool.k_scale[:, slot]),
            gather(kv_pool.v, kv_pool.v_scale[:, slot]))


def amax_for_scale(scale: np.ndarray, qmax: float,
                   strict: bool = True) -> np.ndarray:
    """Invert the int-KV scale calibration ``s = amax/qmax + 1e-9``, f32-exact.

    The preemption restore wave re-quantizes a suspended row's masters
    through ``prefill_extend``'s calibration ``max(suffix_amax, amax)/qmax
    + 1e-9``; passing an ``amax`` whose forward image is bit-equal to the
    row's suspended scale makes the restored scale — and with it every
    re-quantized int — identical to the uninterrupted row's. Scales born
    of true f32 division have such a preimage within a few ulp of
    ``(s − 1e-9)·qmax``; this searches per element. But XLA may lower a
    divide-by-constant as multiply-by-reciprocal (observed inside the
    fused decode scan at qmax=7), and division by a non-power-of-2 maps
    the float grid ~1.14 result-ulps per input ulp — so a device-produced
    scale can sit on a result value that true division skips entirely, at
    ANY search radius. ``strict=False`` returns the nearest approximate
    preimage for such elements instead of raising; callers relying on
    bit-exact restoration must then force the exact scale separately
    (``RowSnapshot.k_scale``/``v_scale`` — re-quantization itself is
    robust to a few-ulp scale error since ``round(i·(1±ε)) == i`` for
    ``|i| ≤ qmax``, so only the scale bytes need forcing).
    """
    s = np.asarray(scale, np.float32)
    qmax32, eps = np.float32(qmax), np.float32(1e-9)

    def fwd(a):
        return np.float32(np.float32(a / qmax32) + eps)

    out = np.empty_like(s)
    it = np.nditer(s, flags=["multi_index"])
    for sv in it:
        sv = np.float32(sv)
        a = np.float32(np.float32(sv - eps) * qmax32)
        lo = hi = a
        for _ in range(64):
            if fwd(a) == sv:
                break
            hi = np.nextafter(hi, np.float32(np.inf), dtype=np.float32)
            if fwd(hi) == sv:
                a = hi
                break
            lo = np.nextafter(lo, np.float32(-np.inf), dtype=np.float32)
            if fwd(lo) == sv:
                a = lo
                break
        else:
            if strict:
                raise ValueError(f"no amax preimage for scale {sv!r}")
            a = np.float32(np.float32(sv - eps) * qmax32)
        out[it.multi_index] = a
    return out


def decode_step(params: dict, cfg: ModelConfig, bits_row: jax.Array,
                tokens: jax.Array, pos: jax.Array, caches: dict,
                row_valid: Optional[jax.Array] = None,
                paged_backend: str = "gather",
                kv_sched: Optional[jax.Array] = None):
    """One decode step. tokens ``[B,1]``, pos ``[B]`` → (logits [B,V], caches).

    ``row_valid`` ``[B]`` bool marks rows still generating (continuous-batching
    slot pools carry retired/free rows): dead rows are dropped from the MoE
    capacity dispatch so they cannot displace a live row's expert routing.
    Non-MoE families ignore it (batch rows are independent there).

    ``paged_backend`` (static) picks how a :class:`PagedKVCache` is read:
    ``"gather"`` materializes the dense per-row view (:func:`paged_view`, the
    CPU/oracle path) while ``"pallas"`` attends **in place** against the
    block pool (:func:`repro.models.attention.paged_decode_attention`) — no
    ``[B, n_lblk*bs]`` copy exists anywhere in the step.

    ``kv_sched`` (``int32[L]``, optional, *data*): per-layer precision-policy
    row — the step's fresh K/V are refined per layer before the cache write
    and the attention read, exactly like the prefill paths.
    """
    eb, _, layer_bits = split_bits(cfg, bits_row)
    x = embed_lookup(params["embed"], tokens, eb)
    positions = pos[:, None].astype(jnp.int32)
    b = tokens.shape[0]

    def body(x, xs):
        if kv_sched is None:
            lp, lb, cache = xs
            ke = None
        else:
            lp, lb, cache, ke = xs
        new_cache = dict(cache)
        if cfg.has_attn:
            xin = _norm(cfg, lp["norm_attn"], x)
            q, k, v = _attn_qkv(cfg, lp, xin, lb, positions)
            if ke is not None:
                k, v = kv_refine(k, ke), kv_refine(v, ke)
            if "kv_view" in cache:
                # paged fast path (decode_segment): the block table is
                # fixed for the whole segment, so the dense per-row view
                # was gathered ONCE at segment entry, rides the carry, and
                # takes every read AND write of the segment — exactly the
                # contiguous ring's per-step cost. The pool passes through
                # untouched; decode_segment folds the view's blocks back
                # through the block tables once, at segment exit.
                kvc = cache["kv"]
                view = update_kv_cache(cache["kv_view"], k, v, pos)
                attn = decode_attention(
                    q, view, pos,
                    window=cfg.window(view.token_idx.shape[1]))
                new_cache["kv_view"] = view
            elif isinstance(cache["kv"], PagedKVCache):
                kvc = update_paged_kv_cache(cache["kv"], k, v, pos)
                slots_p = kvc.block_table.shape[1] * kvc.k.shape[1]
                if paged_backend == "pallas":
                    # in-place path: the kernel streams mapped pool blocks
                    # through the block table; no dense view is built
                    attn = paged_decode_attention(
                        q, kvc, pos, window=cfg.window(slots_p))
                else:
                    # standalone paged step: gather the view on the spot
                    view = paged_view(kvc)
                    attn = decode_attention(
                        q, view, pos, window=cfg.window(slots_p))
            else:
                kvc = update_kv_cache(cache["kv"], k, v, pos)
                attn = decode_attention(
                    q, kvc, pos,
                    window=cfg.window(kvc.token_idx.shape[1]))
            attn = qlinear(lp["attn_out"], attn.reshape(b, 1, -1),
                           lb[_site_idx(cfg, "attn_out")])
            new_cache["kv"] = kvc
        if cfg.family == "hybrid":
            ssm_out, st = ssm_decode_step(lp["ssm"], xin, cache["ssm"],
                                          lb[_site_idx(cfg, "ssm_in")],
                                          lb[_site_idx(cfg, "ssm_out")], cfg.ssm)
            y = 0.5 * (rms_norm(lp["norm_attn_out"], attn)
                       + rms_norm(lp["norm_ssm_out"], ssm_out))
            x = x + y
            x = x + mlp(lp["mlp"], _norm(cfg, lp["norm_mlp"], x),
                        lb[_site_idx(cfg, "mlp_in")],
                        lb[_site_idx(cfg, "mlp_out")])
            new_cache["ssm"] = st
        elif cfg.family == "ssm":
            xin = _norm(cfg, lp["norm_ssm"], x)
            y, st = ssm_decode_step(lp["ssm"], xin, cache["ssm"],
                                    lb[_site_idx(cfg, "ssm_in")],
                                    lb[_site_idx(cfg, "ssm_out")], cfg.ssm)
            x = x + y
            new_cache["ssm"] = st
        else:
            x = x + attn
            xm = _norm(cfg, lp["norm_mlp"], x)
            if cfg.family == "moe":
                bits = {name: lb[_site_idx(cfg, name)]
                        for name in ("router", "expert_in", "expert_out",
                                     "shared_in", "shared_out")}
                y, _ = moe_ffn(lp["moe"], xm, bits,
                               dataclasses.replace(
                                   cfg.moe, groups=math.gcd(cfg.moe.groups, b)),
                               token_valid=(None if row_valid is None
                                            else row_valid[:, None]))
                x = x + y
            else:
                x = x + mlp(lp["mlp"], xm, lb[_site_idx(cfg, "mlp_in")],
                            lb[_site_idx(cfg, "mlp_out")],
                            gated=cfg.act == "silu", act=cfg.act)
        return x, new_cache

    if kv_sched is None:
        layers_and_caches = (params["layers"], layer_bits, caches)
    else:
        layers_and_caches = (params["layers"], layer_bits, caches,
                             jnp.asarray(kv_sched, jnp.int32))
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, layers_and_caches)
    else:  # depth-unrolled analysis variant
        new_list = []
        for l in range(cfg.n_layers):
            xs_l = jax.tree.map(lambda a: a[l], layers_and_caches)
            x, nc_ = body(x, xs_l)
            new_list.append(nc_)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
    x = _norm(cfg, params["norm_f"], x)
    logits = _logits(cfg, params, bits_row, x)[:, 0]
    return logits, new_caches


def prequant_decode_weights(params: dict, cfg: ModelConfig,
                            table: jax.Array) -> dict:
    """Hoist weight fake-quant out of the decode loop.

    The seed decode path re-fake-quanted every weight matrix (embedding table
    and lm_head included) on *every step* — pure overhead around the
    approximate kernels. Since weights are step-invariant, quantize them once
    per profile up front: returns a sparse overlay pytree, parallel to
    ``params``, whose ``wfq`` leaves carry a leading profile dim ``P`` (the
    in-memory analogue of the MDC merge's per-profile actors). The decode scan
    gathers slice ``pid`` per step and grafts it on with :func:`overlay_params`
    — ``qlinear``/``embed_lookup`` prefer ``wfq`` and skip in-loop weight
    quantization. Activation quant stays in-loop (runtime-data dependent).

    Sites not covered (MoE routed-expert stacks, tied lm_head) keep the
    in-loop path — fake-quant is idempotent on its own po2 grid, so numerics
    match either way. Native (``wq``) layouts pass through untouched.
    """
    def one_profile(bits_row):
        eb, hb, layer_bits = split_bits(cfg, bits_row)
        from .layers import SIGNED_SYM
        from repro.core.quantizers import fake_quant_dynamic

        def fq(w, wb):
            return fake_quant_dynamic(w, wb, SIGNED_SYM)

        def fq_stacked(w, name):          # w [L, ...] with per-layer bits
            wb = layer_bits[:, _site_idx(cfg, name), 1]
            return jax.vmap(fq)(w, wb)

        ov: dict[str, Any] = {}
        if "w" in params["embed"] and cfg.frontend != "audio":
            ov["embed"] = {"wfq": fq(params["embed"]["w"], eb[1])}
        if not cfg.tie_embeddings and "w" in params.get("lm_head", {}):
            ov["lm_head"] = {"wfq": fq(params["lm_head"]["w"], hb[1])}
        lp = params["layers"]
        lov: dict[str, Any] = {}
        if cfg.has_attn and "w" in lp["qkv"]:
            lov["qkv"] = {"wfq": fq_stacked(lp["qkv"]["w"], "qkv")}
            lov["attn_out"] = {"wfq": fq_stacked(lp["attn_out"]["w"], "attn_out")}
        if cfg.has_mlp and "w" in lp["mlp"]["w_in"]:
            lov["mlp"] = {
                "w_in": {"wfq": fq_stacked(lp["mlp"]["w_in"]["w"], "mlp_in")},
                "w_out": {"wfq": fq_stacked(lp["mlp"]["w_out"]["w"], "mlp_out")},
            }
        if cfg.has_ssm and "w" in lp["ssm"]["in_proj"]:
            lov["ssm"] = {
                "in_proj": {"wfq": fq_stacked(lp["ssm"]["in_proj"]["w"], "ssm_in")},
                "out_proj": {"wfq": fq_stacked(lp["ssm"]["out_proj"]["w"], "ssm_out")},
            }
        if cfg.family == "moe" and "w" in lp["moe"]["router"]:
            moev: dict[str, Any] = {
                "router": {"wfq": fq_stacked(lp["moe"]["router"]["w"], "router")}}
            if "shared_in" in lp["moe"]:
                moev["shared_in"] = {
                    "wfq": fq_stacked(lp["moe"]["shared_in"]["w"], "shared_in")}
                moev["shared_out"] = {
                    "wfq": fq_stacked(lp["moe"]["shared_out"]["w"], "shared_out")}
            lov["moe"] = moev
        if lov:
            ov["layers"] = lov
        return ov

    return jax.vmap(one_profile)(jnp.asarray(table))


def overlay_params(base: dict, overlay: dict) -> dict:
    """Graft a (sliced) prequant overlay onto the base params pytree. ``wfq``
    leaves land next to the float masters; the quantized consumers prefer
    them, and the untouched ``w`` twins are dead-code-eliminated from the
    compiled scan."""
    out = dict(base)
    for k, v in overlay.items():
        if isinstance(v, dict) and isinstance(base.get(k), dict):
            out[k] = overlay_params(base[k], v)
        else:
            out[k] = v
    return out


def decode_many(params: dict, cfg: ModelConfig, table: jax.Array,
                schedule: jax.Array, logits0: jax.Array, pos0: jax.Array,
                caches: dict, row_budget: Optional[jax.Array] = None,
                prequant: Optional[dict] = None,
                kv_table: Optional[jax.Array] = None):
    """Fused multi-token greedy decode: one ``lax.scan`` over generation steps.

    The whole decode loop stays on device — per-step argmax sampling, KV/SSM
    cache updates, and profile switching all happen inside a single scan, so a
    generate call is one dispatch instead of one per token.

    * ``table`` ``[P, L, 2]`` — the merged engine's bits table; the active
      profile per step is ``schedule[i]`` (``int32[steps]``, *data*: a new
      schedule never retraces — the paper's runtime configuration word).
    * ``logits0`` ``[B, V]`` — prefill logits; ``tokens[:, 0]`` is their argmax
      (the profile that produced them is ``schedule[0]``).
    * ``pos0`` ``[B]`` — absolute position of the first decode step (prompt
      length for left-padded batches).
    * ``caches`` — decode caches from :func:`prefill`; threaded through the
      scan carry (donate them at the ``jit`` boundary for in-place updates).
    * ``row_budget`` ``[B]`` — optional per-row token budget (early stop):
      tokens at index ≥ budget are emitted as −1 and frozen rows feed a
      constant 0 token (their junk never reaches live rows — batch rows are
      independent).
    * ``prequant`` — per-profile weight images from
      :func:`prequant_decode_weights`; pass them in when params/table are
      fixed across calls (a server computes them once), else they are built
      here per call.

    Returns ``(tokens [B, steps] int32, pids [steps] int32, caches)`` where
    ``pids`` is the realized per-step profile trace for accounting.
    """
    steps = schedule.shape[0]
    b = logits0.shape[0]
    budget = (jnp.full((b,), steps, jnp.int32) if row_budget is None
              else jnp.asarray(row_budget, jnp.int32))
    tok0 = jnp.argmax(logits0, axis=-1).astype(jnp.int32)
    live0 = 0 < budget
    out0 = jnp.where(live0, tok0, -1)
    # weight images per profile: caller-supplied (once per server) or built
    # once per call — never once per token
    if prequant is None:
        prequant = prequant_decode_weights(params, cfg, table)
    ys, _, _, _, caches = decode_segment(params, cfg, table, schedule[1:],
                                         jnp.where(live0, tok0, 0), pos0,
                                         caches, budget - 1, prequant=prequant,
                                         kv_table=kv_table)
    tokens = jnp.concatenate([out0[:, None], ys], axis=1)
    return tokens, schedule, caches


def decode_segment(params: dict, cfg: ModelConfig, table: jax.Array,
                   schedule: jax.Array, tok0: jax.Array, pos0: jax.Array,
                   caches: dict, remaining: jax.Array,
                   prequant: Optional[dict] = None,
                   paged_backend: str = "gather",
                   fault_step: Optional[jax.Array] = None,
                   kv_table: Optional[jax.Array] = None):
    """Fused decode *segment*: ``len(schedule)`` scan steps from an arbitrary
    mid-generation state — the continuous-batching quantum primitive.

    Unlike :func:`decode_many` there is no prefill-logits prologue: the carry
    enters with ``tok0 [B]`` (each row's last emitted token; 0 for idle slots),
    ``pos0 [B]`` (next absolute position per row), and ``remaining [B]`` (tokens
    each row still has to emit; 0 = retired/free slot). Rows whose ``remaining``
    runs out mid-segment freeze exactly like :func:`decode_many`'s done-mask:
    their outputs come back −1, they feed a constant 0, and (for MoE) they are
    dropped from the expert-capacity dispatch via ``row_valid``. All shapes are
    static in ``(B, len(schedule))``, so a slot-pool server runs every segment
    through ONE compiled executable regardless of which rows are live.

    Paged pools run one of two backends (``paged_backend``, static):

    * ``"gather"`` — the dense per-row view is gathered ONCE at segment
      entry, every step reads/writes the view, and the view's blocks fold
      back through the tables at exit. Exactly the contiguous per-step cost,
      but the segment moves two extra pool-sized copies — the CPU oracle
      path.
    * ``"pallas"`` — every step attends **in place** against the pool
      through the Pallas paged-attention kernel and writes through the block
      table; no ``[B, n_lblk*bs]`` view and no exit fold-back exist in the
      executable. The pool is the single KV residence of the segment.

    Robustness hooks (both data — the pool-lifetime single executable holds):

    * ``fault_step`` ``[B]`` int32 — per-row scan step at which the row's
      logits are replaced with NaN (−1 / out of range = never). This is the
      deterministic fault-injection operand of the serving runtime's chaos
      machinery (:mod:`repro.serving.faults`): it poisons the *logits* only,
      after the KV write, so the pool is never corrupted — exactly the
      failure mode a numerically degraded low-bit profile produces.
    * the returned ``row_ok`` ``[B]`` bool is a per-row finite-check over
      every live step's logits, folded into the scan carry — detection of
      non-finite output (injected or genuine) costs no extra dispatch and
      rides back with the segment's tokens.

    Returns ``(tokens [B, steps], row_ok [B], tok [B], pos [B], caches)`` —
    tok/pos/caches are the carry for the next segment.
    """
    if prequant is None:
        prequant = prequant_decode_weights(params, cfg, table)
    rem = jnp.asarray(remaining, jnp.int32)
    fs = (jnp.full(jnp.shape(tok0), -1, jnp.int32) if fault_step is None
          else jnp.asarray(fault_step, jnp.int32))
    paged = isinstance(caches.get("kv"), PagedKVCache)
    use_kernel = paged and paged_backend == "pallas"
    if paged and not use_kernel:
        # block tables are fixed for the segment: gather the dense per-row
        # view once here instead of once per step inside the scan — the
        # steps read AND write only the view (the pool passes through the
        # scan untouched and absorbs the view's blocks at segment exit)
        caches = dict(caches)
        caches["kv_view"] = jax.vmap(paged_view)(caches["kv"])

    def step(carry, xs):
        pid, i = xs
        tok, pos, ok, cch = carry
        live = i < rem                       # done-mask: row still generating?
        bits_row = table[pid]
        # per-layer KV precision row, gathered by the step's (traced)
        # profile id — like bits_row, a schedule switch never retraces
        ks = None if kv_table is None else kv_table[pid]
        p_step = overlay_params(params,
                                jax.tree.map(lambda a: a[pid], prequant))
        logits, cch = decode_step(p_step, cfg, bits_row, tok[:, None], pos, cch,
                                  row_valid=live, paged_backend=paged_backend,
                                  kv_sched=ks)
        # fault injection: the targeted row's logits go NaN at its fault
        # step — after the KV write (the pool stays clean), before the
        # argmax and finite-check (both token and flag see the poison)
        logits = jnp.where((i == fs)[:, None],
                           jnp.asarray(jnp.nan, logits.dtype), logits)
        # per-row finite-check, folded into the carry: a live row whose
        # logits go non-finite (injected or genuine) drops its ok bit for
        # the rest of the segment; frozen rows never count
        ok = ok & (jnp.all(jnp.isfinite(logits), axis=-1) | ~live)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = jnp.where(live, nxt, -1)
        feed = jnp.where(live, nxt, 0)
        # dead rows freeze their position: their junk writes stay parked on
        # one slot past their last real token instead of marching around the
        # ring — with a paged cache a marching dead row would eventually wrap
        # into its first logical block, which may be a *shared* prefix block
        return (feed, pos + live.astype(jnp.int32), ok, cch), out

    steps = schedule.shape[0]
    carry0 = (jnp.asarray(tok0, jnp.int32), pos0.astype(jnp.int32),
              jnp.ones(jnp.shape(tok0), bool), caches)
    (tok, pos, row_ok, caches), ys = jax.lax.scan(
        step, carry0, (schedule, jnp.arange(steps, dtype=jnp.int32)))
    if use_kernel:
        # no fold-back: every decode write already landed in the pool through
        # the block table. Only the retirement contract remains — rows that
        # FINISH inside this segment come back with their tables unmapped
        # (their cache has no future reader; residual dead-row writes then
        # drop instead of following the freed blocks to their next owner)
        finish = (rem > 0) & (rem <= steps)
        kv = caches["kv"]
        nb = kv.k.shape[1]                       # [L, n_blocks, bs, ...]
        caches = dict(caches)
        caches["kv"] = kv._replace(
            block_table=jnp.where(finish[None, :, None], nb, kv.block_table))
    elif paged:
        # fold the segment's decode writes back into the persistent pool:
        # one blocked scatter per layer instead of one per step. Shared
        # prefix blocks appear in several rows' tables, but decode never
        # writes their virtual range, so every duplicate scatter carries
        # the same original bytes; unmapped tables (free/retired rows)
        # drop, so their junk follows no block to its next owner. Rows that
        # FINISH inside this segment (0 < remaining <= steps) come back
        # unmapped too — their cache has no future reader, so retirement
        # needs no separate table-clearing dispatch from the host.
        caches = dict(caches)
        view = caches.pop("kv_view")
        finish = (rem > 0) & (rem <= steps)

        def writeback(pool_l, view_l):
            b, nlb = pool_l.block_table.shape
            bs = pool_l.k.shape[1]
            nb = pool_l.k.shape[0]
            bt = jnp.where(finish[:, None], nb, pool_l.block_table)
            # scatter is slow on CPU backends, so write back via the INVERSE
            # map instead: one tiny scatter builds pool-block → view-block
            # (shared blocks appear under several rows — any winner carries
            # identical bytes, since decode never writes the shared range),
            # then fast gathers pull each mapped block's new content and a
            # select keeps unmapped blocks' old bytes
            inv = jnp.full((nb + 1,), b * nlb, jnp.int32)
            inv = inv.at[bt.reshape(-1)].set(
                jnp.arange(b * nlb, dtype=jnp.int32), mode="drop")[:nb]
            mapped = inv < b * nlb

            def put(pl, vl):
                blk = vl.reshape(b * nlb, bs, *vl.shape[2:])
                g = jnp.take(blk, inv, axis=0, mode="fill", fill_value=0)
                keep = mapped.reshape((nb,) + (1,) * (g.ndim - 1))
                return jnp.where(keep, g, pl)

            return pool_l._replace(
                k=put(pool_l.k, view_l.k), v=put(pool_l.v, view_l.v),
                token_idx=put(pool_l.token_idx, view_l.token_idx),
                k_scale=view_l.k_scale, v_scale=view_l.v_scale,
                block_table=bt)

        caches["kv"] = jax.vmap(writeback)(caches["kv"], view)
    return ys.T, row_ok, tok, pos, caches


def ngram_propose(hist: jax.Array, tok: jax.Array, k: int,
                  vocab: int) -> jax.Array:
    """Self-speculative n-gram drafter: longest-suffix match (prompt
    lookup) over the row's own history.

    ``hist [B, Hn]`` holds each row's most recent tokens (−1 = empty pad,
    pads only ever on the left), with the *current* token as the last
    entry; ``tok [B]`` is that current token. Each row scores every
    earlier position ``j`` by how long a suffix of the current context it
    matches (up to a trigram, most-recent position winning ties) and
    proposes the ``k`` tokens that followed the best match — periodically
    extended when the match sits closer than ``k`` to the end, so a
    period-``p`` cycle (including alternating-branch cycles a follower
    vote cannot disambiguate) is predicted exactly once one full period
    is in the window. Rows with no match (fresh history) fall back to
    repeating the current token. Pure jnp — runs inside the segment
    scan, zero host round-trips. Returns proposals ``[B, k]`` int32.
    """
    b, hn = hist.shape
    if not k:
        return jnp.zeros((b, 0), jnp.int32)
    h = jnp.asarray(hist, jnp.int32)
    cur = jnp.asarray(tok, jnp.int32)
    depth = min(3, hn - 1)
    # candidate match ends j ∈ [0, hn-2] (j == hn-1 is the trivial
    # self-match); score = longest matching suffix, weighted so a
    # (d+1)-gram match always beats any d-gram match
    j_idx = jnp.arange(hn - 1, dtype=jnp.int32)[None]         # [1, hn-1]
    score = jnp.zeros((b, hn - 1), jnp.int32)
    run = jnp.ones((b, hn - 1), bool)
    for d in range(depth):
        tgt = h[:, hn - 1 - d][:, None]                       # suffix token
        cand = jnp.where(j_idx - d >= 0,
                         jnp.take_along_axis(
                             h, jnp.maximum(j_idx - d, 0), axis=1), -2)
        run = run & (cand == tgt) & (tgt >= 0)
        score = score + (1 << d) * run.astype(jnp.int32)
    best_j = jnp.argmax(score * hn + j_idx, axis=1).astype(jnp.int32)
    matched = jnp.max(score, axis=1) > 0
    # propose the followers of the match; a match p positions from the
    # end extends periodically (idx wraps back by the period), so short
    # cycles draft past their own tail instead of clamping
    period = jnp.maximum(hn - 1 - best_j, 1)
    offs = jnp.arange(k, dtype=jnp.int32)[None]               # [1, k]
    idx = best_j[:, None] + 1 + jnp.mod(offs, period[:, None])
    prop = jnp.take_along_axis(h, jnp.minimum(idx, hn - 1), axis=1)
    prop = jnp.where(matched[:, None] & (prop >= 0), prop, cur[:, None])
    return prop


def decode_step_spec(params: dict, cfg: ModelConfig, bits_row: jax.Array,
                     tokens: jax.Array, pos: jax.Array, caches: dict,
                     row_valid: Optional[jax.Array] = None,
                     paged_backend: str = "gather"):
    """W-token draft/verify forward. tokens ``[B, W]`` (position of
    ``tokens[:, j]`` is ``pos + j``) → ``(logits [B, W, V], caches,
    (k_ladders, v_ladders))`` with ladders ``[L, B, W, Hkv]``.

    The W-wide twin of :func:`decode_step`, restricted to the stacks
    :func:`supports_speculation` admits (dense full-causal attention — no
    SSM/MoE/SWA branches). All W positions are written to the cache before
    attention runs (write-before-read: each query's causal mask only ever
    sees this window's own prefix plus committed history), and the cache's
    *committed* int8 scales are left untouched — the caller commits the
    returned per-position scale ladders at the accepted count once the
    verify pass has resolved (see :func:`decode_segment_spec`).
    """
    eb, _, layer_bits = split_bits(cfg, bits_row)
    x = embed_lookup(params["embed"], tokens, eb)
    b, w = tokens.shape
    positions = (pos[:, None]
                 + jnp.arange(w, dtype=jnp.int32)[None]).astype(jnp.int32)

    def body(x, xs):
        lp, lb, cache = xs
        new_cache = dict(cache)
        xin = _norm(cfg, lp["norm_attn"], x)
        q, k, v = _attn_qkv(cfg, lp, xin, lb, positions)
        if "kv_view" in cache:
            # paged gather path: same segment-lifetime dense view contract
            # as decode_step — the pool passes through untouched and the
            # view's blocks fold back at segment exit
            kvc = cache["kv"]
            view, klad, vlad = update_kv_cache_window(
                cache["kv_view"], k, v, pos)
            attn = decode_attention_window(
                q, view, pos, klad, vlad,
                window=cfg.window(view.token_idx.shape[1]))
            new_cache["kv_view"] = view
        elif isinstance(cache["kv"], PagedKVCache):
            kvc, klad, vlad = update_paged_kv_cache_window(
                cache["kv"], k, v, pos)
            slots_p = kvc.block_table.shape[1] * kvc.k.shape[1]
            if paged_backend == "pallas":
                attn = paged_decode_attention_window(
                    q, kvc, pos, klad, vlad, window=cfg.window(slots_p))
            else:
                view = paged_view(kvc)
                attn = decode_attention_window(
                    q, view, pos, klad, vlad, window=cfg.window(slots_p))
        else:
            kvc, klad, vlad = update_kv_cache_window(cache["kv"], k, v, pos)
            attn = decode_attention_window(
                q, kvc, pos, klad, vlad,
                window=cfg.window(kvc.token_idx.shape[1]))
        attn = qlinear(lp["attn_out"], attn.reshape(b, w, -1),
                       lb[_site_idx(cfg, "attn_out")])
        new_cache["kv"] = kvc
        x = x + attn
        xm = _norm(cfg, lp["norm_mlp"], x)
        x = x + mlp(lp["mlp"], xm, lb[_site_idx(cfg, "mlp_in")],
                    lb[_site_idx(cfg, "mlp_out")],
                    gated=cfg.act == "silu", act=cfg.act)
        return x, (new_cache, (klad, vlad))

    layers_and_caches = (params["layers"], layer_bits, caches)
    if cfg.scan_layers:
        x, (new_caches, ladders) = jax.lax.scan(body, x, layers_and_caches)
    else:  # depth-unrolled analysis variant
        new_list, lad_list = [], []
        for l in range(cfg.n_layers):
            xs_l = jax.tree.map(lambda a: a[l], layers_and_caches)
            x, (nc_, lad_) = body(x, xs_l)
            new_list.append(nc_)
            lad_list.append(lad_)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
        ladders = jax.tree.map(lambda *xs: jnp.stack(xs), *lad_list)
    x = _norm(cfg, params["norm_f"], x)
    logits = _logits(cfg, params, bits_row, x)          # [B, W, V]
    return logits, new_caches, ladders


def _commit_window_scales(kv, k_ladders, v_ladders, m: jax.Array, w: int):
    """Commit the scale-ladder entry of the last delivered position.

    ``kv`` is a per-layer-stacked (Paged)KVCache with ``k_scale [L, B,
    Hkv]``; ``k_ladders [L, B, W, Hkv]``; ``m [B]`` delivered counts.
    Rows with ``m == 0`` (frozen) keep their committed scale — a dead
    row's junk amax must never move the scale its historical ints were
    written under.
    """
    if kv.bits != 8:
        return kv
    idx = jnp.clip(m - 1, 0, w - 1).astype(jnp.int32)

    def take(lad):
        sel = jnp.take_along_axis(
            lad, jnp.broadcast_to(idx[None, :, None, None],
                                  lad.shape[:2] + (1,) + lad.shape[3:]),
            axis=2)[:, :, 0]
        return sel

    keep = (m >= 1)[None, :, None]
    return kv._replace(
        k_scale=jnp.where(keep, take(k_ladders), kv.k_scale),
        v_scale=jnp.where(keep, take(v_ladders), kv.v_scale))


def decode_segment_spec(params: dict, cfg: ModelConfig, table: jax.Array,
                        schedule: jax.Array, tok0: jax.Array,
                        pos0: jax.Array, caches: dict, remaining: jax.Array,
                        quota: Optional[jax.Array] = None,
                        hist0: Optional[jax.Array] = None,
                        spec_on: Optional[jax.Array] = None,
                        prequant: Optional[dict] = None,
                        paged_backend: str = "gather",
                        fault_step: Optional[jax.Array] = None,
                        draft_k: int = 4,
                        draft_override: Optional[jax.Array] = None,
                        draft_fn=None):
    """Speculative decode segment: ``len(schedule)`` draft/verify windows.

    Each scan iteration proposes ``draft_k`` tokens per row (self-
    speculative :func:`ngram_propose` by default, or ``draft_fn(hist, tok)
    -> [B, draft_k]`` — e.g. a small-model drafter), feeds the
    ``W = draft_k + 1`` window ``[tok, d_1..d_k]`` through ONE batched
    verify forward (:func:`decode_step_spec`), and advances each row by
    its **delivered** count ``m = min(accepted + 1, remaining, quota)``:
    the greedy argmax chain ``g`` matches the drafts position-wise, the
    accepted count is the length of the matching prefix, and position
    ``accepted`` contributes the free bonus token — so every delivered
    token is exactly the token greedy stepwise decode would emit
    (token-identity by induction). Rejected tail positions are rolled
    back **without host sync**: their cache slots hold junk that the next
    window's write span always overwrites before any query can attend to
    it, and their quantization amaxes never reach the committed int8
    scale (:func:`_commit_window_scales`).

    Mirrors :func:`decode_segment`'s carry/exit contract, with two
    generalizations: the done-mask becomes the per-row delivered count
    ``m ∈ [0, W]``, and ``quota [B]`` bounds the segment's delivered
    tokens per row (the scheduler's quantum measured in *accepted*
    tokens). ``spec_on [B]`` disables speculation per row (``m`` clamps
    to 1 — per-class opt-out). ``fault_step [B]`` poisons the whole
    verify-window logits ``[W, V]`` of the targeted row at the given
    iteration; ``row_ok`` finite-checks all ``W·V`` verify logits of
    every live iteration. ``draft_override [B, n_iter, draft_k]``
    (entries ≥ 0) forces proposals — the acceptance-boundary and
    property-test hook.

    Returns ``(tokens [B, n_iter, W], delivered [B, n_iter], row_ok,
    tok, pos, caches)``; delivered tokens of iteration ``i`` are
    ``tokens[:, i, :delivered[:, i]]``, the rest is −1 padding.
    """
    if prequant is None:
        prequant = prequant_decode_weights(params, cfg, table)
    n_iter = schedule.shape[0]
    b = jnp.shape(tok0)[0]
    w = draft_k + 1
    rem = jnp.asarray(remaining, jnp.int32)
    qta = (jnp.full((b,), jnp.iinfo(jnp.int32).max // 2, jnp.int32)
           if quota is None else jnp.asarray(quota, jnp.int32))
    son = (jnp.ones((b,), bool) if spec_on is None
           else jnp.asarray(spec_on, bool))
    fs = (jnp.full((b,), -1, jnp.int32) if fault_step is None
          else jnp.asarray(fault_step, jnp.int32))
    if hist0 is None:
        hist0 = jnp.full((b, 32), -1, jnp.int32)
        hist0 = hist0.at[:, -1].set(jnp.asarray(tok0, jnp.int32))
    dov = (jnp.full((n_iter, b, draft_k), -1, jnp.int32)
           if draft_override is None
           else jnp.asarray(draft_override, jnp.int32).transpose(1, 0, 2))
    paged = isinstance(caches.get("kv"), PagedKVCache)
    use_kernel = paged and paged_backend == "pallas"
    if paged and not use_kernel:
        caches = dict(caches)
        caches["kv_view"] = jax.vmap(paged_view)(caches["kv"])
    wj = jnp.arange(w, dtype=jnp.int32)[None]

    def _commit_caches(cch, klads, vlads, m):
        cch = dict(cch)
        if "kv_view" in cch:
            cch["kv_view"] = _commit_window_scales(
                cch["kv_view"], klads, vlads, m, w)
        else:
            cch["kv"] = _commit_window_scales(cch["kv"], klads, vlads, m, w)
        return cch

    def step(carry, xs):
        pid, it, dov_i = xs
        tok, pos, rem, qta, ok, hist, cch = carry
        live = (rem > 0) & (qta > 0)
        bits_row = table[pid]
        p_step = overlay_params(params,
                                jax.tree.map(lambda a: a[pid], prequant))
        if draft_fn is not None:
            prop = jnp.asarray(draft_fn(hist, tok), jnp.int32)
        else:
            prop = ngram_propose(hist, tok, draft_k, cfg.vocab)
        prop = jnp.where(dov_i >= 0, dov_i, prop)
        feed = jnp.concatenate([tok[:, None], prop], axis=1)     # [B, W]
        feed = jnp.where(live[:, None], feed, 0)
        logits, cch, (klads, vlads) = decode_step_spec(
            p_step, cfg, bits_row, feed, pos, cch, row_valid=live,
            paged_backend=paged_backend)
        # fault injection poisons the whole verify window's logits — after
        # the KV writes (the pool stays clean), before acceptance/argmax
        logits = jnp.where((it == fs)[:, None, None],
                           jnp.asarray(jnp.nan, logits.dtype), logits)
        ok = ok & (jnp.all(jnp.isfinite(logits), axis=(1, 2)) | ~live)
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # [B, W]
        if draft_k:
            match = (prop == g[:, :draft_k]).astype(jnp.int32)
            acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
        else:
            acc = jnp.zeros_like(rem)
        m = jnp.where(son,
                      jnp.minimum(jnp.minimum(acc + 1, rem), qta),
                      jnp.minimum(jnp.minimum(1, rem), qta))
        m = jnp.where(live, m, 0).astype(jnp.int32)
        cch = _commit_caches(cch, klads, vlads, m)
        out = jnp.where(wj < m[:, None], g, -1)
        tok = jnp.where(m >= 1,
                        jnp.take_along_axis(
                            g, jnp.clip(m - 1, 0, w - 1)[:, None],
                            axis=1)[:, 0],
                        tok)
        # slide the n-gram history window past the delivered tokens: junk
        # beyond m never enters (the take window ends at the m-th append)
        hcat = jnp.concatenate([hist, g], axis=1)
        idx = m[:, None] + jnp.arange(hist.shape[1], dtype=jnp.int32)[None]
        hist = jnp.take_along_axis(hcat, idx, axis=1)
        return (tok, pos + m, rem - m, qta - m, ok, hist, cch), (out, m)

    carry0 = (jnp.asarray(tok0, jnp.int32), pos0.astype(jnp.int32),
              rem, qta, jnp.ones((b,), bool),
              jnp.asarray(hist0, jnp.int32), caches)
    (tok, pos, rem_out, _, row_ok, _, caches), (ys, ms) = jax.lax.scan(
        step, carry0,
        (schedule, jnp.arange(n_iter, dtype=jnp.int32), dov))
    # retirement contract: rows that finished inside this segment come back
    # with their tables unmapped — delivered counts are data, so `finish`
    # is data too (vs decode_segment's static-step form), but the unmap
    # select is the same fixed-shape op either way
    finish = (rem > 0) & (rem_out <= 0)
    if use_kernel:
        kv = caches["kv"]
        nb = kv.k.shape[1]                       # [L, n_blocks, bs, ...]
        caches = dict(caches)
        caches["kv"] = kv._replace(
            block_table=jnp.where(finish[None, :, None], nb, kv.block_table))
    elif paged:
        caches = dict(caches)
        view = caches.pop("kv_view")

        def writeback(pool_l, view_l):
            b_, nlb = pool_l.block_table.shape
            bs = pool_l.k.shape[1]
            nb = pool_l.k.shape[0]
            bt = jnp.where(finish[:, None], nb, pool_l.block_table)
            inv = jnp.full((nb + 1,), b_ * nlb, jnp.int32)
            inv = inv.at[bt.reshape(-1)].set(
                jnp.arange(b_ * nlb, dtype=jnp.int32), mode="drop")[:nb]
            mapped = inv < b_ * nlb

            def put(pl, vl):
                blk = vl.reshape(b_ * nlb, bs, *vl.shape[2:])
                g = jnp.take(blk, inv, axis=0, mode="fill", fill_value=0)
                keep = mapped.reshape((nb,) + (1,) * (g.ndim - 1))
                return jnp.where(keep, g, pl)

            return pool_l._replace(
                k=put(pool_l.k, view_l.k), v=put(pool_l.v, view_l.v),
                token_idx=put(pool_l.token_idx, view_l.token_idx),
                k_scale=view_l.k_scale, v_scale=view_l.v_scale,
                block_table=bt)

        caches["kv"] = jax.vmap(writeback)(caches["kv"], view)
    return (ys.transpose(1, 0, 2), ms.T, row_ok, tok, pos, caches)


def prefill(params: dict, cfg: ModelConfig, bits_row: jax.Array, batch: dict,
            slots: int, *, kv_bits: int = 16, return_raw_kv: bool = False,
            kv_sched: Optional[jax.Array] = None):
    """Full-sequence prefill → (last-token logits [B,V], decode-ready caches).

    Ragged batches (``batch["prompt_len"]``): each left-padded row hands off
    its KV entries at per-row *logical* positions (``token_idx = idx − pad``),
    so decode continues at ``pos0 = prompt_len`` exactly where a solo run
    would. Pad slots are never written — their ``token_idx`` stays at the −1
    sentinel, which :func:`repro.models.attention.decode_attention` skips —
    and int-cache dequant scales are calibrated over real tokens only.

    ``return_raw_kv`` additionally returns the *pre-quantization* collected
    per-layer K/V (``(k, v)`` each ``[L, B, S, Hkv, hd]``, still in padded
    column coordinates) as a third result — the full-precision masters a
    prefix registry snapshots so later shared-prefix admissions can replay
    the exact cache-fill (attention reads and int-KV scale calibration) a
    cold prefill would have done.
    """
    hidden, _, collected = forward(params, cfg, bits_row, batch, collect=True,
                                   kv_sched=kv_sched)
    b, s, _ = hidden.shape
    plen = batch.get("prompt_len")
    caches = init_caches(cfg, b, slots, kv_bits=kv_bits)
    kv_col, ssm_col = (collected if isinstance(collected, tuple) and collected
                       else (None, None))
    if cfg.has_attn and kv_col is not None:
        k_all, v_all = kv_col                   # [L, B, S, Hkv, hd]
        eff = caches["kv"].token_idx.shape[-1]
        take = min(eff, s)
        idx = jnp.arange(s - take, s, dtype=jnp.int32)
        if plen is None:
            slot = idx % eff                    # [take], shared across rows
            tok_w = jnp.broadcast_to(idx[None], (b, take))
            ridx = slice(None)                  # kvc.k.at[:, slot]
            amask = None
        else:
            pad = s - jnp.asarray(plen, jnp.int32)          # [B]
            pos_t = idx[None, :] - pad[:, None]             # [B, take] logical
            real = pos_t >= 0
            slot = jnp.where(real, pos_t % eff, eff)        # OOB slot → drop
            tok_w = jnp.where(real, pos_t, -1)
            ridx = jnp.arange(b)[:, None]       # kvc.k.at[bidx, slot]
            amask = (jnp.arange(s, dtype=jnp.int32)[None] >= pad[:, None])

        def fill(kvc, k_l, v_l):
            if kvc.bits in (4, 8):
                from repro.models.attention import _quantize_kv
                qmax = 127.0 if kvc.bits == 8 else 7.0
                ka = jnp.abs(k_l.astype(jnp.float32))
                va = jnp.abs(v_l.astype(jnp.float32))
                if amask is not None:           # pad junk must not set scales
                    ka = jnp.where(amask[:, :, None, None], ka, 0.0)
                    va = jnp.where(amask[:, :, None, None], va, 0.0)
                ks = jnp.max(ka, axis=(1, 3)) / qmax + 1e-9
                vs = jnp.max(va, axis=(1, 3)) / qmax + 1e-9
                kq = _quantize_kv(k_l, ks, kvc.bits)
                vq = _quantize_kv(v_l, vs, kvc.bits)
            else:
                ks, vs = kvc.k_scale, kvc.v_scale
                kq, vq = k_l.astype(kvc.k.dtype), v_l.astype(kvc.v.dtype)
            return KVCache(
                k=kvc.k.at[ridx, slot].set(kq[:, idx], mode="drop"),
                v=kvc.v.at[ridx, slot].set(vq[:, idx], mode="drop"),
                k_scale=ks, v_scale=vs,
                token_idx=kvc.token_idx.at[ridx, slot].set(tok_w, mode="drop"),
                bits=kvc.bits,
            )

        caches["kv"] = jax.vmap(fill)(caches["kv"], k_all, v_all)
    if cfg.has_ssm and ssm_col is not None:
        h_fin, conv_tail = ssm_col              # [L, B, H, P, N], [L, B, K-1, cd]
        caches["ssm"] = SSMState(h=h_fin, conv=conv_tail.astype(jnp.float32))
    logits = _logits(cfg, params, bits_row, hidden[:, -1:])[:, 0]
    if return_raw_kv:
        return logits, caches, kv_col
    return logits, caches


# ---------------------------------------------------------------------------
# shared-prefix continuation prefill (paged KV serving)
# ---------------------------------------------------------------------------

def forward_extend(params: dict, cfg: ModelConfig, bits_row: jax.Array,
                   batch: dict, prefix_k: jax.Array, prefix_v: jax.Array,
                   prefix_len: jax.Array,
                   kv_sched: Optional[jax.Array] = None):
    """Backbone over a prompt *suffix*, attending to precomputed prefix KV.

    The shared-prefix admission path skips re-running the backbone over a
    prefix whose per-layer KV already exists; only the divergent suffix is
    embedded and pushed through the layers, with every attention read
    spanning ``[prefix KV ++ suffix KV]`` (:func:`repro.models.attention.
    prefix_attention`). Positions are absolute (``prefix_len + local``), so
    rope and causal masks line up with what a cold full-prompt prefill
    computes.

    ``batch``: ``tokens [B, Sb]`` left-padded suffixes + ``prompt_len [B]``
    = per-row *suffix* lengths. ``prefix_k``/``prefix_v``: ``[L, B, Pp, Hkv,
    hd]`` full-precision prefix masters, zero-padded past ``prefix_len[row]``
    (their logical positions are ``0..prefix_len−1`` by the shared-prefix
    invariant). Returns ``(hidden [B, Sb, d], (k, v) [L, B, Sb, Hkv, hd])``.
    Only stacks where :func:`supports_prefix_sharing` holds may call this.
    """
    assert supports_prefix_sharing(cfg), cfg.family
    eb, _, layer_bits = split_bits(cfg, bits_row)
    x = embed_lookup(params["embed"], batch["tokens"], eb)
    b, s = batch["tokens"].shape
    slen = jnp.asarray(batch["prompt_len"], jnp.int32)
    plen = jnp.asarray(prefix_len, jnp.int32)
    local = jnp.arange(s, dtype=jnp.int32)[None] - (s - slen)[:, None]
    positions = local + plen[:, None]         # absolute; negative on pads
    valid = local >= 0
    x = jnp.where(valid[..., None], x, 0).astype(x.dtype)
    x = constrain(x, "dp", None, None)

    def body(x, xs):
        if kv_sched is None:
            lp, lb, kp, vp = xs
            ke = None
        else:
            lp, lb, kp, vp, ke = xs
        xin = _norm(cfg, lp["norm_attn"], x)
        q, k, v = _attn_qkv(cfg, lp, xin, lb, positions)
        if ke is not None:
            # refine ONLY the fresh suffix K/V — the prefix masters were
            # refined when they were born; re-refining is not bit-stable
            # (the recomputed fake-quant scale drifts by ulps)
            k, v = kv_refine(k, ke), kv_refine(v, ke)
        attn = prefix_attention(q, kp, vp, k, v, positions=positions,
                                prefix_len=plen, suffix_valid=valid)
        x = x + qlinear(lp["attn_out"], attn.reshape(b, s, -1),
                        lb[_site_idx(cfg, "attn_out")])
        x = constrain(x, "dp", None, None)
        xm = _norm(cfg, lp["norm_mlp"], x)
        x = x + mlp(lp["mlp"], xm, lb[_site_idx(cfg, "mlp_in")],
                    lb[_site_idx(cfg, "mlp_out")],
                    gated=cfg.act == "silu", act=cfg.act)
        return x, (k, v)

    xs_all = ((params["layers"], layer_bits, prefix_k, prefix_v)
              if kv_sched is None
              else (params["layers"], layer_bits, prefix_k, prefix_v,
                    jnp.asarray(kv_sched, jnp.int32)))
    x, kv_col = jax.lax.scan(body, x, xs_all)
    x = _norm(cfg, params["norm_f"], x)
    return x, kv_col


def prefill_extend(params: dict, cfg: ModelConfig, bits_row: jax.Array,
                   batch: dict, slots: int, *, kv_bits: int = 16,
                   prefix_k: jax.Array, prefix_v: jax.Array,
                   prefix_len: jax.Array,
                   prefix_k_amax: Optional[jax.Array] = None,
                   prefix_v_amax: Optional[jax.Array] = None,
                   return_raw_kv: bool = False,
                   kv_sched: Optional[jax.Array] = None):
    """Shared-prefix prefill → (last-token logits, dense decode caches).

    Runs :func:`forward_extend` over the suffix only, then builds the same
    dense ``[B, slots]`` row caches a cold :func:`prefill` of the full
    prompt would: prefix K/V land at logical positions ``0..prefix_len−1``
    (re-cast / re-quantized from the full-precision masters), suffix K/V at
    ``prefix_len..prompt_len−1``, everything else stays at the ``token_idx
    = −1`` empty sentinel. For int KV the per-row dequant scale is
    calibrated as ``max(prefix amax, suffix amax)`` — *exactly* the scale a
    cold prefill over all real tokens computes (``prefix_*_amax [L, B,
    Hkv]`` are the raw max-|K|/|V| over real prefix tokens, snapshotted at
    registration) — so the quantized ints, and every decode step after
    them, match the cold path. The caller scatters the resulting rows into
    pool blocks, skipping the shared ones (copy-on-write: shared blocks are
    never written, divergent content lands in private blocks).

    ``return_raw_kv`` additionally returns the pre-quantization suffix K/V
    (``(k, v)`` each ``[L, B, Sb, Hkv, hd]``, padded column coordinates) —
    what chunked prefill accumulates host-side so the *next* chunk can
    replay this one as its prefix masters at int KV precisions.
    """
    hidden, kv_col = forward_extend(params, cfg, bits_row, batch,
                                    prefix_k, prefix_v, prefix_len,
                                    kv_sched=kv_sched)
    b, s, _ = hidden.shape
    caches = init_caches(cfg, b, slots, kv_bits=kv_bits)
    k_all, v_all = kv_col                        # [L, B, Sb, Hkv, hd]
    eff = caches["kv"].token_idx.shape[-1]
    pp = prefix_k.shape[2]
    plen = jnp.asarray(prefix_len, jnp.int32)
    slen = jnp.asarray(batch["prompt_len"], jnp.int32)
    ppos = jnp.arange(pp, dtype=jnp.int32)
    real_p = ppos[None] < plen[:, None]                   # [B, Pp]
    slot_p = jnp.where(real_p, ppos[None], eff)           # OOB → drop
    tokw_p = jnp.where(real_p, ppos[None], -1)
    local = jnp.arange(s, dtype=jnp.int32)[None] - (s - slen)[:, None]
    pos_s = local + plen[:, None]                         # [B, Sb] absolute
    real_s = local >= 0
    slot_s = jnp.where(real_s, pos_s, eff)
    tokw_s = jnp.where(real_s, pos_s, -1)
    ridx = jnp.arange(b)[:, None]

    def fill(kvc, k_l, v_l, kp_l, vp_l, ka_l, va_l):
        if kvc.bits in (4, 8):
            from repro.models.attention import _quantize_kv
            qmax = 127.0 if kvc.bits == 8 else 7.0
            ka = jnp.where(real_s[:, :, None, None],
                           jnp.abs(k_l.astype(jnp.float32)), 0.0)
            va = jnp.where(real_s[:, :, None, None],
                           jnp.abs(v_l.astype(jnp.float32)), 0.0)
            ks = jnp.maximum(jnp.max(ka, axis=(1, 3)), ka_l) / qmax + 1e-9
            vs = jnp.maximum(jnp.max(va, axis=(1, 3)), va_l) / qmax + 1e-9
            kq_s, vq_s = _quantize_kv(k_l, ks, kvc.bits), \
                _quantize_kv(v_l, vs, kvc.bits)
            kq_p, vq_p = _quantize_kv(kp_l, ks, kvc.bits), \
                _quantize_kv(vp_l, vs, kvc.bits)
        else:
            ks, vs = kvc.k_scale, kvc.v_scale
            kq_s, vq_s = k_l.astype(kvc.k.dtype), v_l.astype(kvc.v.dtype)
            kq_p, vq_p = kp_l.astype(kvc.k.dtype), vp_l.astype(kvc.v.dtype)
        k = kvc.k.at[ridx, slot_p].set(kq_p, mode="drop")
        v = kvc.v.at[ridx, slot_p].set(vq_p, mode="drop")
        ti = kvc.token_idx.at[ridx, slot_p].set(tokw_p, mode="drop")
        return KVCache(
            k=k.at[ridx, slot_s].set(kq_s, mode="drop"),
            v=v.at[ridx, slot_s].set(vq_s, mode="drop"),
            k_scale=ks, v_scale=vs,
            token_idx=ti.at[ridx, slot_s].set(tokw_s, mode="drop"),
            bits=kvc.bits,
        )

    if prefix_k_amax is None:
        prefix_k_amax = jnp.zeros((cfg.n_layers, b, cfg.n_kv), jnp.float32)
    if prefix_v_amax is None:
        prefix_v_amax = jnp.zeros((cfg.n_layers, b, cfg.n_kv), jnp.float32)
    caches["kv"] = jax.vmap(fill)(caches["kv"], k_all, v_all,
                                  prefix_k, prefix_v,
                                  prefix_k_amax, prefix_v_amax)
    logits = _logits(cfg, params, bits_row, hidden[:, -1:])[:, 0]
    if return_raw_kv:
        return logits, caches, kv_col
    return logits, caches

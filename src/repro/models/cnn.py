"""The paper's tiny CNN (§4): 2 × [conv3×3·64 + ReLU + BN + maxpool] + FC.

This is the exact evaluation workload of the paper, with the exact layer
granularity used for its profiles: three quantizable layers ``conv0``,
``conv1`` (the *inner* convolutional layer of the ``Mixed`` profile), and
``fc``. Convolutions run as fake-quantized ``lax.conv_general_dilated``
(QAT path) or as pre-quantized integer images selected via ``lax.switch``
(native merged-engine path — the MDC reconfigurable datapath analogue, with
one weight image per *distinct* spec, shared across profiles).

BN uses batch statistics in both train and eval (the synthetic-digit batches
are large; noted as a deviation from folded-BN FPGA inference in DESIGN §9).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import QuantIndex, switch_images
from repro.core.merge import MergePlan
from repro.core.qtypes import QuantSpec
from repro.core.quantizers import QTensor, dequantize, fake_quant_dynamic, quantize_native
from .layers import SIGNED_SYM

__all__ = ["CNNConfig", "CNN_LAYERS", "init_cnn", "cnn_forward", "cnn_loss",
           "cnn_accuracy", "quantize_cnn_images", "cnn_forward_native",
           "cnn_weight_shapes"]

CNN_LAYERS = ("conv0", "conv1", "fc")
CNN_INDEX = QuantIndex(CNN_LAYERS)


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    size: int = 28
    in_ch: int = 1
    channels: int = 64
    kernel: int = 3
    n_classes: int = 10

    @property
    def fc_in(self) -> int:
        return (self.size // 4) * (self.size // 4) * self.channels


def init_cnn(cfg: CNNConfig, key: jax.Array) -> dict:
    k0, k1, k2 = jax.random.split(key, 3)
    kk, c = cfg.kernel, cfg.channels

    def conv_init(k, cin, cout):
        fan = kk * kk * cin
        return {"w": jax.random.normal(k, (kk, kk, cin, cout), jnp.float32)
                     / np.sqrt(fan),
                "b": jnp.zeros((cout,), jnp.float32),
                "bn_g": jnp.ones((cout,), jnp.float32),
                "bn_b": jnp.zeros((cout,), jnp.float32)}

    return {
        "conv0": conv_init(k0, cfg.in_ch, c),
        "conv1": conv_init(k1, c, c),
        "fc": {"w": jax.random.normal(k2, (cfg.fc_in, cfg.n_classes),
                                      jnp.float32) * 0.02,
               "b": jnp.zeros((cfg.n_classes,), jnp.float32)},
    }


def cnn_weight_shapes(cfg: CNNConfig) -> dict:
    kk, c = cfg.kernel, cfg.channels
    return {"conv0": (kk, kk, cfg.in_ch, c), "conv1": (kk, kk, c, c),
            "fc": (cfg.fc_in, cfg.n_classes)}


def _conv_block(p: dict, x: jax.Array, bits_aw: jax.Array,
                w_override: jax.Array | None = None) -> jax.Array:
    """conv → ReLU → BN → maxpool, quantizing input activations and weights."""
    xq = fake_quant_dynamic(x, bits_aw[0], SIGNED_SYM)
    w = w_override if w_override is not None else \
        fake_quant_dynamic(p["w"], bits_aw[1], SIGNED_SYM)
    y = jax.lax.conv_general_dilated(
        xq, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
    y = jax.nn.relu(y)
    # batch-norm (batch statistics)
    mu = jnp.mean(y, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(y, axis=(0, 1, 2), keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 1e-5) * p["bn_g"] + p["bn_b"]
    # 2×2 maxpool
    return jax.lax.reduce_window(y, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_forward(params: dict, bits_row: jax.Array, images: jax.Array) -> jax.Array:
    """QAT/fake path. images [B, H, W, C] → logits [B, n_classes]."""
    x = _conv_block(params["conv0"], images, CNN_INDEX.gather(bits_row, ["conv0"])[0])
    x = _conv_block(params["conv1"], x, CNN_INDEX.gather(bits_row, ["conv1"])[0])
    b = x.shape[0]
    x = x.reshape(b, -1)
    fb = CNN_INDEX.gather(bits_row, ["fc"])[0]
    xq = fake_quant_dynamic(x, fb[0], SIGNED_SYM)
    wq = fake_quant_dynamic(params["fc"]["w"], fb[1], SIGNED_SYM)
    return xq @ wq + params["fc"]["b"]


def cnn_loss(params: dict, bits_row: jax.Array, batch: dict):
    logits = cnn_forward(params, bits_row, batch["images"])
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1).mean()
    acc = (jnp.argmax(logits, -1) == batch["labels"]).mean()
    return nll, {"acc": acc}


def cnn_accuracy(params: dict, bits_row: jax.Array, images, labels,
                 batch: int = 512) -> float:
    hits = 0
    fwd = jax.jit(cnn_forward)
    for i in range(0, len(labels) - batch + 1, batch):
        lg = fwd(params, bits_row, jnp.asarray(images[i:i + batch]))
        hits += int((np.argmax(np.asarray(lg), -1) == labels[i:i + batch]).sum())
    n = (len(labels) // batch) * batch
    return hits / max(1, n)


# ---------------------------------------------------------------------------
# native merged engine (MDC datapath analogue)
# ---------------------------------------------------------------------------

def quantize_cnn_images(params: dict, plan: MergePlan) -> dict:
    """One integer weight image per *distinct* (a,w) spec per layer — the
    deduplicated 'actors' of the merged datapath. Float specs keep the master."""
    images: dict[str, list] = {}
    for ln in plan.layer_names:
        w = params[ln]["w"]
        imgs = []
        for (_, wb) in plan.distinct_specs[ln]:
            if wb >= 17:
                imgs.append(w)
            else:
                # per-tensor po2 scale: bit-exact with the QAT fake-quant grid
                imgs.append(quantize_native(w, QuantSpec(bits=wb, po2_scale=True)))
        images[ln] = imgs
    return images


def cnn_forward_native(params: dict, images: dict, plan: MergePlan,
                       selectors: jax.Array, bits_row: jax.Array,
                       x: jax.Array) -> jax.Array:
    """Runtime-switched native engine: ``selectors[i]`` picks the weight image
    of layer i (from the merge plan), activations still follow ``bits_row``.

    Shared layers (1 image) compile with no switch at all — the HLO-visible
    resource sharing the tests assert."""

    def deq(im):
        return dequantize(im, jnp.float32) if isinstance(im, QTensor) else im

    def pick(i: int, ln: str):
        return switch_images(selectors[i], images[ln], deq)

    x = _conv_block(params["conv0"], x, CNN_INDEX.gather(bits_row, ["conv0"])[0],
                    w_override=pick(0, "conv0"))
    x = _conv_block(params["conv1"], x, CNN_INDEX.gather(bits_row, ["conv1"])[0],
                    w_override=pick(1, "conv1"))
    b = x.shape[0]
    x = x.reshape(b, -1)
    fb = CNN_INDEX.gather(bits_row, ["fc"])[0]
    xq = fake_quant_dynamic(x, fb[0], SIGNED_SYM)
    return xq @ pick(2, "fc") + params["fc"]["b"]

"""Rotary position embeddings: standard RoPE and multimodal M-RoPE (Qwen2-VL).

M-RoPE splits the head dimension into (temporal, height, width) sections, each
rotated by its own position stream; for pure-text positions (all three streams
equal) it reduces exactly to RoPE — the property the tests assert.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["rope_freqs", "apply_rope", "apply_mrope", "text_mrope_positions"]


def rope_freqs(head_dim: int, theta: float = 1e4) -> jax.Array:
    """Inverse frequencies ``[head_dim/2]``."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """``x [B, S, H, D]``, ``positions [B, S]`` int32 → rotated x (half-split layout)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                               # [D/2]
    ang = positions.astype(jnp.float32)[..., None] * inv     # [B, S, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: tuple[int, ...],
                theta: float = 1e4) -> jax.Array:
    """M-RoPE. ``positions [B, 3, S]`` (t/h/w streams); ``sections`` gives the
    number of *frequency pairs* per stream, summing to head_dim/2."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = rope_freqs(d, theta)                               # [D/2]
    # Select, per frequency index, which position stream drives it, then gather
    # the per-stream angles accordingly.
    stream_of = jnp.repeat(jnp.arange(len(sections)), jnp.asarray(sections),
                           total_repeat_length=d // 2)       # [D/2] in {0,1,2}
    ang_streams = positions.astype(jnp.float32)[:, :, :, None] * inv[None, None, None, :]  # [B,3,S,D/2]
    ang = jnp.take_along_axis(
        ang_streams,
        jnp.broadcast_to(stream_of[None, None, None, :],
                         (x.shape[0], 1, x.shape[1], d // 2)).astype(jnp.int32),
        axis=1,
    )[:, 0]                                                  # [B, S, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def text_mrope_positions(positions: jax.Array) -> jax.Array:
    """Text-only M-RoPE positions: the three streams coincide. ``[B,S]→[B,3,S]``."""
    return jnp.broadcast_to(positions[:, None, :], (positions.shape[0], 3, positions.shape[1]))

"""Pipeline parallelism across pods — the streaming-architecture analogue.

The paper's FPGA engine is a *spatial pipeline*: one hardware block per layer,
activations streaming block-to-block through on-chip FIFOs. At fleet scale the
same shape is pipeline parallelism: each pod owns a contiguous stage of layers
and microbatches stream stage-to-stage over the (slow) inter-pod links — the
exact reason the multi-pod mesh has a dedicated ``pod`` axis (DESIGN §5).

GPipe-style schedule inside ``shard_map`` over the stage axis:

    t = 0 .. (M + S − 2):   stage s processes microbatch (t − s) when valid;
    activations hop s → s+1 via ``lax.ppermute`` each tick.

The loop is a ``lax.fori_loop`` (compile-time compact); bubbles are the usual
(S−1)/(M+S−1) fraction. Forward-only here (the serving/streaming analogue);
training composes it with ``jax.grad`` through the loop or uses DP across
pods instead (the dry-run default).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax >= 0.5 moved shard_map to the top level (renaming check_rep → check_vma)
# and added lax.pcast for the varying-manual-axes check; on 0.4.x use the
# experimental entry point and a no-op pcast (carries need no varying mark).
import inspect

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma" if "check_vma" in
             inspect.signature(_shard_map).parameters else "check_rep")

def _pcast(x, axes, to):
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axes, to=to)
    return x

__all__ = ["pipeline_forward", "stage_split"]


def stage_split(params_stacked, n_stages: int):
    """Reshape layer-stacked params [L, ...] → [S, L/S, ...] (stage-major)."""
    def one(a):
        l = a.shape[0]
        assert l % n_stages == 0, f"{l} layers don't split into {n_stages} stages"
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])
    return jax.tree.map(one, params_stacked)


def pipeline_forward(stage_fn: Callable, params_staged, x: jax.Array, *,
                     mesh, axis_name: str = "pod",
                     n_microbatches: int) -> jax.Array:
    """Run ``x [B, ...]`` through S pipeline stages, microbatched.

    ``stage_fn(stage_params, xm) -> xm`` applies one stage's layers to one
    microbatch. ``params_staged`` has leading dim S (from :func:`stage_split`),
    sharded so stage s lives on pod s. Returns y with stage-S output for every
    microbatch, reassembled to ``[B, ...]``.
    """
    n_stages = mesh.shape[axis_name]
    b = x.shape[0]
    assert b % n_microbatches == 0
    mb = b // n_microbatches
    xm = x.reshape(n_microbatches, mb, *x.shape[1:])

    in_specs = (P(axis_name), P())      # params by stage; microbatches everywhere
    out_specs = P()

    def body(params_local, xm_all):
        # params_local: [1, L/S, ...] — this pod's stage
        sp = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis_name)
        n_ticks = n_microbatches + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (while valid); others use buf
            feed = jax.lax.dynamic_index_in_dim(
                xm_all, jnp.minimum(t, n_microbatches - 1), keepdims=False)
            x_in = jnp.where(stage == 0, feed, buf)
            y = stage_fn(sp, x_in)
            mb_idx = t - (n_stages - 1)       # microbatch exiting last stage
            is_out = (mb_idx >= 0) & (stage == n_stages - 1)
            mb_c = jnp.clip(mb_idx, 0, n_microbatches - 1)
            row = jnp.where(is_out, y,
                            jax.lax.dynamic_index_in_dim(outs, mb_c,
                                                         keepdims=False))
            outs = jax.lax.dynamic_update_index_in_dim(outs, row, mb_c, axis=0)
            buf = jax.lax.ppermute(y, axis_name, perm)
            return buf, outs

        # carries become device-varying inside the loop → mark them upfront
        buf0 = _pcast(jnp.zeros_like(xm_all[0]), (axis_name,), to="varying")
        outs0 = _pcast(jnp.zeros_like(xm_all), (axis_name,), to="varying")
        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf0, outs0))
        # only the last stage holds real outputs; broadcast via max-reduce
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis_name)
        return outs

    y = _shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, **{_CHECK_KW: False})(params_staged, xm)
    return y.reshape(b, *x.shape[1:])

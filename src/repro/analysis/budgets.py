"""Declarative per-scenario invariant budgets.

Each :class:`SegmentBudget` pins a reference geometry (the BENCH_4 paged
pool point, the BENCH_6 chaos point, and the BENCH_8 speculative
``draft_k``-wide point) and the ceilings a traced decode segment must
respect there:

- ``max_aval_bytes`` — no intermediate aval in the segment jaxpr may
  exceed this. The ceiling sits between the pallas in-place path's
  largest intermediate and the gather path's materialized
  ``[B, n_lblk*bs]`` view, so a kernel regression to the gather path
  fails the gate even before the bytes/step bench notices.
- ``forbid_gather_view`` — the ``(B, n_lblk*bs)``-adjacent-dims aval must
  not appear at all (named invariant ``no-gather-view``).

Runtime ceilings enforced by the scenario audit (``scripts/
check_static.py`` + :class:`repro.analysis.tracker.SchedulerAudit`):

- ``single-segment-executable`` — ``_segment._cache_size() == 1`` for the
  pool lifetime.
- ``max-prefill-waves`` — at most :data:`MAX_PREFILL_WAVES_PER_ROUND`
  admission-wave dispatches per ``admit()`` round (cold / shared /
  resume / chunk-continuation; imminent continuations pre-commit their
  share of the budget before new kinds classify).
- ``no-retrace`` — zero new cache entries after warmup.
- ``no-per-token-dispatch`` — the stepwise ``_decode`` executable is
  never dispatched by the fused serving path.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_check

# Runtime ceilings (see docs/serving.md "Invariants" 1 and 7).
SINGLE_SEGMENT_EXECUTABLES = 1
MAX_PREFILL_WAVES_PER_ROUND = 2


@dataclass(frozen=True)
class SegmentBudget:
    """Aval-byte ceiling for a decode-segment trace at a fixed geometry."""

    name: str
    arch: str
    batch: int
    slots: int           # per-row token capacity
    block_size: int
    pool_blocks: int | None
    kv_bits: int
    steps: int
    max_aval_bytes: int
    forbid_gather_view: bool = True
    draft_k: int = 0     # > 0: trace the speculative W = k+1 segment

    @property
    def slots_padded(self) -> int:
        return -(-self.slots // self.block_size) * self.block_size


# Ceilings calibrated empirically on the smoke configs (see
# tests/test_analysis.py::test_reference_budgets_pass_on_pallas): the
# pallas path's largest intermediate at each point, plus ~25% headroom —
# comfortably below the gather path's materialized view at the same
# geometry, so flipping the backend (or regressing the kernel to a
# gather) trips the budget.
REFERENCE_BUDGETS: tuple[SegmentBudget, ...] = (
    # BENCH_4 paged-pool reference point: 64-block pool, bs=16, batch 8.
    SegmentBudget(
        name="bench4-kv16",
        arch="granite-3-2b",
        batch=8,
        slots=128,
        block_size=16,
        pool_blocks=64,
        kv_bits=16,
        steps=4,
        max_aval_bytes=163_840,
    ),
    SegmentBudget(
        name="bench4-kv8",
        arch="granite-3-2b",
        batch=8,
        slots=128,
        block_size=16,
        pool_blocks=64,
        kv_bits=8,
        steps=4,
        max_aval_bytes=163_840,
    ),
    # BENCH_10 packed-int4 point: same geometry as BENCH_4, half the kv8
    # pool bytes. The packed view is small enough that the aval ceiling
    # alone no longer separates the backends — the no-gather-view
    # invariant does: the pallas path must never materialize the
    # [B, n_lblk*bs] packed view (measured pallas peak 131,072 B; the
    # ceiling keeps the standard ~25% headroom above it).
    SegmentBudget(
        name="bench10-kv4",
        arch="granite-3-2b",
        batch=8,
        slots=128,
        block_size=16,
        pool_blocks=64,
        kv_bits=4,
        steps=4,
        max_aval_bytes=163_840,
    ),
    # BENCH_6 chaos point: tiny 10-block pool under drought, batch 4.
    SegmentBudget(
        name="bench6-chaos-kv16",
        arch="granite-3-2b",
        batch=4,
        slots=40,
        block_size=16,
        pool_blocks=10,
        kv_bits=16,
        steps=4,
        max_aval_bytes=163_840,
    ),
    # BENCH_8 speculative point: every activation aval in the verify
    # window is W = draft_k + 1 wide, yet the ceiling is the SAME as the
    # greedy points — the k-query pallas variant folds W into the head
    # grid instead of materializing per-query (let alone per-window)
    # pool views, so a regression that does trips this budget first.
    SegmentBudget(
        name="bench8-spec-kv8",
        arch="granite-3-2b",
        batch=8,
        slots=128,
        block_size=16,
        pool_blocks=64,
        kv_bits=8,
        steps=2,
        max_aval_bytes=163_840,
        draft_k=4,
    ),
)


def trace_segment(parts, backend: str, budget: SegmentBudget):
    """Trace ``decode_segment`` at the budget's geometry.

    ``parts`` is the ``(cfg, params, eng)`` triple from the smoke build.
    Returns the closed jaxpr; pair with :func:`repro.analysis.jaxpr_check.
    check_aval_budget` / :func:`~repro.analysis.jaxpr_check.
    has_adjacent_dims` to enforce the budget.
    """
    from repro.models import transformer as T

    cfg, params, eng = parts
    caches = T.init_paged_caches(
        cfg,
        budget.batch,
        budget.slots,
        kv_bits=budget.kv_bits,
        block_size=budget.block_size,
        pool_blocks=budget.pool_blocks,
    )
    table = jnp.asarray(eng.table)
    prequant = T.prequant_decode_weights(params, cfg, table)

    def seg(schedule, tok, pos, cch, remaining):
        if budget.draft_k:
            return T.decode_segment_spec(
                params, cfg, table, schedule, tok, pos, cch, remaining,
                prequant=prequant, paged_backend=backend,
                draft_k=budget.draft_k)
        return T.decode_segment(params, cfg, table, schedule, tok, pos, cch,
                                remaining, prequant=prequant,
                                paged_backend=backend)

    b = budget.batch
    return jax.make_jaxpr(seg)(
        jnp.zeros((budget.steps,), jnp.int32), jnp.zeros((b,), jnp.int32),
        jnp.zeros((b,), jnp.int32), caches, jnp.zeros((b,), jnp.int32))


@dataclass(frozen=True)
class BudgetReport:
    budget: SegmentBudget
    backend: str
    max_bytes: int
    violations: tuple
    gather_view: bool

    @property
    def ok(self) -> bool:
        if self.violations:
            return False
        if self.budget.forbid_gather_view and self.gather_view:
            return False
        return True

    def render(self) -> str:
        status = "ok" if self.ok else "FAIL"
        lines = [
            f"[{status}] {self.budget.name} ({self.backend}): "
            f"max aval {self.max_bytes:,} B / budget "
            f"{self.budget.max_aval_bytes:,} B"
        ]
        for v in self.violations[:5]:
            lines.append(f"    over budget: {v.render()}")
        if self.budget.forbid_gather_view and self.gather_view:
            lines.append(
                f"    gather view present: adjacent dims "
                f"({self.budget.batch}, {self.budget.slots_padded})"
            )
        return "\n".join(lines)


def check_budget(parts, budget: SegmentBudget,
                 backend: str = "pallas") -> BudgetReport:
    """Trace the segment at the budget point and evaluate every ceiling."""
    jaxpr = trace_segment(parts, backend, budget)
    return BudgetReport(
        budget=budget,
        backend=backend,
        max_bytes=jaxpr_check.max_aval_bytes(jaxpr),
        violations=tuple(
            jaxpr_check.check_aval_budget(jaxpr, budget.max_aval_bytes)
        ),
        gather_view=jaxpr_check.has_adjacent_dims(
            jaxpr, (budget.batch, budget.slots_padded)
        ),
    )

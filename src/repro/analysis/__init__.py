"""Static analysis for hot-path discipline.

Three layers, each usable on its own:

- :mod:`repro.analysis.lint` — AST rules over source files (host syncs,
  jit-boundary hygiene, device-constant smells) with per-line
  ``# repro: allow(rule-id)`` suppression.
- :mod:`repro.analysis.jaxpr_check` — invariant checks over traced jaxprs
  (aval byte budgets, forbidden shapes, primitive counts, donation).
- :mod:`repro.analysis.tracker` — runtime dispatch/retrace auditing for
  jitted executables bound on a server or scheduler.

:mod:`repro.analysis.budgets` pins the reference-scenario ceilings that
``scripts/check_static.py`` enforces in CI.
"""

from repro.analysis.lint import Finding, lint_file, lint_source, lint_tree
from repro.analysis.jaxpr_check import (
    count_primitives,
    count_transfers,
    forbid_aval_shape,
    has_adjacent_dims,
    iter_eqns,
    max_aval_bytes,
    verify_donation,
)
from repro.analysis.tracker import DispatchAudit, SchedulerAudit

__all__ = [
    "Finding",
    "lint_file",
    "lint_source",
    "lint_tree",
    "iter_eqns",
    "max_aval_bytes",
    "forbid_aval_shape",
    "has_adjacent_dims",
    "count_primitives",
    "count_transfers",
    "verify_donation",
    "DispatchAudit",
    "SchedulerAudit",
]

"""AST lint rules for hot-path discipline.

Rules (each has a kebab-case ID, a fix hint, and an inline escape hatch):

- ``host-sync`` — a construct that forces device->host synchronization
  inside a hot-path scope: ``.item()``, ``.block_until_ready()``,
  ``int()/float()/bool()`` applied to a device-tainted expression, or
  ``np.asarray``/``np.array`` of a device-tainted expression.
- ``missing-donate`` — ``jax.jit`` of a locally-defined function that
  threads a carry (a parameter named ``caches`` or ``carry``) without
  ``donate_argnums``/``donate_argnames``.
- ``tracer-branch`` — Python ``if``/``while`` on a bare parameter name
  inside a function that is ``jax.jit``-ed in the same module; under jit
  the parameter is a tracer and the branch either fails or bakes in a
  constant.
- ``late-closure`` — a nested ``def``/``lambda`` reading a local variable
  that is first assigned *after* the nested function's definition line;
  under jit the closure captures whatever the name holds at trace time.
- ``device-constant`` — a large literal list/tuple (>= 64 scalar
  elements) passed to ``jnp.array``/``jnp.asarray``/``np.array`` inside a
  hot-path scope; constants this size should be loaded once at module
  scope, not re-materialized per trace.

Suppression: append ``# repro: allow(rule-id) <reason>`` on the finding
line, the line directly above it, or the ``def`` line of the enclosing
function (which suppresses the rule for the whole function body).

Device taint is a deliberately simple single-pass, per-function dataflow:
names become tainted when assigned from ``jnp.*``/``lax.*`` calls, from
calls of known jitted-executable attributes (``self._segment`` etc.), from
attributes/names that are conventionally device arrays in this codebase
(``_tok``, ``_pos``, ``_caches``), or from subscripting a tainted value.
``np.asarray(x)`` on a tainted ``x`` is itself a finding, and its result
is treated as host (taint cleared) so downstream ``int()`` calls on the
materialized copy do not double-report.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Iterable, Sequence

# ---------------------------------------------------------------------------
# Rule registry


@dataclass(frozen=True)
class Rule:
    id: str
    summary: str
    hint: str


RULES: dict[str, Rule] = {
    r.id: r
    for r in [
        Rule(
            "host-sync",
            "device->host synchronization in a hot-path scope",
            "keep the value on device (lax.cond / jnp ops), or move the sync "
            "to a flush boundary and allowlist it with a justification",
        ),
        Rule(
            "missing-donate",
            "jax.jit of a carry-threading function without donate_argnums",
            "pass donate_argnums=(i,) for the carry parameter so XLA can "
            "reuse its buffer in place",
        ),
        Rule(
            "tracer-branch",
            "Python branch on a jit parameter (a tracer at trace time)",
            "use lax.cond/lax.select or jnp.where on the traced value",
        ),
        Rule(
            "late-closure",
            "closure reads a local assigned after the nested def",
            "bind the value as a default argument or define it before the "
            "nested function",
        ),
        Rule(
            "device-constant",
            "large literal array constructed inside a hot-path function",
            "hoist the constant to module scope so it is materialized once",
        ),
    ]
}

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\(([\w-]+)\)")

# Device-taint seeds: calls of these attribute names return device arrays.
_JITTED_ATTRS = {
    "_prefill",
    "_decode",
    "_generate",
    "_segment",
    "_admit",
    "_admit_paged",
    "_admit_shared",
    "_admit_restore",
    "_clear",
    "_clear_rows",
}
# Attributes / names conventionally holding device arrays in this codebase.
_DEVICE_NAMES = {"_tok", "_pos", "_caches"}
# Dict keys whose values are device arrays (flush entries).
_DEVICE_KEYS = {"toks", "ok"}

_COERCIONS = {"int", "float", "bool"}
_NP_MATERIALIZE = {"asarray", "array"}
_DEVICE_CONSTANT_MIN = 64


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    @property
    def hint(self) -> str:
        return RULES[self.rule].hint

    def render(self) -> str:
        return (
            f"{self.path}:{self.line}: [{self.rule}] {self.message}\n"
            f"    hint: {RULES[self.rule].hint}"
        )


# ---------------------------------------------------------------------------
# Hot-path scoping


@dataclass(frozen=True)
class HotPathSpec:
    """Which (file, function) pairs the hot-path rules apply to.

    ``dirs`` — every function in any file under these directories is hot.
    ``files`` — every function in these files is hot.
    ``func_substr`` — maps a file to a substring; only functions whose
    name contains the substring are hot in that file.
    """

    dirs: tuple[str, ...] = ()
    files: tuple[str, ...] = ()
    func_substr: tuple[tuple[str, str], ...] = ()

    def file_in_scope(self, rel: str) -> bool:
        rel = rel.replace("\\", "/")
        if any(rel == f for f in self.files):
            return True
        if any(d == "" or rel.startswith(d.rstrip("/") + "/") for d in self.dirs):
            return True
        return any(rel == f for f, _ in self.func_substr)

    def func_is_hot(self, rel: str, func_name: str) -> bool:
        rel = rel.replace("\\", "/")
        for f, sub in self.func_substr:
            if rel == f:
                return sub in func_name
        return self.file_in_scope(rel)


# The tree spec used by scripts/check_static.py: kernels and the serving
# scheduler/engine are hot everywhere; in the model stack only decode-path
# functions are hot (prefill/training paths may sync freely).
DEFAULT_SPEC = HotPathSpec(
    dirs=("kernels",),
    files=("serving/scheduler.py", "serving/engine.py"),
    func_substr=(("models/transformer.py", "decode"),),
)

# Fixture/test spec: everything is hot.
ALL_HOT = HotPathSpec(dirs=("",), files=())


# ---------------------------------------------------------------------------
# Helpers over the AST


def _call_root(node: ast.AST) -> str | None:
    """Dotted-name root of a call target: jnp.asarray -> 'jnp'."""
    while isinstance(node, ast.Attribute):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def _dotted(node: ast.AST) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _literal_len(node: ast.AST) -> int:
    """Number of scalar constants in a (possibly nested) list/tuple literal."""
    if isinstance(node, (ast.List, ast.Tuple)):
        return sum(_literal_len(e) for e in node.elts)
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float, complex)):
        return 1
    if (
        isinstance(node, ast.BinOp)
        and isinstance(node.op, ast.Mult)
        and isinstance(node.right, ast.Constant)
        and isinstance(node.right.value, int)
    ):
        return _literal_len(node.left) * node.right.value
    return 0


class _TaintTracker:
    """Single-pass per-function device-taint approximation."""

    def __init__(self) -> None:
        self.tainted: set[str] = set()

    def expr_is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _DEVICE_NAMES:
                return True
            return self.expr_is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            if isinstance(node.slice, ast.Constant) and node.slice.value in _DEVICE_KEYS:
                return True
            return self.expr_is_tainted(node.value)
        if isinstance(node, ast.Call):
            root = _call_root(node.func)
            if root in ("jnp", "lax"):
                return True
            if isinstance(node.func, ast.Attribute) and node.func.attr in _JITTED_ATTRS:
                return True
            dotted = _dotted(node.func) or ""
            if dotted.startswith("jax.") and not dotted.startswith("jax.debug"):
                return True
            # method call on a tainted value: x.sum(), cache.at[...].set(...)
            if isinstance(node.func, ast.Attribute) and self.expr_is_tainted(
                node.func.value
            ):
                return True
            return False
        if isinstance(node, ast.BinOp):
            return self.expr_is_tainted(node.left) or self.expr_is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr_is_tainted(node.operand)
        if isinstance(node, ast.Compare):
            return self.expr_is_tainted(node.left) or any(
                self.expr_is_tainted(c) for c in node.comparators
            )
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr_is_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.expr_is_tainted(node.body) or self.expr_is_tainted(node.orelse)
        if isinstance(node, ast.Starred):
            return self.expr_is_tainted(node.value)
        return False

    def _mark(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._mark(e, tainted)
        # Attribute/subscript targets: taint is tracked on the base name.

    def observe_assign(self, node: ast.Assign | ast.AugAssign | ast.AnnAssign) -> None:
        value = node.value
        if value is None:
            return
        tainted = self.expr_is_tainted(value)
        # np.asarray(...) materializes to host: result is NOT tainted.
        if (
            isinstance(value, ast.Call)
            and _call_root(value.func) == "np"
            and isinstance(value.func, ast.Attribute)
            and value.func.attr in _NP_MATERIALIZE
        ):
            tainted = False
        if isinstance(node, ast.Assign):
            for t in node.targets:
                self._mark(t, tainted)
        else:
            self._mark(node.target, tainted)


# ---------------------------------------------------------------------------
# Allow-pragma handling


class _Allowlist:
    def __init__(self, source_lines: Sequence[str]) -> None:
        # line number (1-based) -> set of allowed rule ids on that line
        self.by_line: dict[int, set[str]] = {}
        for i, text in enumerate(source_lines, start=1):
            ids = {m.group(1) for m in _ALLOW_RE.finditer(text)}
            if ids:
                self.by_line[i] = ids
        # def-line allows extend over the function body; filled by the linter.
        self.by_range: list[tuple[int, int, set[str]]] = []

    def add_function_scope(self, def_line: int, end_line: int) -> None:
        ids = self.by_line.get(def_line)
        if ids:
            self.by_range.append((def_line, end_line, set(ids)))

    def allows(self, line: int, rule: str) -> bool:
        for probe in (line, line - 1):
            if rule in self.by_line.get(probe, set()):
                return True
        return any(lo <= line <= hi and rule in ids for lo, hi, ids in self.by_range)


# ---------------------------------------------------------------------------
# The linter


class _Linter(ast.NodeVisitor):
    def __init__(self, rel_path: str, source: str, spec: HotPathSpec) -> None:
        self.rel = rel_path
        self.spec = spec
        self.lines = source.splitlines()
        self.allow = _Allowlist(self.lines)
        self.findings: list[Finding] = []
        # module-level pass 1 state
        self.jitted_func_names: set[str] = set()  # local defs passed to jax.jit
        self.local_defs: dict[str, ast.FunctionDef] = {}
        self._func_stack: list[ast.FunctionDef] = []
        self._taint_stack: list[_TaintTracker] = []

    # -- driving ------------------------------------------------------------

    def run(self, tree: ast.Module) -> list[Finding]:
        self._collect_defs(tree)
        self._collect_jit_targets(tree)
        self.visit(tree)
        return self.findings

    def _collect_defs(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.local_defs[node.name] = node  # type: ignore[assignment]
                self.allow.add_function_scope(node.lineno, node.end_lineno or node.lineno)

    def _collect_jit_targets(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted not in ("jax.jit", "jit"):
                continue
            if node.args and isinstance(node.args[0], ast.Name):
                self.jitted_func_names.add(node.args[0].id)

    def _emit(self, node: ast.AST, rule: str, message: str) -> None:
        line = getattr(node, "lineno", 0)
        if self.allow.allows(line, rule):
            return
        self.findings.append(Finding(self.rel, line, rule, message))

    def _in_hot_func(self) -> bool:
        if not self._func_stack:
            return False
        return self.spec.func_is_hot(self.rel, self._func_stack[0].name)

    @property
    def _taint(self) -> _TaintTracker | None:
        return self._taint_stack[-1] if self._taint_stack else None

    # -- function scoping ---------------------------------------------------

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_late_closure_container(node)
        self._func_stack.append(node)
        tracker = _TaintTracker()
        # Parameters named like device carries seed the taint set.
        for arg in list(node.args.args) + list(node.args.kwonlyargs):
            if arg.arg in ("caches", "carry", "tok", "pos") or arg.arg in _DEVICE_NAMES:
                tracker.tainted.add(arg.arg)
        self._taint_stack.append(tracker)
        self.generic_visit(node)
        self._taint_stack.pop()
        self._func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    # -- assignments feed the taint tracker ---------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        if self._taint is not None:
            self._taint.observe_assign(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        if self._taint is not None:
            self._taint.observe_assign(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self.generic_visit(node)
        if self._taint is not None:
            self._taint.observe_assign(node)

    # -- host-sync ----------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        hot = self._in_hot_func()
        taint = self._taint

        if hot and isinstance(node.func, ast.Attribute):
            if node.func.attr == "item" and not node.args:
                self._emit(node, "host-sync", ".item() forces a device sync")
            elif node.func.attr == "block_until_ready":
                self._emit(
                    node, "host-sync", ".block_until_ready() outside benchmarks"
                )

        if hot and taint is not None:
            # int()/float()/bool() on a tainted expression
            if (
                isinstance(node.func, ast.Name)
                and node.func.id in _COERCIONS
                and node.args
                and taint.expr_is_tainted(node.args[0])
            ):
                self._emit(
                    node,
                    "host-sync",
                    f"{node.func.id}() on a device value pulls it to host",
                )
            # np.asarray / np.array on a tainted expression
            if (
                isinstance(node.func, ast.Attribute)
                and _call_root(node.func) == "np"
                and node.func.attr in _NP_MATERIALIZE
                and node.args
                and taint.expr_is_tainted(node.args[0])
            ):
                self._emit(
                    node,
                    "host-sync",
                    f"np.{node.func.attr}() of a device value forces a transfer",
                )

        # missing-donate: jax.jit(fn) of a local def threading a carry
        dotted = _dotted(node.func)
        if dotted in ("jax.jit", "jit") and node.args:
            self._check_missing_donate(node)

        # device-constant: big literal into an array constructor
        if hot and isinstance(node.func, ast.Attribute):
            root = _call_root(node.func)
            if root in ("jnp", "np") and node.func.attr in ("array", "asarray"):
                if node.args and _literal_len(node.args[0]) >= _DEVICE_CONSTANT_MIN:
                    self._emit(
                        node,
                        "device-constant",
                        f"literal array of {_literal_len(node.args[0])} elements "
                        "built inside a hot function",
                    )

        self.generic_visit(node)

    def _check_missing_donate(self, node: ast.Call) -> None:
        target = node.args[0]
        if not isinstance(target, ast.Name):
            return
        fn = self.local_defs.get(target.id)
        if fn is None:
            return
        params = [a.arg for a in fn.args.args]
        if not any(p in ("caches", "carry") for p in params):
            return
        kw_names = {k.arg for k in node.keywords}
        if not kw_names & {"donate_argnums", "donate_argnames"}:
            self._emit(
                node,
                "missing-donate",
                f"jax.jit({target.id}) threads a carry "
                f"({[p for p in params if p in ('caches', 'carry')][0]!r}) "
                "without donate_argnums",
            )

    # -- tracer-branch ------------------------------------------------------

    def _branch_on_param(self, test: ast.AST) -> str | None:
        if not self._func_stack:
            return None
        fn = self._func_stack[-1]
        if fn.name not in self.jitted_func_names:
            return None
        params = {a.arg for a in fn.args.args} | {a.arg for a in fn.args.kwonlyargs}
        if isinstance(test, ast.Name) and test.id in params:
            return test.id
        return None

    def visit_If(self, node: ast.If) -> None:
        name = self._branch_on_param(node.test)
        if name is not None:
            self._emit(
                node, "tracer-branch", f"`if {name}:` inside a jitted function"
            )
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        name = self._branch_on_param(node.test)
        if name is not None:
            self._emit(
                node, "tracer-branch", f"`while {name}:` inside a jitted function"
            )
        self.generic_visit(node)

    # -- late-closure -------------------------------------------------------

    def _check_late_closure_container(self, node: ast.FunctionDef) -> None:
        """For each nested def/lambda in `node`, flag reads of locals first
        assigned after the nested function's definition line."""
        assign_line: dict[str, int] = {}
        for a in list(node.args.args) + list(node.args.kwonlyargs):
            assign_line.setdefault(a.arg, node.lineno)
        nested: list[ast.FunctionDef | ast.Lambda] = []

        def scan(n: ast.AST) -> None:
            for child in ast.iter_child_nodes(n):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                    nested.append(child)  # do not descend: its locals are its own
                    continue
                if isinstance(child, ast.Assign):
                    for t in child.targets:
                        for nm in _target_names(t):
                            assign_line.setdefault(nm, child.lineno)
                elif isinstance(child, (ast.AugAssign, ast.AnnAssign)):
                    for nm in _target_names(child.target):
                        assign_line.setdefault(nm, child.lineno)
                elif isinstance(child, ast.For):
                    for nm in _target_names(child.target):
                        assign_line.setdefault(nm, child.lineno)
                scan(child)

        scan(node)
        for fn in nested:
            own = _local_names(fn)
            for name_node in ast.walk(fn):
                if not isinstance(name_node, ast.Name) or not isinstance(
                    name_node.ctx, ast.Load
                ):
                    continue
                nm = name_node.id
                if nm in own:
                    continue
                first = assign_line.get(nm)
                if first is not None and first > fn.lineno:
                    self._emit(
                        fn,
                        "late-closure",
                        f"closure reads {nm!r}, first assigned at line {first} "
                        f"(after the def at line {fn.lineno})",
                    )
                    break  # one finding per nested function is enough


def _target_names(t: ast.AST) -> Iterable[str]:
    if isinstance(t, ast.Name):
        yield t.id
    elif isinstance(t, (ast.Tuple, ast.List)):
        for e in t.elts:
            yield from _target_names(e)


def _local_names(fn: ast.FunctionDef | ast.Lambda) -> set[str]:
    names: set[str] = set()
    args = fn.args
    for a in list(args.args) + list(args.kwonlyargs) + list(args.posonlyargs):
        names.add(a.arg)
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                names.add(node.id)
    return names


# ---------------------------------------------------------------------------
# Public API


def lint_source(
    source: str, rel_path: str = "<string>", spec: HotPathSpec = ALL_HOT
) -> list[Finding]:
    tree = ast.parse(source)
    return _Linter(rel_path, source, spec).run(tree)


def lint_file(path: str | Path, root: str | Path | None = None,
              spec: HotPathSpec = DEFAULT_SPEC) -> list[Finding]:
    path = Path(path)
    rel = str(path.relative_to(root)) if root is not None else path.name
    return lint_source(path.read_text(), rel, spec)


def lint_tree(
    root: str | Path,
    spec: HotPathSpec = DEFAULT_SPEC,
    exclude: Callable[[str], bool] | None = None,
) -> list[Finding]:
    """Lint every ``.py`` under ``root`` whose relative path is in scope."""
    root = Path(root)
    findings: list[Finding] = []
    for path in sorted(root.rglob("*.py")):
        rel = str(path.relative_to(root)).replace("\\", "/")
        if exclude is not None and exclude(rel):
            continue
        if not spec.file_in_scope(rel):
            continue
        findings.extend(lint_source(path.read_text(), rel, spec))
    return findings

"""Invariant checks over traced jaxprs and compiled executables.

These formalize what the tests previously hand-rolled: walk every
equation (recursing into nested jaxprs carried in eqn params, e.g.
``scan``/``cond``/``pjit`` bodies), and assert properties of the
intermediate avals — byte ceilings, forbidden shapes, primitive counts —
plus donation verification via the lowered executable text.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Callable, Iterable

import numpy as np

from jax.core import Jaxpr, JaxprEqn

_TRANSFER_PRIMITIVES = {"device_put", "convert_element_type_to_host", "copy"}


def _nested_jaxprs(eqn: JaxprEqn) -> Iterable[Jaxpr]:
    for val in eqn.params.values():
        objs = val if isinstance(val, (list, tuple)) else [val]
        for obj in objs:
            if hasattr(obj, "jaxpr"):  # ClosedJaxpr
                yield obj.jaxpr
            elif isinstance(obj, Jaxpr):
                yield obj


def iter_eqns(jaxpr) -> Iterable[JaxprEqn]:
    """Yield every equation in ``jaxpr``, recursing into nested jaxprs."""
    if hasattr(jaxpr, "jaxpr"):  # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _nested_jaxprs(eqn):
            yield from iter_eqns(sub)


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    try:
        n = int(np.prod(shape)) if shape else 1
        return n * np.dtype(dtype).itemsize
    except Exception:
        return 0


@dataclass(frozen=True)
class AvalViolation:
    primitive: str
    shape: tuple
    dtype: str
    nbytes: int

    def render(self) -> str:
        return (
            f"{self.primitive}: {self.dtype}{list(self.shape)} = "
            f"{self.nbytes:,} bytes"
        )


def max_aval_bytes(jaxpr) -> int:
    """Largest intermediate aval (in bytes) anywhere in the jaxpr."""
    best = 0
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            best = max(best, _aval_bytes(var.aval))
    return best


def check_aval_budget(jaxpr, budget_bytes: int) -> list[AvalViolation]:
    """Every intermediate aval whose size exceeds ``budget_bytes``."""
    out: list[AvalViolation] = []
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            nbytes = _aval_bytes(var.aval)
            if nbytes > budget_bytes:
                aval = var.aval
                out.append(
                    AvalViolation(
                        str(eqn.primitive),
                        tuple(getattr(aval, "shape", ())),
                        str(getattr(aval, "dtype", "?")),
                        nbytes,
                    )
                )
    return out


def forbid_aval_shape(jaxpr, pred: Callable[[tuple], bool]) -> list[AvalViolation]:
    """Every intermediate aval whose shape satisfies ``pred``."""
    out: list[AvalViolation] = []
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            shape = tuple(getattr(var.aval, "shape", ()))
            if shape and pred(shape):
                out.append(
                    AvalViolation(
                        str(eqn.primitive),
                        shape,
                        str(getattr(var.aval, "dtype", "?")),
                        _aval_bytes(var.aval),
                    )
                )
    return out


def has_adjacent_dims(jaxpr, dims: tuple[int, int]) -> bool:
    """True if any intermediate aval has ``dims`` as adjacent dimensions.

    This is the gather-view signature: the materialized paged view is
    ``[B, n_lblk*bs]``-shaped (batch adjacent to padded slot count), which
    the in-place pallas path must never produce.
    """
    a, b = dims

    def pred(shape: tuple) -> bool:
        return any(
            shape[i] == a and shape[i + 1] == b for i in range(len(shape) - 1)
        )

    return bool(forbid_aval_shape(jaxpr, pred))


def count_primitives(jaxpr) -> Counter:
    """Histogram of primitive names over the whole (recursive) jaxpr."""
    return Counter(str(eqn.primitive) for eqn in iter_eqns(jaxpr))


def count_transfers(jaxpr) -> int:
    """Number of explicit host/device transfer primitives in the jaxpr."""
    counts = count_primitives(jaxpr)
    return sum(counts[p] for p in _TRANSFER_PRIMITIVES)


def verify_donation(jitted, *args, **kwargs) -> bool:
    """True if the lowered executable aliases at least one input buffer to
    an output (i.e. donation actually took effect, not just requested).

    Works by lowering with the given abstract/concrete args and searching
    the StableHLO text for the aliasing attribute; robust across jax
    versions that do not expose ``input_output_aliases`` on Compiled.
    """
    lowered = jitted.lower(*args, **kwargs)
    text = lowered.as_text()
    return "tf.aliasing_output" in text or "jax.buffer_donor" in text

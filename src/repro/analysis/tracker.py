"""Runtime dispatch/retrace auditing for jitted executables.

``DispatchAudit`` wraps named executable attributes on any object (an
``AdaptiveServer``, a ``ContinuousScheduler``, a module) and counts every
dispatch while the context is open, so scenarios can assert "N dispatches"
and "zero retraces after warmup" declaratively instead of hand-rolling
monkeypatches per test.

``SchedulerAudit`` extends it with admission-round bracketing: it wraps
``scheduler.admit`` so the prefill-wave executables' dispatch deltas are
recorded *per round*, which is what the ≤2-prefill-waves invariant is
actually about.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence


def _cache_size(fn) -> int | None:
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return probe()
    except Exception:
        return None


@dataclass
class _Wrapped:
    name: str
    original: Any
    calls: int = 0
    forbidden: bool = False


class DispatchAudit:
    """Count dispatches of jitted attributes on ``target`` while open.

    >>> with DispatchAudit(server, ["_decode", "_generate"]) as audit:
    ...     audit.forbid("_decode")   # any call raises AssertionError
    ...     server.generate(prompts, max_new=6)
    >>> audit.calls("_generate")
    1
    >>> audit.assert_no_retrace()
    """

    def __init__(self, target: Any, names: Sequence[str]):
        self.target = target
        self.names = list(names)
        self._wrapped: dict[str, _Wrapped] = {}
        self._cache_at_enter: dict[str, int | None] = {}

    def __enter__(self) -> "DispatchAudit":
        for name in self.names:
            original = getattr(self.target, name)
            w = _Wrapped(name, original)
            self._wrapped[name] = w
            self._cache_at_enter[name] = _cache_size(original)

            def make(wrec: _Wrapped):
                def counted(*args, **kwargs):
                    if wrec.forbidden:
                        raise AssertionError(
                            f"forbidden executable {wrec.name!r} was dispatched"
                        )
                    wrec.calls += 1
                    return wrec.original(*args, **kwargs)

                return counted

            setattr(self.target, name, make(w))
        return self

    def __exit__(self, *exc) -> None:
        for name, w in self._wrapped.items():
            setattr(self.target, name, w.original)

    # -- assertions ---------------------------------------------------------

    def forbid(self, name: str) -> None:
        """Any subsequent dispatch of ``name`` raises AssertionError."""
        self._wrapped[name].forbidden = True

    def calls(self, name: str) -> int:
        return self._wrapped[name].calls

    def cache_size(self, name: str) -> int | None:
        return _cache_size(self._wrapped[name].original)

    def assert_no_retrace(self, names: Sequence[str] | None = None) -> None:
        """Assert no executable compiled new entries since ``__enter__``.

        Executables that were cold at enter (cache size 0) are allowed to
        reach exactly 1 — the warmup trace; anything past that is a
        retrace.
        """
        for name in names if names is not None else self.names:
            before = self._cache_at_enter[name]
            after = self.cache_size(name)
            if before is None or after is None:
                continue
            ceiling = max(before, 1)
            if after > ceiling:
                raise AssertionError(
                    f"{name!r} retraced: cache size {before} -> {after}"
                )

    def assert_single_executable(self, name: str) -> None:
        size = self.cache_size(name)
        if size != 1:
            raise AssertionError(
                f"{name!r} should have exactly ONE cached executable, has {size}"
            )


_ADMIT_NAMES = ("_admit", "_admit_paged", "_admit_shared", "_admit_restore")


class SchedulerAudit(DispatchAudit):
    """DispatchAudit over a ``ContinuousScheduler`` with per-admission-round
    prefill-wave bracketing.

    The audited invariants (see docs/serving.md "Invariants"):

    - ``single-segment-executable`` — ``assert_single_segment()``
    - ``max-prefill-waves`` — ``assert_max_prefill_waves(2)``
    - ``no-retrace`` — ``assert_no_retrace()``
    """

    def __init__(self, scheduler: Any, extra_names: Sequence[str] = ()):
        names = ["_segment"]
        names += [n for n in _ADMIT_NAMES if getattr(scheduler, n, None) is not None]
        names += [n for n in extra_names if n not in names]
        super().__init__(scheduler, names)
        self.prefill_waves_per_round: list[int] = []
        self._admit_original = None

    def __enter__(self) -> "SchedulerAudit":
        super().__enter__()
        self._admit_original = self.target.admit
        audit = self

        def bracketed_admit(*args, **kwargs):
            before = sum(
                audit.calls(n) for n in _ADMIT_NAMES if n in audit._wrapped
            )
            out = audit._admit_original(*args, **kwargs)
            after = sum(
                audit.calls(n) for n in _ADMIT_NAMES if n in audit._wrapped
            )
            audit.prefill_waves_per_round.append(after - before)
            return out

        self.target.admit = bracketed_admit
        return self

    def __exit__(self, *exc) -> None:
        # `admit` is a class method wrapped via an instance attribute; remove
        # the shadow rather than pinning a stale bound method.
        self.target.__dict__.pop("admit", None)
        super().__exit__(*exc)

    # -- named invariants ----------------------------------------------------

    def assert_single_segment(self) -> None:
        self.assert_single_executable("_segment")

    def assert_max_prefill_waves(self, ceiling: int = 2) -> None:
        if not self.prefill_waves_per_round:
            return
        worst = max(self.prefill_waves_per_round)
        if worst > ceiling:
            raise AssertionError(
                f"an admission round dispatched {worst} prefill waves "
                f"(ceiling {ceiling}): {self.prefill_waves_per_round}"
            )

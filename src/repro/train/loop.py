"""Training loop with QAT, checkpoint/restart fault tolerance, and
straggler monitoring.

The loop is deliberately *stateless between steps* apart from
(params, opt_state, ef_state): the data pipeline is a pure function of the
step index (``TokenStream.batch_at``), so a restart from checkpoint replays
bit-exactly — the property ``tests/test_fault_tolerance.py`` asserts by
killing a run mid-flight and diffing the recovered parameters.

Fault-tolerance model for 1000+ nodes (documented; single-host container
exercises the same code paths):

* **checkpoint/restart** — CheckpointManager with atomic commits; on any node
  failure the job restarts from the newest committed step (same or different
  mesh — elastic restore re-places leaves).
* **straggler mitigation** — StragglerMonitor tracks per-step wall time and
  flags outliers (> mean + k·σ); at scale the launcher (launch/train.py)
  responds by excluding the slow host from the next allocation (backup-worker
  policy). The monitor and its triggering are unit-tested with injected
  latencies.
* **preemption** — SIGTERM sets a flag; the loop checkpoints and exits cleanly
  (tested via the failure-injection hook).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update

__all__ = ["TrainConfig", "StragglerMonitor", "train", "make_train_step"]


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 25
    keep: int = 3
    log_every: int = 10
    # failure injection for fault-tolerance tests: raise at this step once
    fail_at_step: Optional[int] = None


class StragglerMonitor:
    """Flags abnormally slow steps (straggler detection at the host level)."""

    def __init__(self, window: int = 20, k_sigma: float = 3.0, min_steps: int = 5):
        self.window = window
        self.k = k_sigma
        self.min_steps = min_steps
        self.times: list[float] = []
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        hist = self.times[-self.window:]
        slow = False
        if len(hist) >= self.min_steps:
            mu = float(np.mean(hist))
            sd = float(np.std(hist)) + 1e-9
            slow = dt > mu + self.k * sd and dt > 1.5 * mu
            if slow:
                self.flagged.append((step, dt))
        self.times.append(dt)
        return slow


def make_train_step(loss_fn: Callable, adam_cfg: AdamConfig):
    """jit-able (params, opt, batch) → (params, opt, metrics) around any
    ``loss_fn(params, batch) -> (loss, metrics)``."""

    def step(params, opt: AdamState, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt, opt_m = adam_update(adam_cfg, grads, opt, params)
        return params, opt, {"loss": loss, **metrics, **opt_m}

    return step


class _Preempted(Exception):
    pass


def train(params, loss_fn: Callable, data_at: Callable[[int], Any],
          cfg: TrainConfig, adam_cfg: AdamConfig,
          step_transform: Callable | None = None,
          step_factory: Callable | None = None,
          log: Callable[[str], None] = print) -> dict:
    """Run (or resume) training. Returns final state + history.

    ``data_at(step)`` must be a pure function of the step index.
    ``step_transform`` lets the launcher wrap the step in jit/pjit with
    shardings; default is plain ``jax.jit``. ``step_factory`` overrides
    ``make_train_step`` (e.g. to insert gradient compression).
    """
    train_step = (step_factory or make_train_step)(loss_fn, adam_cfg)
    train_step = (step_transform or jax.jit)(train_step)

    opt = adam_init(params)
    start = 0
    mgr = CheckpointManager(cfg.ckpt_dir, cfg.keep) if cfg.ckpt_dir else None
    if mgr and mgr.latest_step() is not None:
        (params, opt), meta = mgr.restore((params, opt))
        start = meta["step"] + 1
        log(f"[train] resumed from step {meta['step']}")

    monitor = StragglerMonitor()
    history = []
    preempted = {"flag": False}

    def _sigterm(signum, frame):
        preempted["flag"] = True

    old = signal.signal(signal.SIGTERM, _sigterm)
    failed_once = {"done": False}
    try:
        for step in range(start, cfg.steps):
            t0 = time.perf_counter()
            batch = data_at(step)
            params, opt, metrics = train_step(params, opt, batch)
            if cfg.fail_at_step is not None and step == cfg.fail_at_step \
                    and not failed_once["done"]:
                failed_once["done"] = True
                raise RuntimeError(f"injected failure at step {step}")
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = monitor.record(step, dt)
            if slow:
                log(f"[train] straggler flagged at step {step}: {dt*1e3:.0f} ms")
            if step % cfg.log_every == 0:
                log(f"[train] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            history.append(loss)
            if mgr and (step % cfg.ckpt_every == 0 or step == cfg.steps - 1
                        or preempted["flag"]):
                mgr.save(step, (params, opt), {"loss": loss})
            if preempted["flag"]:
                log(f"[train] preempted at step {step}; checkpointed and exiting")
                break
    finally:
        signal.signal(signal.SIGTERM, old)
    return {"params": params, "opt": opt, "history": history,
            "stragglers": monitor.flagged, "last_step": step if cfg.steps else -1}

"""Training launcher.

CPU-scale entry point exercising the full production stack — merged adaptive
engine (QAT across profiles), AdamW, deterministic data, checkpoint/restart,
straggler monitoring. On a real TPU fleet the same step function is jitted
with the shardings from ``launch/sharding.py`` over ``make_production_mesh()``
(exactly what ``dryrun.py`` lowers); here the default is the reduced smoke
config so the driver runs end-to-end in CI.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --steps 50 --ckpt-dir /tmp/ckpt [--full] [--profile A8-W8] \
      [--grad-compression]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke
from repro.core.profiles import paper_profiles
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.data.tokens import TokenStream
from repro.models import transformer as T
from repro.optim.adam import AdamConfig
from repro.optim.compression import (compress_tree, decompress_tree,
                                     init_error_feedback)
from repro.train.loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCHS)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU-scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--profile", default=None,
                    help="train a single profile (default: rotate all, joint QAT)")
    ap.add_argument("--grad-compression", action="store_true",
                    help="int8 gradient compression with error feedback")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    if cfg.frontend is not None:
        raise SystemExit("token-LM driver: pick a text arch "
                         "(audio/vlm archs train via tests/benchmarks)")
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    print(f"[train] {cfg.name}: {T.param_count(params)/1e6:.1f}M params")

    names = T.quant_layer_names(cfg)
    lo, hi = cfg.n_layers // 3, 2 * cfg.n_layers // 3
    inner = [n for n in names
             if n.startswith("L") and lo <= int(n[1:].split(".")[0]) < hi]
    profs = paper_profiles(names, inner_layers=inner)
    engine = AdaptiveEngine(tuple(profs), QuantIndex(names),
                            lambda p, br, b: T.train_loss(p, cfg, br, b))
    pid_fixed = engine.profile_id(args.profile) if args.profile else None

    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch,
                         seed=args.seed)
    ef = {"state": init_error_feedback(params) if args.grad_compression else None}

    def loss_fn(params, batch):
        pid = batch["profile_id"]
        return engine(params, pid, {"tokens": batch["tokens"],
                                    "labels": batch["labels"]})

    def data_at(step):
        b = stream.batch_at(step)
        pid = pid_fixed if pid_fixed is not None else step % len(profs)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"]),
                "profile_id": jnp.asarray(pid, jnp.int32)}

    step_factory = None
    if args.grad_compression:
        # compress→decompress grads around the optimizer: the int8 wire format
        # the multi-pod all-reduce uses (EF numerics shown single-host)
        from repro.optim.adam import adam_update

        def step_factory(loss_fn_, acfg_):
            def step(params, opt, ef_state, batch):
                (l, m), g = jax.value_and_grad(loss_fn_, has_aux=True)(params, batch)
                q, s, ef_state = compress_tree(g, ef_state,
                                               jax.random.PRNGKey(0))
                g = decompress_tree(q, s)
                params, opt, om = adam_update(acfg_, g, opt, params)
                return params, opt, ef_state, {"loss": l, **m, **om}

            jitted = jax.jit(step)

            def wrapped(params, opt, batch):  # loop-compatible signature
                params, opt, ef["state"], metrics = jitted(
                    params, opt, ef["state"], batch)
                return params, opt, metrics
            return wrapped
        step_transform = lambda f: f  # already jitted inside
    else:
        step_transform = jax.jit
    out = train(params, loss_fn, data_at,
                TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                            ckpt_every=max(1, args.steps // 4), log_every=5),
                AdamConfig(lr=args.lr, total_steps=args.steps, warmup_steps=10),
                step_transform=step_transform, step_factory=step_factory)
    h = out["history"]
    print(f"[train] done: loss {h[0]:.3f} → {h[-1]:.3f} "
          f"({len(h)} steps, {len(out['stragglers'])} stragglers flagged)")


if __name__ == "__main__":
    main()

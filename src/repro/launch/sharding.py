"""Logical sharding rules: parameter/activation PartitionSpecs per mesh.

MaxText-style rule table keyed on parameter path, with automatic
divisibility fallback (an axis that doesn't divide is dropped rather than
erroring — e.g. ``global_batch=1`` in ``long_500k`` simply doesn't shard over
``data``). Baseline layout:

* **FSDP**: the contraction (d_model) dim of every big matrix shards over the
  data axes (pod+data), so optimizer state for 110B params fits 16 GB/chip;
* **TP**: the output dim (heads / d_ff / vocab / experts) shards over
  ``model`` (Megatron column→row pairs);
* **EP**: the expert dim of stacked MoE weights shards over ``model``;
* SSM packed projections stay replicated over ``model`` (component-packed
  columns don't split cleanly — DESIGN §5; revisited in §Perf hillclimb).
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import dp_axes

__all__ = ["param_specs", "batch_specs", "cache_specs", "named", "spec_tree"]


def _fits(dim: int | None, mesh: Mesh, axes) -> bool:
    if dim is None:
        return False
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return dim % n == 0 and dim >= n


def _spec(mesh: Mesh, shape: tuple[int, ...], assign: dict[int, Any]) -> P:
    """Build a PartitionSpec, dropping axes that don't divide."""
    entries = []
    for i, dim in enumerate(shape):
        ax = assign.get(i)
        if ax is not None and _fits(dim, mesh, ax):
            entries.append(ax)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)


# rules: (regex on path, fn(shape, fsdp, mesh) -> {dim_index: axis})
def _param_assign(path: str, shape: tuple[int, ...], fsdp, mesh: Mesh) -> dict:
    nd = len(shape)
    # native QTensor leaves: <site>/wq/0 = int carrier (same layout as w,
    # packed int4 halves the last dim — divisibility still holds), /1 = scale
    # [.., 1, out] (inherits the weight's last-dim placement)
    m = re.match(r"^(.*?)/wq/\d+$", path)
    if m:
        base = m.group(1)
        assign = _param_assign(base, shape, fsdp, mesh)
        return assign or _param_assign(base + "/w", shape, fsdp, mesh)
    # MoE raw stacked QTensors: layers/moe/w_in/<leaf-idx>
    m = re.match(r"^(.*moe/(?:w_in|w_out))/\d+$", path)
    if m:
        return _param_assign(m.group(1), shape, fsdp, mesh)
    # embeddings: [V, d]
    if re.search(r"(^|/)embed/w$", path):
        return {0: "model", 1: fsdp}
    if re.search(r"(^|/)lm_head/w$", path):
        return {0: fsdp, 1: "model"}
    # MoE stacked experts: [L, E, d, f] — expert parallel + FSDP on d
    if re.search(r"moe/w_in$", path):
        return {1: "model", 2: fsdp}
    if re.search(r"moe/w_out$", path):
        return {1: "model", 3: fsdp}
    if re.search(r"moe/router/w$", path):
        return {1: fsdp}
    # SSM packed projections: replicated over model (see module docstring);
    # FSDP still shards the contraction dim.
    if re.search(r"ssm/(in_proj|out_proj)/w$", path):
        return {nd - 2: fsdp}
    # generic column-parallel producers: [*, d_in, d_out_big]
    if re.search(r"(qkv|w_in|shared_in|mlp/w_in)/?w?$", path) and nd >= 2:
        return {nd - 2: fsdp, nd - 1: "model"}
    # row-parallel consumers: [*, d_big, d_model]
    if re.search(r"(attn_out|w_out|shared_out|mlp/w_out)/?w?$", path) and nd >= 2:
        return {nd - 2: "model", nd - 1: fsdp}
    # biases of column-parallel layers
    if re.search(r"(qkv|w_in|shared_in)/b$", path):
        return {nd - 1: "model"}
    return {}  # norms, scalars, conv, A_log, ... replicated


def param_specs(params_like: Any, mesh: Mesh, *, serve: bool = False):
    """Pytree of PartitionSpecs for a parameter tree (works on SDS trees).

    ``serve=True`` drops the FSDP dimension (pure TP layout): serving holds no
    optimizer state, so weights fit model-sharded only and the per-layer FSDP
    all-gathers disappear from the decode step (§Perf decode iteration 4).
    """
    fsdp = None if serve else dp_axes(mesh)
    if isinstance(fsdp, tuple):
        fsdp = fsdp[0] if len(fsdp) == 1 else fsdp

    def one(path, leaf):
        return _spec(mesh, tuple(leaf.shape),
                     _param_assign(_path_str(path), tuple(leaf.shape), fsdp, mesh))

    return jax.tree_util.tree_map_with_path(one, params_like)


def batch_specs(batch_like: Any, mesh: Mesh):
    """Inputs: batch dim over (pod, data); everything else replicated."""
    dp = dp_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp

    def one(path, leaf):
        return _spec(mesh, tuple(leaf.shape), {0: dp})

    return jax.tree_util.tree_map_with_path(one, batch_like)


def cache_specs(cache_like: Any, mesh: Mesh):
    """Decode caches (stacked [L, B, ...]): batch over dp, heads/state over model."""
    dp = dp_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp

    def one(path, leaf):
        p = _path_str(path)
        shape = tuple(leaf.shape)
        nd = len(shape)
        assign: dict[int, Any] = {}
        if nd >= 2:
            assign[1] = dp                      # batch dim
        if re.search(r"kv/(k|v)$", p) and nd == 5:
            if _fits(shape[3], mesh, "model"):
                assign[3] = "model"             # Hkv heads (no psum needed)
            else:
                assign[2] = "model"             # else shard cache slots
        elif re.search(r"kv/token_idx$", p) and nd == 3:
            assign[2] = "model"                 # matches slot-sharded caches
        elif re.search(r"ssm/h$", p) and nd == 5:
            assign[4] = "model"                 # d_state
        elif re.search(r"(k_scale|v_scale)$", p) and nd == 3:
            assign[2] = "model"
        return _spec(mesh, shape, assign)

    return jax.tree_util.tree_map_with_path(one, cache_like)


def named(mesh: Mesh, spec_tree_):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree_,
                        is_leaf=lambda x: isinstance(x, P))


def spec_tree(kind: str, like: Any, mesh: Mesh):
    fn = {"params": param_specs, "batch": batch_specs, "cache": cache_specs}[kind]
    return fn(like, mesh)

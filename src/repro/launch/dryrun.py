import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()
# ^ MUST run before any jax import: jax locks the device count on first init.

"""Multi-pod dry-run driver (brief §MULTI-POD DRY-RUN).

For every (architecture × input shape × mesh) cell:
``jax.jit(step, in_shardings, out_shardings).lower(...).compile()`` on the
production mesh built from placeholder CPU devices, then record
``memory_analysis()`` / ``cost_analysis()`` and the collective byte totals
parsed from the post-SPMD HLO into a JSON artifact that §Roofline reads.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k --mesh pod1 [--native-bits 8] [--kv-bits 8] \
      [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod1 pod2
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch import sharding as shd
from repro.launch import specs as S
from repro.launch.mesh import dp_size, make_production_mesh, make_tiny_mesh

# bf16 compute in the lowered HLO (TPU target numerics); never executed here.
# Applied inside run_cell/main — NOT at import, so importing this module for
# its parsers (tests) doesn't poison CPU-executing code with bf16 dots.

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _tensor_bytes(shape_str: str) -> int:
    """'bf16[8,128]' → 2048. Tuple shapes handled by summing members."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*"
    r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*))\s+([a-z0-9-]+)\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form [num_groups, group_size]<=[...]
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Sum *operand* bytes of every collective in the post-SPMD HLO.

    Operand shapes are not always printed, so bytes derive from the (always
    printed) result shape and the collective's semantics:
    all-gather operand = result / group_size; reduce-scatter operand =
    result × group_size; all-reduce / all-to-all / collective-permute
    operand = result. Async ``*-done`` halves are skipped (their ``*-start``
    twin carries the shape).
    """
    per_kind = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        kind = next((k for k in _COLLECTIVES if op.startswith(k)), None)
        if kind is None:
            continue
        nbytes = _tensor_bytes(shape_str)
        if kind == "all-gather":
            nbytes //= max(1, _group_size(line))
        elif kind == "reduce-scatter":
            nbytes *= _group_size(line)
        per_kind[kind] += nbytes
        count[kind] += 1
    total = sum(per_kind.values())
    return {"total": total, "per_kind": per_kind, "count": count}


def _mesh_for(name: str):
    if name == "pod1":
        return make_production_mesh(multi_pod=False)
    if name == "pod2":
        return make_production_mesh(multi_pod=True)
    if name == "tiny":
        return make_tiny_mesh(2, 2)
    if name == "tiny2":
        return make_tiny_mesh(2, 2, multi_pod=True)
    raise ValueError(name)


def _lower_step(cfg, shape, mesh, *, native_bits, kv_bits, serve_layout=False):
    """Lower the cell's step function with explicit in/out shardings."""
    engine = S.build_engine(cfg)
    pid_sh = shd.named(mesh, jax.sharding.PartitionSpec())
    pid = jax.ShapeDtypeStruct((), jnp.int32)
    if shape.kind == "train":
        params = S.abstract_params(cfg)
        opt = S.abstract_opt(params)
        batch = S.input_specs(cfg, shape)
        p_sh = shd.named(mesh, shd.param_specs(params, mesh))
        opt_sh = type(opt)(step=pid_sh,
                           mu=shd.named(mesh, shd.param_specs(opt.mu, mesh)),
                           nu=shd.named(mesh, shd.param_specs(opt.nu, mesh)))
        b_sh = shd.named(mesh, shd.batch_specs(batch, mesh))
        fn = S.make_train_step_fn(cfg, engine)
        jitted = jax.jit(fn, in_shardings=(p_sh, opt_sh, pid_sh, b_sh),
                         out_shardings=(p_sh, opt_sh, None),
                         donate_argnums=(0, 1))
        return jitted.lower(params, opt, pid, batch)
    if shape.kind == "prefill":
        params = S.abstract_params(cfg, native_bits=native_bits)
        batch = S.input_specs(cfg, shape)
        p_sh = shd.named(mesh, shd.param_specs(params, mesh, serve=serve_layout))
        b_sh = shd.named(mesh, shd.batch_specs(batch, mesh))
        fn = S.make_prefill_fn(cfg, engine)
        jitted = jax.jit(fn, in_shardings=(p_sh, pid_sh, b_sh))
        return jitted.lower(params, pid, batch)
    # decode
    params = S.abstract_params(cfg, native_bits=native_bits)
    caches = S.abstract_caches(cfg, shape, kv_bits=kv_bits)
    io = S.input_specs(cfg, shape)
    p_sh = shd.named(mesh, shd.param_specs(params, mesh, serve=serve_layout))
    c_sh = shd.named(mesh, shd.cache_specs(caches, mesh))
    i_sh = shd.named(mesh, shd.batch_specs(io, mesh))
    fn = S.make_decode_fn(cfg, engine)
    jitted = jax.jit(fn, in_shardings=(p_sh, pid_sh, i_sh["tokens"],
                                       i_sh["pos"], c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(4,))
    return jitted.lower(params, pid, io["tokens"], io["pos"], caches)


def _measure(compiled) -> dict:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # jax <= 0.4.x returns a one-element list of dicts; newer returns the dict
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    def _get(o, *names):
        for n in names:
            v = getattr(o, n, None) if not isinstance(o, dict) else o.get(n)
            if v is not None:
                return v
        return None

    return dict(
        flops=float(cost.get("flops", 0.0)) if isinstance(cost, dict) else None,
        bytes_accessed=float(cost.get("bytes accessed", 0.0))
        if isinstance(cost, dict) else None,
        memory=dict(
            argument_bytes=_get(mem, "argument_size_in_bytes"),
            output_bytes=_get(mem, "output_size_in_bytes"),
            temp_bytes=_get(mem, "temp_size_in_bytes"),
        ),
        collectives=coll,
        hlo_lines=hlo.count("\n"),
    )


def _extrapolate(a1: dict, a2: dict, n_layers: int) -> dict:
    """Exact depth extrapolation from unrolled L=1 / L=2 measurements:
    per_layer = m(2) − m(1); total(L) = m(1) + (L−1)·per_layer.

    cost_analysis counts while-loop bodies once (verified in
    EXPERIMENTS §Dry-run-method), so the production scanned lowering
    under-reports; the unrolled variants have loop-free depth, making the
    linear-in-L fit exact for flops / bytes / collective bytes.
    """
    out = {}
    for key in ("flops", "bytes_accessed"):
        m1, m2 = a1[key], a2[key]
        per = max(0.0, m2 - m1)
        out[key] = m1 + (n_layers - 1) * per
        out[key + "_per_layer"] = per
    c1, c2 = a1["collectives"], a2["collectives"]
    per_kind = {}
    for k in c1["per_kind"]:
        per = max(0, c2["per_kind"][k] - c1["per_kind"][k])
        per_kind[k] = c1["per_kind"][k] + (n_layers - 1) * per
    out["collective_bytes"] = {"total": sum(per_kind.values()),
                               "per_kind": per_kind}
    return out


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             native_bits: int | None, kv_bits: int,
             remat: bool | None = None, analysis: bool = True,
             constraints: bool = False, swa_skip: bool = True,
             remat_policy: str = "nothing", serve_layout: bool = False,
             config_edit=None, verbose: bool = True) -> dict:
    from repro.launch.mesh import dp_axes
    from repro.models import pshard

    runtime.set_compute_dtype(jnp.bfloat16)  # TPU-target numerics in the HLO
    shape = SHAPES[shape_name]
    cfg0 = get_config(arch)
    ok, why = shape_applicable(cfg0, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "native_bits": native_bits, "kv_bits": kv_bits,
           "opts": {"constraints": constraints, "swa_skip": swa_skip,
                    "remat_policy": remat_policy, "serve_layout": serve_layout}}
    if not ok:
        rec.update(status="skipped", reason=why)
        if verbose:
            print(f"[dryrun] {arch} × {shape_name}: SKIP ({why})")
        return rec

    mesh = _mesh_for(mesh_name)
    cfg = S.adapt_config(cfg0, shape, dp_size(mesh))
    cfg = dataclasses.replace(cfg, swa_block_skip=swa_skip,
                              remat_policy=remat_policy)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if config_edit is not None:
        cfg = config_edit(cfg)

    if constraints:
        dp = dp_axes(mesh)
        pshard.enable(mesh, dp[0] if len(dp) == 1 else dp)
    else:
        pshard.disable()

    with mesh:
        # --- production lowering: full depth, scan-over-layers ---
        t0 = time.time()
        lowered = _lower_step(cfg, shape, mesh, native_bits=native_bits,
                              kv_bits=kv_bits, serve_layout=serve_layout)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        prod = _measure(compiled)
        del compiled, lowered

        # --- analysis lowerings: depth-unrolled L=1 / L=2 → exact totals ---
        if analysis:
            meas = []
            for L in (1, 2):
                cfg_l = dataclasses.replace(cfg, n_layers=L, scan_layers=False,
                                            unroll_inner=True)
                c = _lower_step(cfg_l, shape, mesh, native_bits=native_bits,
                                kv_bits=kv_bits,
                                serve_layout=serve_layout).compile()
                meas.append(_measure(c))
                del c
            rec["analysis"] = _extrapolate(meas[0], meas[1], cfg.n_layers)
    pshard.disable()

    rec.update(
        status="ok",
        devices=int(np.prod(list(mesh.shape.values()))),
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        production=prod,
    )
    if verbose:
        a = rec.get("analysis", {})
        flops = a.get("flops", prod["flops"]) or 0.0
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s, "
              f"flops/dev {flops:.3e}, "
              f"coll/dev {a.get('collective_bytes', prod['collectives'])['total']/2**30:.2f} GiB)")
        print("  memory_analysis:", prod["memory"])
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", nargs="+", default=["pod1"],
                    choices=["pod1", "pod2", "tiny", "tiny2"])
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch × shape) cell")
    ap.add_argument("--native-bits", type=int, default=None,
                    help="serve paths: native int weight storage (8 or 4)")
    ap.add_argument("--kv-bits", type=int, default=16, choices=[4, 8, 16])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-analysis", action="store_true",
                    help="skip the L=1/L=2 unrolled roofline lowerings")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--constraints", action="store_true",
                    help="enable activation-sharding constraints (§Perf)")
    ap.add_argument("--no-swa-skip", action="store_true",
                    help="baseline masked attention for SWA archs")
    ap.add_argument("--remat-policy", default="nothing",
                    choices=["nothing", "dots"])
    ap.add_argument("--serve-layout", action="store_true",
                    help="pure-TP weight layout for serving (no FSDP gathers)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for mesh_name in args.mesh:
            tag = f"{arch}__{shape}__{mesh_name}"
            if args.native_bits:
                tag += f"__w{args.native_bits}"
            if args.kv_bits != 16:
                tag += f"__kv{args.kv_bits}"
            if args.tag:
                tag += f"__{args.tag}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        print(f"[dryrun] {tag}: cached, skip")
                        continue
            try:
                rec = run_cell(arch, shape, mesh_name,
                               native_bits=args.native_bits,
                               kv_bits=args.kv_bits,
                               remat=False if args.no_remat else None,
                               constraints=args.constraints,
                               swa_skip=not args.no_swa_skip,
                               remat_policy=args.remat_policy,
                               serve_layout=args.serve_layout,
                               analysis=(mesh_name in ("pod1", "tiny")
                                         and not args.no_analysis))
            except Exception as e:  # a failing cell is a bug — surface it
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "error", "error": f"{type(e).__name__}: {e}"}
                failures.append(tag)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
    if failures:
        raise SystemExit(f"dry-run FAILURES: {failures}")
    print("[dryrun] all requested cells OK")


if __name__ == "__main__":
    main()

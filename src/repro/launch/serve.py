"""Serving launcher — the paper's deployment scenario: an adaptive inference
engine behind a Profile Manager with an energy budget.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --requests 6 --budget-inferences 200 [--kv-bits 8] [--full]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke
from repro.core.energy import TPU_V5E, activity_factor, step_energy
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.serving.engine import AdaptiveServer, Request, ServingConfig


def profile_stats(cfg, profs, n_params: int) -> list[ProfileStats]:
    """Modeled per-inference energy per profile (roofline §energy model);
    accuracies are the paper's Table-1 shape (calibration hook in prod)."""
    acc_by_w = {8: 0.989, 4: 0.953, 32: 0.998}
    out = []
    t_est = 2.0 * n_params / TPU_V5E.peak_flops  # one fwd, compute term
    for p in profs:
        a, w = next(iter(p.bits.values()))
        act = activity_factor(min(a, 16), min(w, 16), min(w, 16) / 16.0)
        name_acc = acc_by_w.get(w, 0.97) - (0.004 if p.name == "Mixed" else 0)
        out.append(ProfileStats(p.name, name_acc,
                                step_energy(t_est, act), t_est))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCHS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kv-bits", type=int, default=16, choices=[4, 8, 16],
                    help="KV-cache precision: 16 = bf16, 8 = int8, 4 = "
                         "packed int4 (two tokens' nibbles per byte — half "
                         "the pool bytes of kv8, 2x the token capacity at "
                         "equal block count)")
    ap.add_argument("--precision-policy", default=None, metavar="PATH",
                    help="per-layer KV bit-width policy JSON (written by "
                         "benchmarks/precision_frontier.py): profile 0 — "
                         "the accuracy-critical binding — pins the all-"
                         "high row, every other profile rides the searched "
                         "frontier schedule. The [n_profiles, n_layers] "
                         "table is data to the jitted decode (no retrace "
                         "on profile switches)")
    ap.add_argument("--budget-inferences", type=float, default=200,
                    help="energy budget in units of full-power inferences")
    ap.add_argument("--continuous", action="store_true",
                    help="serve through the continuous-batching slot pool "
                         "(ContinuousScheduler) instead of static groups")
    ap.add_argument("--quantum", type=int, default=8,
                    help="decode steps per continuous-batching segment")
    ap.add_argument("--paged-kv", dest="paged_kv", action="store_true",
                    default=True,
                    help="paged KV pool for the continuous scheduler: "
                         "global block pool + per-row block tables "
                         "(default)")
    ap.add_argument("--no-paged-kv", dest="paged_kv", action="store_false",
                    help="contiguous [max_batch, slots] KV rows instead of "
                         "the paged pool")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block of the paged pool "
                         "(default: 16)")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="physical KV blocks to provision; default sizes "
                         "the pool at the contiguous footprint — set lower "
                         "to oversubscribe (admission backpressure kicks "
                         "in when it runs dry)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false", default=True,
                    help="disable shared-prefix reuse (block-hash registry "
                         "+ suffix-only admission prefill)")
    ap.add_argument("--paged-backend", default="auto",
                    choices=["auto", "pallas", "gather"],
                    help="paged decode backend: 'pallas' attends in place "
                         "against the block pool through the paged-"
                         "attention kernel (no dense view, no fold-back); "
                         "'gather' materializes the per-segment view (the "
                         "oracle path); 'auto' = pallas on TPU, gather "
                         "elsewhere (default)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: admission prompts longer than "
                         "this many tokens prefill in block-aligned chunks "
                         "interleaved with decode segments (full-causal "
                         "stacks; default: disabled)")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="request priority classes for the continuous "
                         "scheduler: 1 = classless FIFO (default); >=2 "
                         "builds the critical/.../saver ladder — class 0 "
                         "admits first and is profile-bound to the "
                         "accuracy target (every 3rd demo request rides "
                         "class 0, the rest the lowest class)")
    ap.add_argument("--preemption", action="store_true",
                    help="arm preemptive scheduling: a critical arrival "
                         "that cannot admit evicts saver-class rows (block "
                         "tables + host KV masters snapshotted; they "
                         "resume bit-exactly through the continuation-"
                         "prefill executable). Requires --continuous, the "
                         "paged pool, and a full-causal stack")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request completion deadline in wall-clock ms "
                         "from submission; queued requests past (or "
                         "provably unable to meet) their deadline finalize "
                         "EXPIRED, live rows are reaped at the next flush "
                         "boundary. Requires --continuous")
    ap.add_argument("--shed", type=int, default=None, metavar="DEPTH",
                    help="graceful overload degradation: when the queue "
                         "exceeds DEPTH (or the predicted deadline-miss "
                         "count exceeds it), the lowest-priority tail "
                         "request finalizes SHED instead of queuing. "
                         "Requires --continuous")
    ap.add_argument("--inject-faults", action="store_true",
                    help="arm the seeded chaos schedule: random NaN-logit "
                         "injections into live decode rows (detected by "
                         "the in-segment finite check; the row is "
                         "quarantined and retried at a higher-accuracy "
                         "profile), plus one allocator-drought admission "
                         "round. Requires --continuous")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the deterministic fault schedule "
                         "(default: 0; only with --inject-faults)")
    ap.add_argument("--retry-budget", type=int, default=2,
                    help="max quarantine retries per request before it "
                         "finalizes FAILED (default: 2)")
    ap.add_argument("--speculate", action="store_true",
                    help="speculative decoding: each decode step drafts "
                         "--draft-k tokens (self-speculative n-gram "
                         "lookup) and verifies the whole window in one "
                         "batched pass — token-identical to greedy, "
                         "faster on predictable streams. Requires "
                         "--continuous")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="drafted tokens per speculative window "
                         "(window = draft-k + 1 positions; default: 4)")
    ap.add_argument("--draft-model", default=None,
                    help="external drafter from the registry instead of "
                         "the self-speculative n-gram lookup (e.g. "
                         "'repeat'; default: self-speculative)")
    ap.add_argument("--journal-dir", default=None, metavar="DIR",
                    help="crash-consistent serving: write-ahead request "
                         "journal + live-state checkpoints under DIR. On "
                         "boot, a non-empty DIR is recovered first — the "
                         "newest committed checkpoint restores live/queued "
                         "state, the journal suffix replays, and every "
                         "accepted request resumes token-identically "
                         "(docs/serving.md §Durability). Requires "
                         "--continuous")
    ap.add_argument("--checkpoint-every", type=int, default=8,
                    metavar="ROUNDS",
                    help="live-state checkpoint cadence in scheduler "
                         "rounds (default: 8; 0 = journal only — nothing "
                         "is lost either way, a checkpoint just bounds "
                         "recovery recompute). Only with --journal-dir")
    ap.add_argument("--drain-on-sigterm", action="store_true",
                    help="graceful shutdown: SIGTERM stops admission, "
                         "runs every admitted row to a terminal status, "
                         "writes a final checkpoint (with --journal-dir) "
                         "and exits; queued requests stay journaled for "
                         "the next process. Requires --continuous")
    ap.add_argument("--kv16-masters", action="store_true",
                    help="keep f32 KV masters for shared/chunked rows even "
                         "at --kv-bits 16 (structurally bit-exact "
                         "continuations + exact kv16 checkpoints; costs "
                         "host memory)")
    ap.add_argument("--aging", type=int, default=None, metavar="ROUNDS",
                    help="anti-starvation promotion: a queued request "
                         "that has waited ROUNDS scheduler rounds at the "
                         "head of its class climbs one priority level "
                         "(position only — profile binding and billing "
                         "keep the submitted class). Default: off = "
                         "strict lowest-level-first")
    ap.add_argument("--paranoid", action="store_true",
                    help="run the full block-pool invariant audit "
                         "(refcounts vs free/LRU/live partition, "
                         "BlockAllocator.check) after every scheduler "
                         "step. Requires --continuous")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    if not cfg.causal:
        raise SystemExit("encoder-only arch has no decode step")
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    engine = AdaptiveEngine(tuple(profs), QuantIndex(names),
                            lambda p, br, b: T.train_loss(p, cfg, br, b))
    stats = profile_stats(cfg, profs, T.param_count(params))
    mgr = ProfileManager(stats, accuracy_target=0.985, accuracy_floor=0.95,
                         budget_j=stats[0].energy_j * args.budget_inferences,
                         low_energy=0.5)
    if args.preemption and not args.continuous:
        raise SystemExit("--preemption needs --continuous (the slot pool)")
    if not args.continuous and (args.deadline_ms is not None
                                or args.shed is not None
                                or args.inject_faults or args.paranoid):
        raise SystemExit("--deadline-ms/--shed/--inject-faults/--paranoid "
                         "need --continuous (the fault-tolerant scheduler)")
    if args.speculate and not args.continuous:
        raise SystemExit("--speculate needs --continuous (draft/verify "
                         "windows run through the slot-pool segment)")
    if (args.journal_dir or args.drain_on_sigterm) and not args.continuous:
        raise SystemExit("--journal-dir/--drain-on-sigterm need --continuous "
                         "(durability hooks live on the slot-pool scheduler)")
    policy = None
    if args.precision_policy:
        import json
        with open(args.precision_policy) as f:
            pp = json.load(f)
        row = tuple(int(b) for b in pp["schedule"])
        if len(row) != cfg.n_layers:
            raise SystemExit(f"--precision-policy schedule has {len(row)} "
                             f"layers, model has {cfg.n_layers}")
        # profile 0 is the accuracy-critical binding: pin it to the exact
        # all-high row; the rest ride the searched frontier schedule
        policy = tuple((16,) * cfg.n_layers if i == 0 else row
                       for i in range(len(profs)))
    stop = {"drain": False}
    if args.drain_on_sigterm:
        # install before the (slow) model/executable build: a TERM during
        # warmup drains at the first step boundary instead of killing us
        import signal
        signal.signal(signal.SIGTERM, lambda *_: stop.update(drain=True))
    srv = AdaptiveServer(cfg, params, engine,
                         ServingConfig(slots=256, kv_bits=args.kv_bits,
                                       max_batch=4, paged_kv=args.paged_kv,
                                       block_size=args.block_size,
                                       pool_blocks=args.pool_blocks,
                                       prefix_cache=args.prefix_cache,
                                       paged_backend=args.paged_backend,
                                       prefill_chunk=args.prefill_chunk,
                                       priority_classes=args.priority_classes,
                                       preemption=args.preemption,
                                       aging=args.aging,
                                       speculate=args.speculate,
                                       draft_k=args.draft_k,
                                       draft_model=args.draft_model,
                                       kv16_masters=args.kv16_masters,
                                       precision_policy=policy),
                         manager=mgr)
    rng = np.random.default_rng(args.seed)
    n_cls = max(1, args.priority_classes)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, int(n)).astype(np.int32),
                    max_new=args.max_new,
                    accuracy_critical=(i % 3 == 0),
                    priority=(0 if i % 3 == 0 else n_cls - 1),
                    deadline_ms=args.deadline_ms)
            for i, n in enumerate(rng.integers(4, 24, args.requests))]
    import time
    t0 = time.perf_counter()
    sched = None
    if args.continuous:
        from repro.serving.faults import FaultSchedule
        from repro.serving.policy import ShedPolicy
        from repro.serving.scheduler import ContinuousScheduler
        faults = None
        if args.inject_faults:
            # one guaranteed recoverable fault (request 1, first attempt)
            # plus random NaNs at ~1 per 4 requests (capped) and one
            # drought round — every injection detected, quarantined, and
            # retried at a higher-accuracy profile under --retry-budget
            faults = FaultSchedule(args.fault_seed, p_nan=0.25,
                                   max_nan=max(1, args.requests // 4),
                                   nan_at={min(1, args.requests - 1): (0,)},
                                   alloc_at=(2,))
        sched_kwargs = dict(
            quantum=args.quantum,
            shed=(ShedPolicy(max_queue=args.shed)
                  if args.shed is not None else None),
            faults=faults, retry_budget=args.retry_budget,
            paranoid=args.paranoid)
        if args.journal_dir:
            from repro.serving.durability import recover
            sched = recover(srv, args.journal_dir,
                            checkpoint_every=args.checkpoint_every,
                            **sched_kwargs)
            ri = sched.recover_info
            if ri["resumed_rows"] or ri["chunk_rows"] or ri["replayed"]:
                print(f"[serve] recovered from {args.journal_dir}: "
                      f"{ri['resumed_rows']} live rows resumable, "
                      f"{ri['chunk_rows']} mid-prompt chunk rows rebuilt, "
                      f"{ri['replayed']} journal records replayed, "
                      f"{len(ri['refilled'])} re-prefilled after corruption "
                      f"({ri['recovery_s']*1e3:.0f} ms)")
        else:
            sched = ContinuousScheduler(srv, **sched_kwargs)
        for r in reqs:
            sched.submit(r)
        drained = False
        while sched.step():
            if stop["drain"]:
                # graceful shutdown: finish every admitted row, leave the
                # queue journaled for the next process, cut one final
                # checkpoint, exit 0
                sched.drain()
                if sched.durable is not None:
                    sched.durable.checkpoint()
                drained = True
                break
        results = [sched.results.get(i) for i in range(sched._n)]
        if drained:
            print(f"[serve] SIGTERM drain: {sched.pending} request(s) left "
                  f"queued (journaled) after finishing all admitted rows")
    else:
        results = srv.serve(reqs)
    wall = time.perf_counter() - t0
    if sched is not None and sched.paged:
        st = sched.paged_stats()
        print(f"[serve] paged KV: peak {st['peak_used_blocks']}/"
              f"{st['pool_blocks']} blocks of {st['block_size']} tokens, "
              f"prefix hits {st.get('registry_hits', 0)}, "
              f"lru cached {st['lru_cached_blocks']}, "
              f"preemptions {st['preemptions']} "
              f"(resumed {st['resumes']})")
    n_tok = sum(len(r["tokens"]) for r in results if r)
    for i, r in enumerate(results):
        if r is None:                # still queued after a SIGTERM drain
            print(f"[serve] req{i}: queued (journaled for next process)")
            continue
        status = r.get("status")
        extra = "" if status is None else f" [{status.value}" + (
            f": {r['reason']}]" if r.get("reason") else "]")
        retries = r.get("retries", 0)
        if retries:
            extra += f" (recovered after {retries} escalated "\
                     f"retr{'y' if retries == 1 else 'ies'})"
        print(f"[serve] req{i}: {len(r['tokens'])} tokens, "
              f"profiles used: {sorted(set(r['profile_trace']))}{extra}")
    if sched is not None and (args.inject_faults or args.shed is not None
                              or args.deadline_ms is not None
                              or args.paranoid):
        rs = sched.robustness_stats()
        print(f"[serve] robustness: cancelled={rs['cancelled']} "
              f"expired={rs['expired']} shed={rs['shed']} "
              f"failed={rs['failed']} recovered={rs['recovered']} "
              f"faults_detected={rs['faults_detected']}")
        sched.check()    # full pool audit (raises on any leak)
        print("[serve] block-pool audit clean: refcounts, free list, and "
              "LRU partition the pool exactly")
    print(f"[serve] {n_tok} tokens in {wall:.2f}s "
          f"({n_tok / wall:.0f} tok/s incl. compile; fused decode loop)")
    print(f"[serve] energy spent: {mgr.spent_j:.2e} J "
          f"({100*(1-mgr.remaining_fraction()):.0f}% of budget), "
          f"saver_mode={mgr._saver}")


if __name__ == "__main__":
    main()

"""Abstract input/parameter specs for the dry-run (ShapeDtypeStruct only).

Everything here is allocation-free: parameter trees come from
``jax.eval_shape`` over the real initializers, batches are SDS stand-ins with
the exact shapes/dtypes of the data pipeline, and the step functions are the
*same* functions the real launcher jits (no dry-run-only forks).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.common import Shape
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.models.native import to_native
from repro.optim.adam import AdamConfig, adam_init, adam_update

__all__ = ["build_engine", "adapt_config", "input_specs", "abstract_params",
           "abstract_opt", "abstract_caches", "make_train_step_fn",
           "make_prefill_fn", "make_decode_fn", "KV_SLOTS"]

KV_SLOTS = {"decode_32k": 32_768, "long_500k": 524_288, "prefill_32k": 32_768}


def adapt_config(cfg: T.ModelConfig, shape: Shape, dp: int) -> T.ModelConfig:
    """Shape-dependent static knobs: align MoE dispatch groups with the DP
    degree, bound the loss chunk by the sequence."""
    upd: dict[str, Any] = {}
    if cfg.moe is not None:
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        g = dp if tokens % dp == 0 else math.gcd(cfg.moe.groups, tokens)
        upd["moe"] = dataclasses.replace(cfg.moe, groups=max(1, g))
    if shape.seq_len < cfg.loss_chunk:
        upd["loss_chunk"] = shape.seq_len
    return dataclasses.replace(cfg, **upd) if upd else cfg


def build_engine(cfg: T.ModelConfig) -> AdaptiveEngine:
    """Merged adaptive engine over the paper's profile family for this arch.

    ``Mixed`` drops the middle third of the layers to A4-W4 — the LM analogue
    of the paper's 'inner convolutional layer at A4-W4' (§4.3)."""
    names = T.quant_layer_names(cfg)
    lo, hi = cfg.n_layers // 3, 2 * cfg.n_layers // 3
    inner = [n for n in names
             if n.startswith("L") and lo <= int(n[1:].split(".")[0]) < hi]
    profs = paper_profiles(names, inner_layers=inner)
    idx = QuantIndex(names)
    return AdaptiveEngine(tuple(profs), idx,
                          lambda p, br, b: T.train_loss(p, cfg, br, b))


def input_specs(cfg: T.ModelConfig, shape: Shape) -> dict:
    """SDS stand-ins for the step inputs of this (arch × shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            batch = {"features": jax.ShapeDtypeStruct((b, s, cfg.feature_dim),
                                                      jnp.float32),
                     "labels": jax.ShapeDtypeStruct((b, s), i32)}
        elif cfg.frontend == "vision":
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                     "patch_embeds": jax.ShapeDtypeStruct(
                         (b, cfg.n_patches, cfg.d_model), jnp.float32),
                     "labels": jax.ShapeDtypeStruct((b, s), i32)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                     "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: one new token against a seq_len-deep cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32)}


def abstract_params(cfg: T.ModelConfig, *, native_bits: int | None = None):
    def build():
        p = T.init_params(cfg, jax.random.PRNGKey(0))
        if native_bits is not None:
            p = to_native(p, native_bits)
        return p
    return jax.eval_shape(build)


def abstract_opt(params_sds):
    return jax.eval_shape(adam_init, params_sds)


def abstract_caches(cfg: T.ModelConfig, shape: Shape, *, kv_bits: int = 16):
    slots = KV_SLOTS.get(shape.name, shape.seq_len)
    return jax.eval_shape(
        lambda: T.init_caches(cfg, shape.global_batch, slots, kv_bits=kv_bits))


# ---------------------------------------------------------------------------
# step functions (shared by dry-run and real launchers)
# ---------------------------------------------------------------------------

def make_train_step_fn(cfg: T.ModelConfig, engine: AdaptiveEngine,
                       adam_cfg: AdamConfig = AdamConfig()) -> Callable:
    """(params, opt, profile_id, batch) → (params, opt, metrics)."""

    def step(params, opt, profile_id, batch):
        def loss_fn(p):
            return engine(p, profile_id, batch)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, opt_m = adam_update(adam_cfg, grads, opt, params)
        return params, opt, {"loss": loss, **metrics, **opt_m}

    return step


def make_prefill_fn(cfg: T.ModelConfig, engine: AdaptiveEngine) -> Callable:
    table = engine.table

    def step(params, profile_id, batch):
        bits = jnp.asarray(table)[profile_id]
        hidden, _, _ = T.forward(params, cfg, bits, batch)
        return T._logits(cfg, params, bits, hidden[:, -1:])[:, 0]

    return step


def make_decode_fn(cfg: T.ModelConfig, engine: AdaptiveEngine) -> Callable:
    table = engine.table

    def step(params, profile_id, tokens, pos, caches):
        bits = jnp.asarray(table)[profile_id]
        return T.decode_step(params, cfg, bits, tokens, pos, caches)

    return step

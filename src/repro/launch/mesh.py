"""Production mesh definitions (brief §MULTI-POD DRY-RUN).

``make_production_mesh`` is a *function* so importing this module never touches
jax device state. Axes:

* single pod: ``(data=16, model=16)`` — 256 chips (one v5e pod).
* multi-pod:  ``(pod=2, data=16, model=16)`` — 512 chips; the ``pod`` axis is
  data-parallel by default (gradient all-reduce crosses the DCN/ICI boundary;
  gradient compression in ``optim/compression.py`` targets exactly that hop),
  or pipeline-parallel when the launcher enables streaming PP (DESIGN §5).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_tiny_mesh", "dp_axes", "dp_size"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_tiny_mesh(n_data: int = 2, n_model: int = 2, *, multi_pod: bool = False):
    """Scaled-down mesh for in-repo distribution tests (subprocess, 8 devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """Mesh axes used for data parallelism (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n

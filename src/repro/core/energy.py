"""Roofline-derived latency/energy model (TPU v5e target constants).

The paper reports measured mW on a KRIA FPGA; this container has no TPU, so the
Profile Manager and the Fig.3/Fig.4 reproductions run on a documented *model*
(DESIGN §2, §9):

  T_est  = max(compute_term, memory_term, collective_term)          [s]
  E_step = T_est * (P_static + P_dyn_peak * activity(profile))      [J]

``activity`` scales the dynamic power with datapath bit-activity, the standard
first-order switching model (energy/MAC ∝ a_bits × w_bits) that underlies the
paper's measured power drop at reduced precision; memory activity scales with
bytes moved (weight-only quant reduces it). All constants are module-level and
overridable so the model is auditable.
"""
from __future__ import annotations

import dataclasses

__all__ = ["HWSpec", "TPU_V5E", "roofline_terms", "step_energy", "activity_factor"]


@dataclasses.dataclass(frozen=True)
class HWSpec:
    """Per-chip hardware constants used by roofline + energy model."""

    name: str
    peak_flops: float          # bf16 FLOP/s
    hbm_bw: float              # B/s
    ici_bw: float              # B/s per link
    p_static: float            # W, idle/leakage+infra share
    p_dyn_peak: float          # W, dynamic at full-precision full utilization
    vmem_bytes: int = 128 * 2**20  # v5e VMEM (128 MiB)
    hbm_bytes: int = 16 * 2**30


# Brief-specified constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_V5E = HWSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    p_static=70.0,
    p_dyn_peak=130.0,
)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int, hw: HWSpec = TPU_V5E) -> dict:
    """The three roofline terms in seconds (brief §ROOFLINE formulas).

    ``flops``/``hbm_bytes``/``coll_bytes`` are *global* (whole-step, all chips).
    """
    c = max(1, chips)
    t_comp = flops / (c * hw.peak_flops)
    t_mem = hbm_bytes / (c * hw.hbm_bw)
    t_coll = coll_bytes / (c * hw.ici_bw)
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["t_step_s"] = max(t_comp, t_mem, t_coll)
    return terms


def activity_factor(mean_a_bits: float, mean_w_bits: float,
                    mem_bytes_ratio: float = 1.0,
                    compute_share: float = 0.6) -> float:
    """Relative dynamic-power activity of a profile vs full bf16 execution.

    ``compute_share`` splits dynamic power between datapath switching (scales
    with a_bits×w_bits, the multiplier-activity model) and data movement
    (scales with bytes moved, i.e. weight-quantization ratio).
    """
    mac = (min(mean_a_bits, 16.0) * min(mean_w_bits, 16.0)) / (16.0 * 16.0)
    return compute_share * mac + (1.0 - compute_share) * mem_bytes_ratio


def step_energy(t_step_s: float, act: float, chips: int = 1, hw: HWSpec = TPU_V5E) -> float:
    """Energy of one step in joules under the activity model."""
    return t_step_s * chips * (hw.p_static + hw.p_dyn_peak * act)

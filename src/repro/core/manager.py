"""Profile Manager — the paper's runtime self-adaptive controller (§4.4, Fig. 4).

Monitors the remaining energy budget and the application accuracy constraint,
and selects the execution profile for the next inference(s). Mirrors the
CERBERO-style monitor→decide→act loop the paper references: the *engine*
executes whatever ``profile_id`` the manager hands it (one scalar, no
recompilation), the *manager* owns the policy.

Also provides :func:`battery_simulation`, the Fig. 4 right-hand-side experiment
(10 Ah budget → battery lifetime / number of classifications, adaptive vs
non-adaptive).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

__all__ = ["ProfileStats", "ProfileManager", "battery_simulation"]


@dataclasses.dataclass(frozen=True)
class ProfileStats:
    """Calibrated characteristics of one profile (from QAT eval + energy model)."""

    name: str
    accuracy: float          # validation accuracy in [0,1]
    energy_j: float          # modeled J / inference (core/energy.py)
    latency_s: float         # modeled s / inference


@dataclasses.dataclass
class ProfileManager:
    """Energy-aware profile selection with hysteresis.

    Policy (paper §4.4): run the cheapest profile that satisfies the accuracy
    requirement; when the remaining energy fraction drops below ``low_energy``,
    relax the requirement to ``accuracy_floor`` (the "battery saver" regime)
    unless the caller flags the request accuracy-critical. Hysteresis keeps the
    selection from oscillating around the threshold.
    """

    profiles: Sequence[ProfileStats]
    accuracy_target: float
    accuracy_floor: float
    budget_j: float
    low_energy: float = 0.2
    hysteresis: float = 0.05

    spent_j: float = 0.0
    _saver: bool = False

    def remaining_fraction(self) -> float:
        """Remaining energy budget in ``[0, 1]``.

        Zero budget = *unconstrained* (an unconfigured manager must not be
        silently pinned into battery-saver mode by a 0/0 → "empty" reading).
        """
        if not self.budget_j:
            return 1.0
        return max(0.0, 1.0 - self.spent_j / self.budget_j)

    def _eligible(self, floor: float) -> list[tuple[int, ProfileStats]]:
        ok = [(i, p) for i, p in enumerate(self.profiles) if p.accuracy >= floor]
        # If nothing meets the floor, degrade gracefully to the most accurate.
        return ok or [max(enumerate(self.profiles), key=lambda ip: ip[1].accuracy)]

    def select(self, accuracy_critical: bool = False) -> int:
        """Return the profile index to run next (the engine's ``profile_id``).

        Deterministic given the ledger (``spent_j``) and the hysteresis
        state — the property every schedule planner below relies on.
        ``accuracy_critical`` holds the selection at ``accuracy_target``
        even in the battery-saver regime. Does NOT account: callers pair
        each ``select`` with an :meth:`account` of the inferences actually
        dispatched.
        """
        rem = self.remaining_fraction()
        if self._saver and rem > self.low_energy + self.hysteresis:
            self._saver = False
        elif not self._saver and rem < self.low_energy:
            self._saver = True
        floor = self.accuracy_target if (accuracy_critical or not self._saver) \
            else self.accuracy_floor
        cand = self._eligible(floor)
        idx, _ = min(cand, key=lambda ip: ip[1].energy_j)
        return idx

    def account(self, profile_idx: int, n_inferences: int = 1) -> None:
        """Bill ``n_inferences`` runs of profile ``profile_idx`` to the
        ledger (one batched decode step over N live rows = N inferences;
        one admission prefill = one inference per admitted request)."""
        self.spent_j += self.profiles[profile_idx].energy_j * n_inferences

    def plan_schedule(self, steps: int, n_per_step: int = 1,
                      accuracy_critical: bool = False) -> np.ndarray:
        """Select-and-account ``steps`` inferences ahead → ``int32[steps]``.

        The policy is deterministic given the energy ledger, so the per-step
        profile ids of a multi-token generate call can be precomputed and fed
        to the engine as *data* (the schedule array rides through the jitted
        decode scan without retracing — the bits-as-data analogue of the
        paper's runtime configuration word). Identical ledger evolution to
        calling ``select``/``account`` once per step.
        """
        sched = np.empty((steps,), np.int32)
        for i in range(steps):
            sched[i] = self.select(accuracy_critical=accuracy_critical)
            self.account(int(sched[i]), n_per_step)
        return sched

    def plan_schedule_ragged(self, steps: int, row_remaining,
                             row_critical=None, *, draft_w: int = 1,
                             provisional: bool = False) -> np.ndarray:
        """Per-step ids for a ragged row group → ``int32[steps]``.

        Rows finish at different steps (heterogeneous ``max_new`` /
        continuous-batching slot pools), so step ``i`` bills the ledger for
        the rows actually live at that step (``row_remaining > i``) and is
        accuracy-critical only while a critical row is still live — the exact
        ledger evolution of a stepwise per-row select/account oracle, not the
        group-wide over-billing of padding every row to the longest request.

        Args:
            steps: schedule length (the decode segment's quantum — in
                *windows* when ``draft_w > 1``).
            row_remaining: ``[B]`` tokens each pool row still has to emit
                (0 = idle slot — never billed).
            row_critical: optional ``[B]`` bool accuracy-critical flags.
            draft_w: tokens a speculative draft/verify window can deliver
                (``k + 1``; 1 = plain greedy). Window ``i``'s planned bill
                for row ``b`` is ``min(draft_w, rem_b - i*draft_w)`` —
                **clamped** where the final window would overshoot the
                row's budget, so a row with 3 tokens left never plans 4
                phantom bills under ``draft_w = 4`` (invariant 11:
                accepted-token billing).
            provisional: plan profile ids only — do NOT advance the
                ledger. Speculative segments bill *delivered* tokens at the
                flush boundary (acceptance is data the planner cannot
                know); the plan is just the per-window profile binding.
        Returns:
            ``int32[steps]`` profile ids, ready to ride the fused decode
            scan as data. Unless ``provisional``, the ledger is already
            advanced for all of them — plan exactly one segment ahead, or
            the billing drifts from the rows actually live.
        """
        rem = np.asarray(row_remaining, np.int64)
        w = max(1, int(draft_w))
        crit = (np.zeros(rem.shape, bool) if row_critical is None
                else np.asarray(row_critical, bool))
        sched = np.empty((steps,), np.int32)
        spent0, saver0 = self.spent_j, self._saver
        for i in range(steps):
            live = rem > i * w
            sched[i] = self.select(accuracy_critical=bool((crit & live).any()))
            # never bill past a row's own budget: the last window of a row
            # delivers at most rem - i*w tokens, not a full draft_w
            n_tok = int(np.minimum(w, np.maximum(rem - i * w, 0)).sum())
            self.account(int(sched[i]), n_tok)
        if provisional:
            self.spent_j, self._saver = spent0, saver0
        return sched

    def plan_schedule_classes(self, steps: int, row_remaining, row_levels,
                              critical_levels, row_critical=None, *,
                              draft_w: int = 1, provisional: bool = False
                              ) -> np.ndarray:
        """Per-step ids for a *class-aware* row group → ``int32[steps]``.

        The priority-class analogue of :meth:`plan_schedule_ragged`: each
        pool row carries a priority-class ``level``, and the scheduling
        policy binds some classes to the accuracy target
        (``critical_levels``). Step ``i`` is planned accuracy-critical iff
        any row live at step ``i`` belongs to a bound class or carries its
        own per-request critical flag (``row_critical``) — so a critical-
        class row pins high-precision profiles for exactly the steps it is
        live, and the ledger still bills precisely the live rows (the
        stepwise-oracle exactness contract is unchanged).

        Args:
            steps: schedule length (the decode segment's quantum).
            row_remaining: ``[B]`` tokens each pool row still has to emit.
            row_levels: ``[B]`` int priority-class level per row (value
                irrelevant for idle rows — ``remaining == 0`` never bills).
            critical_levels: class levels whose profile binding is
                accuracy-critical (e.g. ``(0,)`` for the stock ladder).
            row_critical: optional ``[B]`` per-request critical flags,
                OR'd with the class binding.
            draft_w: speculative window width in tokens (``k + 1``); the
                final window of each row is clamped to its remaining
                budget — see :meth:`plan_schedule_ragged`.
            provisional: plan ids without advancing the ledger (the
                speculative flush bills actual delivered tokens instead).
        """
        lvl = np.asarray(row_levels)
        crit = np.isin(lvl, np.asarray(list(critical_levels), lvl.dtype))
        if row_critical is not None:
            crit = crit | np.asarray(row_critical, bool)
        return self.plan_schedule_ragged(steps, row_remaining, crit,
                                         draft_w=draft_w,
                                         provisional=provisional)

    def search_precision(self, n_layers: int,
                         score_fn: Callable[[np.ndarray], float],
                         bytes_fn: Callable[[np.ndarray], float],
                         *, ladder: Sequence[int] = (16, 8, 4),
                         max_drop: float = 0.05) -> tuple[np.ndarray, list[dict]]:
        """Search a per-layer KV bit-width schedule (greedy frontier descent).

        The offline half of the precision-policy loop: the online half
        (``select``/``plan_schedule_*``) binds a *profile* per step, and this
        search produces the per-layer KV schedule a profile carries (the
        ``kv_table`` row the serving engine gathers as data — no retrace).

        Starts from the all-high schedule (``ladder[0]`` everywhere — the
        exact-passthrough baseline) and greedily lowers one layer one rung at
        a time, always taking the move with the best bytes-saved per unit of
        proxy-score increase, while the cumulative proxy score stays within
        ``max_drop`` of the baseline. Layers are never raised back: the walk
        is a monotone descent of the bytes axis, and every accepted state is
        recorded on the frontier.

        Args:
            n_layers: schedule length.
            score_fn: ``schedule -> float`` proxy degradation (0 at the
                all-high baseline; larger = worse). Must be deterministic.
            bytes_fn: ``schedule -> float`` KV bytes/step under the schedule.
            ladder: bit-widths high → low (each move drops one rung).
            max_drop: proxy-score budget — moves that would exceed it are
                rejected.
        Returns:
            ``(schedule, frontier)``: the final ``int32[n_layers]`` schedule
            and the accepted-state frontier, each entry a dict with
            ``schedule`` (list), ``score``, and ``bytes``.
        """
        ladder = [int(b) for b in ladder]
        assert sorted(ladder, reverse=True) == ladder and len(ladder) >= 1
        rung = np.zeros((n_layers,), np.int64)      # index into `ladder`
        sched = np.full((n_layers,), ladder[0], np.int32)
        base = float(score_fn(sched))
        frontier = [{"schedule": sched.tolist(), "score": base,
                     "bytes": float(bytes_fn(sched))}]
        while True:
            best = None                              # (ratio, layer, score, by)
            cur_bytes = frontier[-1]["bytes"]
            for l in range(n_layers):
                if rung[l] + 1 >= len(ladder):
                    continue
                cand = sched.copy()
                cand[l] = ladder[rung[l] + 1]
                s = float(score_fn(cand))
                if s - base > max_drop:
                    continue
                by = float(bytes_fn(cand))
                saved = max(cur_bytes - by, 1e-12)
                ratio = max(s - frontier[-1]["score"], 0.0) / saved
                if best is None or ratio < best[0]:
                    best = (ratio, l, s, by)
            if best is None:
                break
            _, l, s, by = best
            rung[l] += 1
            sched[l] = ladder[rung[l]]
            frontier.append({"schedule": sched.tolist(), "score": s,
                             "bytes": by})
        return sched, frontier

    def exhausted(self) -> bool:
        """Whether the energy budget is fully spent."""
        if not self.budget_j:           # zero budget = unconstrained (see
            return False                # remaining_fraction): never exhausts
        return self.spent_j >= self.budget_j


def battery_simulation(profiles: Sequence[ProfileStats], budget_j: float,
                       accuracy_target: float, accuracy_floor: float,
                       fixed_profile: int | None = None,
                       critical_every: int = 0,
                       max_steps: int = 100_000_000) -> dict:
    """Run inferences until the budget is gone (paper Fig. 4, right).

    ``fixed_profile`` simulates the non-adaptive engine (always that profile);
    otherwise the :class:`ProfileManager` policy runs. ``critical_every`` marks
    every k-th classification accuracy-critical (the paper's "critical
    circumstances"). Returns classifications executed, mean accuracy, and the
    battery lifetime in engine-seconds.
    """
    mgr = ProfileManager(profiles, accuracy_target, accuracy_floor, budget_j)
    n = 0
    acc_sum = 0.0
    lifetime_s = 0.0
    usage = [0] * len(profiles)
    while not mgr.exhausted() and n < max_steps:
        if fixed_profile is not None:
            idx = fixed_profile
        else:
            critical = critical_every > 0 and (n % critical_every == 0)
            idx = mgr.select(accuracy_critical=critical)
        mgr.account(idx)
        usage[idx] += 1
        acc_sum += profiles[idx].accuracy
        lifetime_s += profiles[idx].latency_s
        n += 1
    return {
        "classifications": n,
        "mean_accuracy": acc_sum / max(1, n),
        "lifetime_s": lifetime_s,
        "profile_usage": {p.name: u for p, u in zip(profiles, usage)},
    }

"""MDC-analogue profile merging: which layers are shared, what is the overhead.

The Multi-Dataflow Composer of the paper merges the dataflow graphs of several
execution profiles into one reconfigurable datapath, *sharing the actors whose
configuration is identical across profiles*. On TPU the "actor" is a layer's
quantized execution; merging manifests as:

* **shared layer** — identical ``(a_bits, w_bits)`` in all profiles → one code
  path, one (quantized) weight image;
* **switched layer** — differing specs → the merged engine holds one quantized
  weight image *per distinct spec* (not per profile!) and a runtime selection.

:func:`merge_plan` computes that structure plus the resource-accounting used to
reproduce the paper's Fig. 4 overhead numbers (merged engine vs the sum of the
standalone engines).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from .profiles import Profile
from .qtypes import QuantSpec, nbytes_of

__all__ = ["MergePlan", "merge_plan"]


@dataclasses.dataclass(frozen=True)
class MergePlan:
    """Static merge structure for a set of profiles over one model."""

    profiles: tuple[str, ...]
    layer_names: tuple[str, ...]
    # per layer: tuple of distinct (a_bits, w_bits) specs, stable order
    distinct_specs: Mapping[str, tuple[tuple[int, int], ...]]
    # per layer, per profile: index into distinct_specs[layer]
    selector: Mapping[str, tuple[int, ...]]

    @property
    def shared_layers(self) -> tuple[str, ...]:
        return tuple(ln for ln in self.layer_names if len(self.distinct_specs[ln]) == 1)

    @property
    def switched_layers(self) -> tuple[str, ...]:
        return tuple(ln for ln in self.layer_names if len(self.distinct_specs[ln]) > 1)

    def sharing_ratio(self) -> float:
        return len(self.shared_layers) / max(1, len(self.layer_names))

    def resource_bytes(self, weight_shapes: Mapping[str, tuple[int, ...]]) -> dict:
        """Paper-Fig.4 style accounting (weight-image bytes as the BRAM analogue).

        Returns merged bytes, per-profile standalone bytes, and the overhead of
        the merged engine vs the *largest* standalone engine (the paper compares
        the adaptive engine to the most accurate non-adaptive profile).
        """
        merged = 0
        standalone = {p: 0 for p in self.profiles}
        for ln in self.layer_names:
            shape = weight_shapes[ln]
            for (ab, wb) in self.distinct_specs[ln]:
                merged += nbytes_of(shape, QuantSpec(bits=None if wb >= 17 else wb))
            for pi, p in enumerate(self.profiles):
                ab, wb = self.distinct_specs[ln][self.selector[ln][pi]]
                standalone[p] += nbytes_of(shape, QuantSpec(bits=None if wb >= 17 else wb))
        biggest = max(standalone.values())
        return {
            "merged_bytes": merged,
            "standalone_bytes": standalone,
            "sum_standalone_bytes": sum(standalone.values()),
            "overhead_vs_largest": merged / biggest - 1.0 if biggest else 0.0,
            "saving_vs_sum": 1.0 - merged / max(1, sum(standalone.values())),
        }


def merge_plan(profiles: Sequence[Profile]) -> MergePlan:
    """Compute the merged multi-profile structure (the MDC front-end analogue)."""
    if not profiles:
        raise ValueError("need at least one profile")
    layer_names = profiles[0].layer_names
    for p in profiles[1:]:
        if p.layer_names != layer_names:
            raise ValueError(
                f"profiles disagree on layers: {p.name} vs {profiles[0].name}")
    distinct: dict[str, tuple[tuple[int, int], ...]] = {}
    selector: dict[str, tuple[int, ...]] = {}
    for ln in layer_names:
        specs: list[tuple[int, int]] = []
        sel: list[int] = []
        for p in profiles:
            s = tuple(p.bits[ln])
            if s not in specs:
                specs.append(s)
            sel.append(specs.index(s))
        distinct[ln] = tuple(specs)
        selector[ln] = tuple(sel)
    return MergePlan(
        profiles=tuple(p.name for p in profiles),
        layer_names=layer_names,
        distinct_specs=distinct,
        selector=selector,
    )

"""Quantizers: fake-quant (QAT, straight-through) and native integer quantization.

Two regimes, sharing :class:`~repro.core.qtypes.QuantSpec` so a QAT checkpoint
deploys unchanged to the native inference path (DESIGN §8.3):

* ``fake_quant``      — float-in/float-out quantize→dequantize with a
  straight-through estimator; used during quantization-aware training exactly
  like QKeras/Brevitas in the paper.
* ``quantize_native`` / ``dequantize`` — produce/consume integer carriers
  (int8, packed int4) for the serving path and the Pallas kernels, cutting the
  HBM/collective roofline terms.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .qtypes import (
    QuantSpec,
    carrier_dtype,
    compute_scale,
    pack_int4,
    qrange,
    qrange_dynamic,
    unpack_int4,
)

__all__ = [
    "fake_quant",
    "fake_quant_dynamic",
    "fake_quant_dynamic_token",
    "quantize_native",
    "dequantize",
    "QTensor",
]


def _round(x: jax.Array, stochastic: bool, key: Optional[jax.Array]) -> jax.Array:
    if not stochastic:
        # round-half-away-from-zero: matches HLS AP_RND behaviour and is
        # symmetric in sign, unlike jnp.round's banker's rounding.
        return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)
    assert key is not None, "stochastic rounding needs a PRNG key"
    noise = jax.random.uniform(key, x.shape, dtype=x.dtype)
    return jnp.floor(x + noise)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(x: jax.Array, spec: QuantSpec, scale: Optional[jax.Array] = None,
               key: Optional[jax.Array] = None) -> jax.Array:
    """Quantize→dequantize ``x`` onto the grid of ``spec`` (float in/out).

    If ``scale`` is None it is calibrated on the fly from ``max|x|`` (the
    dynamic-quantization used for activations at training time); passing a
    fixed scale reproduces static fixed-point behaviour.
    Gradient: straight-through inside the clip range, zero outside.
    """
    y, _ = _fake_quant_fwd(x, spec, scale, key)
    return y


def _fake_quant_impl(x, spec: QuantSpec, scale, key):
    if spec.is_float:
        return x, None
    dt = x.dtype
    xf = x.astype(jnp.float32)
    s = compute_scale(xf, spec) if scale is None else jnp.asarray(scale, jnp.float32)
    qmin, qmax = qrange(spec)
    q = jnp.clip(_round(xf / s, spec.stochastic, key), qmin, qmax)
    lo, hi = qmin * s, qmax * s  # pass-through band for the STE mask
    return (q * s).astype(dt), (xf, lo, hi)


def _fake_quant_fwd(x, spec, scale, key):
    y, res = _fake_quant_impl(x, spec, scale, key)
    return y, res


def _fake_quant_bwd(spec, res, g):
    if res is None:  # float passthrough
        return (g, None, None)
    xf, lo, hi = res
    mask = ((xf >= lo) & (xf <= hi)).astype(g.dtype)
    return (g * mask, None, None)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


@jax.custom_vjp
def fake_quant_dynamic(x: jax.Array, bits: jax.Array, signed_sym: jax.Array) -> jax.Array:
    """Fake-quant with *traced* bit-width (spec-as-data; DESIGN §8.2).

    Used inside ``lax.scan`` over stacked layers where each layer row carries
    its own (possibly different) precision — the branch-free realization of the
    paper's per-layer mixed precision. ``bits >= 17`` rows degrade to identity.
    ``signed_sym`` is a (2,) int array [signed, symmetric] kept as data for
    completeness; current model code always uses signed, non-symmetric.
    """
    y, _ = _fqd_fwd(x, bits, signed_sym)
    return y


def _fqd_impl(x, bits, signed_sym, axis=None):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    qmin, qmax = qrange_dynamic(bits, signed=True, symmetric=False)
    if axis is None:
        amax = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-9)
    else:
        amax = jnp.maximum(jnp.max(jnp.abs(xf), axis=axis, keepdims=True), 1e-9)
    scale = jnp.exp2(jnp.ceil(jnp.log2(amax / jnp.maximum(-qmin, qmax))))
    q = jnp.clip(jnp.sign(xf / scale) * jnp.floor(jnp.abs(xf / scale) + 0.5), qmin, qmax)
    y = q * scale
    passthrough = (bits >= 17).astype(jnp.float32)
    y = passthrough * xf + (1.0 - passthrough) * y
    lo, hi = qmin * scale, qmax * scale
    mask = passthrough + (1.0 - passthrough) * ((xf >= lo) & (xf <= hi)).astype(jnp.float32)
    return y.astype(dt), mask


def _fqd_fwd(x, bits, signed_sym):
    y, mask = _fqd_impl(x, bits, signed_sym)
    return y, mask


def _fqd_bwd(mask, g):
    return (g * mask.astype(g.dtype), None, None)


fake_quant_dynamic.defvjp(_fqd_fwd, _fqd_bwd)


@jax.custom_vjp
def fake_quant_dynamic_token(x: jax.Array, bits: jax.Array,
                             signed_sym: jax.Array) -> jax.Array:
    """Per-token :func:`fake_quant_dynamic`: the pow2 grid is chosen from each
    trailing-axis row's own ``amax`` instead of the whole tensor's.

    Activation quantization uses this so a token's values depend **only on that
    token** — a row's decode numerics become invariant to batch composition and
    to how many positions share the forward pass. That invariance is what makes
    speculative verify windows (``[B, k+1]``) bit-identical to the stepwise
    ``[B, 1]`` greedy decode (docs/serving.md, invariant 11): a per-tensor amax
    would couple every window position (and every batch row) through one shared
    scale, flipping pow2 buckets whenever a *neighbouring* token's range grows.
    For 1-D inputs this is exactly ``fake_quant_dynamic``. Weight quantization
    keeps the per-tensor grid (weights are identical across paths anyway).
    """
    y, _ = _fqd_fwd_token(x, bits, signed_sym)
    return y


def _fqd_fwd_token(x, bits, signed_sym):
    return _fqd_impl(x, bits, signed_sym, axis=-1)


fake_quant_dynamic_token.defvjp(_fqd_fwd_token, _fqd_bwd)


class QTensor(NamedTuple):
    """A natively quantized tensor: integer carrier + scale (+ static spec info).

    ``data`` is int8 (int4 values packed two-per-byte when ``bits <= 4``);
    ``scale`` broadcasts against the *dequantized* shape. ``bits`` and the
    original trailing dim ``orig_last`` ride in static fields of the pytree.
    """

    data: jax.Array
    scale: jax.Array
    bits: int
    orig_last: int

    @property
    def shape(self):
        if self.bits <= 4:
            return (*self.data.shape[:-1], self.orig_last)
        return self.data.shape


def quantize_native(x: jax.Array, spec: QuantSpec, scale: Optional[jax.Array] = None) -> QTensor:
    """Quantize to an integer carrier for storage/serving (no gradient path)."""
    assert not spec.is_float
    xf = x.astype(jnp.float32)
    s = compute_scale(xf, spec) if scale is None else jnp.asarray(scale, jnp.float32)
    qmin, qmax = qrange(spec)
    q = jnp.clip(jnp.sign(xf / s) * jnp.floor(jnp.abs(xf / s) + 0.5), qmin, qmax)
    if spec.bits <= 4:
        data = pack_int4(q.astype(jnp.int8))
    else:
        data = q.astype(carrier_dtype(spec.bits))
    return QTensor(data=data, scale=s, bits=spec.bits, orig_last=x.shape[-1])


def dequantize(qt: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    """Dequantize a :class:`QTensor` back to floats (the jnp reference path;
    the Pallas kernel fuses this into the matmul)."""
    q = unpack_int4(qt.data) if qt.bits <= 4 else qt.data
    return (q.astype(jnp.float32) * qt.scale).astype(dtype)


jax.tree_util.register_pytree_node(
    QTensor,
    lambda t: ((t.data, t.scale), (t.bits, t.orig_last)),
    lambda aux, ch: QTensor(ch[0], ch[1], aux[0], aux[1]),
)

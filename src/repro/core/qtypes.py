"""Quantization types — the framework analogue of QONNX arbitrary-precision datatypes.

The paper expresses per-layer precision as Vitis-HLS ``ap_fixed<W,I>`` fixed-point
types carried in a QONNX graph.  On TPU the hardware-aligned carriers are int8 /
int4 (+ bf16 compute), so we express an arbitrary bit-width ``b`` as an integer
grid of ``2**b`` levels held inside the narrowest carrier that fits, with either

* a **power-of-two scale** (``po2_scale=True``) — bit-exact with fixed point,
  the paper-faithful mode, or
* a float (optionally per-channel) scale — the TPU-native extension used by the
  beyond-paper optimized paths.

``QuantSpec`` is hashable and static (pytree-aux data); the tensors derived from
it (scales, packed weights) are ordinary pytree leaves.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "QuantSpec",
    "qrange",
    "compute_scale",
    "pack_int4",
    "unpack_int4",
    "carrier_dtype",
    "FLOAT_SPEC",
]


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of one quantized datatype (the ``Ax``/``Wy`` of the paper).

    Attributes:
      bits: total bit width (1..16). ``bits >= 17`` (or ``bits is None``) means
        "not quantized" (float passthrough).
      signed: two's-complement signed grid if True.
      symmetric: if True the grid is ±(2**(b-1)-1) (no asymmetric zero-point);
        if False, the full two's-complement range [-2**(b-1), 2**(b-1)-1] is
        used — this is the exact value set of ``ap_fixed`` and is the default
        for the paper-faithful po2 mode.
      po2_scale: constrain the scale to a power of two (fixed-point faithful).
      per_channel: one scale per output channel (weights only).
      channel_axis: axis holding channels when ``per_channel``.
      stochastic: use stochastic rounding when (fake-)quantizing — used by the
        int8 gradient-compression path, never by inference.
    """

    bits: Optional[int] = 8
    signed: bool = True
    symmetric: bool = False
    po2_scale: bool = True
    per_channel: bool = False
    channel_axis: int = -1
    stochastic: bool = False

    @property
    def is_float(self) -> bool:
        return self.bits is None or self.bits >= 17

    def __str__(self) -> str:  # e.g. "i8(po2)" / "i4/ch" / "f"
        if self.is_float:
            return "f"
        tags = []
        if self.po2_scale:
            tags.append("po2")
        if self.per_channel:
            tags.append("ch")
        if self.symmetric:
            tags.append("sym")
        t = ",".join(tags)
        return f"{'i' if self.signed else 'u'}{self.bits}" + (f"({t})" if t else "")

    def with_(self, **kw) -> "QuantSpec":
        return dataclasses.replace(self, **kw)


FLOAT_SPEC = QuantSpec(bits=None)


def qrange(spec: QuantSpec) -> tuple[int, int]:
    """(qmin, qmax) integer grid bounds for a spec."""
    assert not spec.is_float
    b = spec.bits
    if spec.signed:
        if spec.symmetric:
            return -(2 ** (b - 1) - 1), 2 ** (b - 1) - 1
        return -(2 ** (b - 1)), 2 ** (b - 1) - 1
    return 0, 2**b - 1


def qrange_dynamic(bits: jax.Array, signed: bool = True, symmetric: bool = False):
    """qmin/qmax when ``bits`` is a *traced* array (spec-as-data, see DESIGN §8.2).

    Enables per-layer bit-widths inside ``lax.scan`` over stacked layers: the
    bits value rides along as a scanned leaf instead of switching code paths.
    """
    bits = bits.astype(jnp.float32)
    if signed:
        qmax = jnp.exp2(bits - 1.0) - 1.0
        qmin = -(qmax + (0.0 if symmetric else 1.0))
    else:
        qmax = jnp.exp2(bits) - 1.0
        qmin = jnp.zeros_like(qmax)
    return qmin, qmax


def _reduce_axes(x: jax.Array, spec: QuantSpec) -> tuple[int, ...]:
    if not spec.per_channel:
        return tuple(range(x.ndim))
    ax = spec.channel_axis % x.ndim
    return tuple(a for a in range(x.ndim) if a != ax)


def compute_scale(x: jax.Array, spec: QuantSpec, eps: float = 1e-9) -> jax.Array:
    """Calibrate a scale from the max-abs of ``x`` (per-tensor or per-channel).

    po2 mode rounds the scale *up* to the next power of two so the grid always
    covers the observed range (fixed-point semantics: widen the integer part).
    """
    assert not spec.is_float
    qmin, qmax = qrange(spec)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=_reduce_axes(x, spec), keepdims=spec.per_channel)
    amax = jnp.maximum(amax, eps)
    denom = float(max(qmax, -qmin))
    scale = amax / denom
    if spec.po2_scale:
        scale = jnp.exp2(jnp.ceil(jnp.log2(scale)))
    return scale


def carrier_dtype(bits: int) -> jnp.dtype:
    """Narrowest storage dtype for a native-quantized tensor of width ``bits``."""
    if bits <= 8:
        return jnp.int8  # int4 values are stored packed 2-per-int8 (see pack_int4)
    return jnp.int16


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack signed int4 values (int8-carried, in [-8, 7]) two-per-byte.

    The last axis must be even. Low nibble = even index, high nibble = odd.
    This is the storage layout the Pallas kernel unpacks in VMEM.
    """
    assert q.shape[-1] % 2 == 0, "pack_int4 needs an even trailing axis"
    q = q.astype(jnp.int8)
    lo = q[..., 0::2] & 0x0F
    hi = q[..., 1::2] & 0x0F
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(p: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` — returns int8-carried int4 values."""
    p = p.astype(jnp.int8)
    lo = (p << 4) >> 4          # arithmetic shift sign-extends the low nibble
    hi = p >> 4                 # arithmetic shift sign-extends the high nibble
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2)


def nbytes_of(shape: tuple[int, ...], spec: QuantSpec) -> int:
    """Storage bytes for a native-quantized tensor (int4 counts 0.5 B/elt)."""
    n = int(np.prod(shape))
    if spec.is_float:
        return n * 2  # bf16 reference storage
    if spec.bits <= 4:
        return (n + 1) // 2
    if spec.bits <= 8:
        return n
    return n * 2

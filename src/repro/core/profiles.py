"""Execution profiles — the paper's ``Ax-Wy`` data-approximation configurations.

A :class:`Profile` assigns every quantizable layer of a model a pair
``(a_bits, w_bits)`` — activation and weight precision — exactly like the
paper's profile strings (``A16-W8`` … ``A4-W4``) plus intra-network mixed
profiles (their ``Mixed`` = A8-W8 with the inner conv at A4-W4).

Profiles compile to a dense ``[n_profiles, n_layers, 2]`` int32 table
(:func:`profile_table`); at runtime the active profile is *data* (an index into
the table), which is what lets the merged engine switch profiles without
recompilation (DESIGN §8.1).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Mapping, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = ["Profile", "profile_table", "parse_profile_string", "PAPER_PROFILES", "FLOAT_BITS"]

# bits >= 17 means "float passthrough" in the spec-as-data encoding.
FLOAT_BITS = 32

_NAME_RE = re.compile(r"^A(\d+)-W(\d+)$")


@dataclasses.dataclass(frozen=True, eq=False)
class Profile:
    """Per-layer precision assignment for one execution profile."""

    name: str
    bits: Mapping[str, tuple[int, int]]  # layer name -> (a_bits, w_bits)

    def __hash__(self):  # stable content hash (bits is a dict)
        return hash((self.name, tuple(sorted(self.bits.items()))))

    def __eq__(self, other):
        return isinstance(other, Profile) and self.name == other.name and \
            dict(self.bits) == dict(other.bits)

    @staticmethod
    def uniform(name: str, layer_names: Sequence[str],
                a_bits: int | None = None, w_bits: int | None = None) -> "Profile":
        """Build e.g. ``A8-W4`` over all layers; bits parsed from ``name`` if omitted."""
        if a_bits is None or w_bits is None:
            a_bits, w_bits = parse_profile_string(name)
        return Profile(name, {ln: (a_bits, w_bits) for ln in layer_names})

    @staticmethod
    def float32(layer_names: Sequence[str]) -> "Profile":
        return Profile("float", {ln: (FLOAT_BITS, FLOAT_BITS) for ln in layer_names})

    def override(self, name: str, overrides: Mapping[str, tuple[int, int]]) -> "Profile":
        """Derive a mixed profile (paper §4.3): replace precision on some layers."""
        merged = dict(self.bits)
        for k, v in overrides.items():
            if k not in merged:
                raise KeyError(f"unknown layer {k!r}; known: {sorted(merged)}")
            merged[k] = v
        return Profile(name, merged)

    @property
    def layer_names(self) -> tuple[str, ...]:
        return tuple(self.bits)

    def a_bits(self, layer: str) -> int:
        return self.bits[layer][0]

    def w_bits(self, layer: str) -> int:
        return self.bits[layer][1]


def parse_profile_string(s: str) -> tuple[int, int]:
    """``"A8-W4"`` → ``(8, 4)``."""
    m = _NAME_RE.match(s)
    if not m:
        raise ValueError(f"profile string {s!r} does not match 'Ax-Wy'")
    return int(m.group(1)), int(m.group(2))


def profile_table(profiles: Sequence[Profile], layer_names: Sequence[str]) -> jnp.ndarray:
    """Dense ``[P, L, 2]`` int32 table of (a_bits, w_bits); the merged engine's
    "configuration memory" (the analogue of MDC's datapath configuration)."""
    if not profiles:
        raise ValueError("need at least one profile")
    tab = np.zeros((len(profiles), len(layer_names), 2), np.int32)
    for p, prof in enumerate(profiles):
        missing = [ln for ln in layer_names if ln not in prof.bits]
        if missing:
            raise KeyError(f"profile {prof.name!r} missing layers {missing}")
        for l, ln in enumerate(layer_names):
            tab[p, l] = prof.bits[ln]
    return jnp.asarray(tab)


def paper_profiles(layer_names: Sequence[str], inner_layers: Sequence[str] = ()) -> list[Profile]:
    """The exact profile family evaluated by the paper (§4.2-4.3).

    ``inner_layers`` are the layers dropped to A4-W4 in the ``Mixed`` profile
    (the paper uses the inner convolutional layer).
    """
    profs = [Profile.uniform(n, layer_names)
             for n in ("A16-W8", "A16-W4", "A8-W8", "A8-W4", "A4-W4")]
    base = Profile.uniform("A8-W8", layer_names)
    mixed = base.override("Mixed", {ln: (4, 4) for ln in inner_layers}) if inner_layers else base
    profs.append(dataclasses.replace(mixed, name="Mixed"))
    return profs


PAPER_PROFILES = ("A16-W8", "A16-W4", "A8-W8", "A8-W4", "A4-W4", "Mixed")

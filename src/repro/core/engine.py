"""Adaptive inference engine — one compiled executable, many execution profiles.

The FPGA flow's adaptive engine is a coarse-grained-reconfigurable datapath:
all profiles are synthesized *once* into merged hardware, and a configuration
word selects the active profile at runtime. The TPU analogue (DESIGN §8.1):

* the full profile family is traced/compiled **once**;
* the per-layer precision of the active profile is *data* — a row of the
  ``[P, L, 2]`` bits table gathered with the traced scalar ``profile_id``;
* layers whose precision coincides across profiles are automatically shared
  (same code path, same weights); layers that differ see different bits values
  (fake-quant path) or a ``lax.switch`` over pre-quantized weight images
  (native serving path).

Switching profiles therefore costs one scalar — no re-jit, no weight reload —
mirroring MDC reconfiguration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from .merge import MergePlan, merge_plan
from .profiles import Profile, profile_table

__all__ = ["QuantIndex", "AdaptiveEngine", "switch_images"]


@dataclasses.dataclass(frozen=True)
class QuantIndex:
    """Static layer-name → row-index map shared by a model and its engine.

    Models capture this statically (closure/aux data) and use it to pull their
    per-layer bits out of the traced bits row that the engine feeds them.
    """

    layer_names: tuple[str, ...]

    def __post_init__(self):
        object.__setattr__(self, "_idx", {n: i for i, n in enumerate(self.layer_names)})

    def index(self, name: str) -> int:
        return self._idx[name]

    def a_bits(self, bits_row: jax.Array, name: str) -> jax.Array:
        return bits_row[self._idx[name], 0]

    def w_bits(self, bits_row: jax.Array, name: str) -> jax.Array:
        return bits_row[self._idx[name], 1]

    def gather(self, bits_row: jax.Array, names: Sequence[str]) -> jax.Array:
        """Stack bits for ``names`` → ``[len(names), 2]`` (scan-over-layers leaf)."""
        ids = jnp.asarray([self._idx[n] for n in names], jnp.int32)
        return bits_row[ids]


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: hash by identity (jit key)
class AdaptiveEngine:
    """Merged multi-profile executor around a quantization-aware ``apply_fn``.

    ``apply_fn(params, bits_row, *inputs)`` must consume per-layer precision
    exclusively through ``bits_row`` (shape ``[L, 2]``, int32) — typically via
    :class:`QuantIndex` — so that the engine stays a single traceable program.
    """

    profiles: tuple[Profile, ...]
    index: QuantIndex
    apply_fn: Callable[..., Any]

    def __post_init__(self):
        object.__setattr__(self, "table", profile_table(self.profiles, self.index.layer_names))
        object.__setattr__(self, "plan", merge_plan(self.profiles))

    @property
    def profile_names(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.profiles)

    def profile_id(self, name: str) -> int:
        return self.profile_names.index(name)

    def bits_row(self, profile_id: jax.Array | int) -> jax.Array:
        return jnp.asarray(self.table)[jnp.asarray(profile_id, jnp.int32)]

    def __call__(self, params, profile_id: jax.Array | int, *inputs, **kw):
        return self.apply_fn(params, self.bits_row(profile_id), *inputs, **kw)

    def merge_report(self, weight_shapes: Mapping[str, tuple[int, ...]] | None = None) -> dict:
        plan: MergePlan = self.plan
        rep = {
            "profiles": list(plan.profiles),
            "n_layers": len(plan.layer_names),
            "shared_layers": list(plan.shared_layers),
            "switched_layers": list(plan.switched_layers),
            "sharing_ratio": plan.sharing_ratio(),
        }
        if weight_shapes is not None:
            rep["resources"] = plan.resource_bytes(weight_shapes)
        return rep


def switch_images(selector: jax.Array, images: Sequence[Any], fn: Callable[[Any], Any]):
    """Native-path runtime selection among pre-quantized weight images.

    ``images`` holds one entry per *distinct* spec of a switched layer (the
    deduplicated "actors" of the MDC merge); ``selector`` is the traced index
    produced from ``profile_id`` via the merge plan's selector row. For a
    single image (shared layer) the switch disappears — mirroring MDC sharing.
    """
    if len(images) == 1:
        return fn(images[0])
    return jax.lax.switch(selector, [lambda im=im: fn(im) for im in images])

"""Core of the reproduction: the paper's contribution as composable JAX modules.

- ``qtypes`` / ``quantizers`` — arbitrary-precision data approximation
  (QONNX-style per-layer ``Ax-Wy``), QAT fake-quant + native int carriers.
- ``profiles`` / ``merge`` / ``engine`` — computation approximation: execution
  profiles merged into a single runtime-switchable engine (MDC analogue).
- ``manager`` / ``energy`` — the self-adaptive Profile Manager on a documented
  roofline-derived energy model.
"""
from .qtypes import QuantSpec, FLOAT_SPEC, qrange, compute_scale, pack_int4, unpack_int4
from .quantizers import (fake_quant, fake_quant_dynamic,
                         fake_quant_dynamic_token, quantize_native,
                         dequantize, QTensor)
from .profiles import Profile, profile_table, parse_profile_string, paper_profiles, FLOAT_BITS
from .merge import MergePlan, merge_plan
from .engine import AdaptiveEngine, QuantIndex, switch_images
from .manager import ProfileManager, ProfileStats, battery_simulation
from .energy import HWSpec, TPU_V5E, roofline_terms, step_energy, activity_factor

__all__ = [
    "QuantSpec", "FLOAT_SPEC", "qrange", "compute_scale", "pack_int4", "unpack_int4",
    "fake_quant", "fake_quant_dynamic", "fake_quant_dynamic_token",
    "quantize_native", "dequantize", "QTensor",
    "Profile", "profile_table", "parse_profile_string", "paper_profiles", "FLOAT_BITS",
    "MergePlan", "merge_plan",
    "AdaptiveEngine", "QuantIndex", "switch_images",
    "ProfileManager", "ProfileStats", "battery_simulation",
    "HWSpec", "TPU_V5E", "roofline_terms", "step_energy", "activity_factor",
]

"""Gradient compression for cross-pod all-reduce (distributed-optimization trick).

At multi-pod scale the data-parallel all-reduce of f32 gradients dominates the
collective roofline term. We apply the paper's own medicine to the *training*
path: gradients are quantized to int8 with stochastic rounding before the
all-reduce and dequantized after, with **error feedback** (the residual is
carried to the next step) so convergence is preserved (Karimireddy et al.,
2019). 4× fewer collective bytes; EXPERIMENTS §Perf quantifies the term.

Implemented as a pair of pure functions so it composes with any ``psum``-like
reducer: ``compress → (reduce int8 partials as f32 sums) → decompress``.
The wire format is int8 + one f32 scale per leaf.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionState", "init_error_feedback", "compress_tree",
           "decompress_tree", "compressed_psum"]


class CompressionState(NamedTuple):
    residual: dict  # error-feedback memory, same structure as grads


def init_error_feedback(params) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))


def _quantize_leaf(g: jax.Array, key: jax.Array):
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = amax / 127.0
    noise = jax.random.uniform(key, g.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(g / scale + noise), -127, 127).astype(jnp.int8)
    return q, scale


def compress_tree(grads, state: CompressionState, key: jax.Array):
    """→ (int8 tree, scales tree, new_state). Residual added before quant,
    quantization error becomes the next residual (error feedback)."""
    leaves, tdef = jax.tree_util.tree_flatten(grads)
    res = jax.tree_util.tree_leaves(state.residual)
    keys = jax.random.split(key, len(leaves))
    qs, scales, new_res = [], [], []
    for g, r, k in zip(leaves, res, keys):
        corrected = g.astype(jnp.float32) + r
        q, s = _quantize_leaf(corrected, k)
        qs.append(q)
        scales.append(s)
        new_res.append(corrected - q.astype(jnp.float32) * s)
    return (tdef.unflatten(qs), tdef.unflatten(scales),
            CompressionState(residual=tdef.unflatten(new_res)))


def decompress_tree(q_tree, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scales)


def compressed_psum(grads, state: CompressionState, key: jax.Array,
                    axis_name: str):
    """int8-wire psum over ``axis_name`` (inside shard_map/pmap): quantize,
    sum int8 payloads as f32 (scales reduced alongside), dequantize, average."""
    q, s, new_state = compress_tree(grads, state, key)
    n = jax.lax.psum(1, axis_name)
    summed = jax.tree.map(
        lambda qi, si: jax.lax.psum(qi.astype(jnp.float32) * si, axis_name) / n,
        q, s)
    return summed, new_state

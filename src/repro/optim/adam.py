"""AdamW with warmup-cosine schedule and global-norm clipping (no optax in
this container — implemented from scratch as a pytree transform)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamConfig", "AdamState", "adam_init", "adam_update",
           "warmup_cosine", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def adam_init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.copy, zeros))


def warmup_cosine(cfg: AdamConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


def adam_update(cfg: AdamConfig, grads, state: AdamState, params):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = warmup_cosine(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_ = cfg.b1 * m + (1 - cfg.b1) * g
        v_ = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_ / b1c
        vh = v_ / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_, v_

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}

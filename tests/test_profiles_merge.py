"""Profiles, MDC-analogue merging, and the adaptive engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip cleanly when absent
from hypothesis import given, settings, strategies as st

from repro.core import (AdaptiveEngine, Profile, QuantIndex, fake_quant_dynamic,
                        merge_plan, profile_table, switch_images)
from repro.core.profiles import paper_profiles, parse_profile_string

LAYERS = ("conv0", "conv1", "fc")


def test_parse_profile_string():
    assert parse_profile_string("A16-W8") == (16, 8)
    with pytest.raises(ValueError):
        parse_profile_string("B16-W8")


def test_paper_profiles_family():
    profs = paper_profiles(LAYERS, inner_layers=["conv1"])
    names = [p.name for p in profs]
    assert names == ["A16-W8", "A16-W4", "A8-W8", "A8-W4", "A4-W4", "Mixed"]
    mixed = profs[-1]
    assert mixed.bits["conv1"] == (4, 4) and mixed.bits["conv0"] == (8, 8)


def test_paper_merge_structure():
    """The paper's pair (A8-W8 + Mixed) shares all layers but the inner conv."""
    profs = {p.name: p for p in paper_profiles(LAYERS, inner_layers=["conv1"])}
    plan = merge_plan([profs["A8-W8"], profs["Mixed"]])
    assert plan.shared_layers == ("conv0", "fc")
    assert plan.switched_layers == ("conv1",)
    res = plan.resource_bytes({"conv0": (3, 3, 1, 64), "conv1": (3, 3, 64, 64),
                               "fc": (3136, 10)})
    # merged engine ≤ sum of standalones (resource sharing), ≥ largest single
    assert res["merged_bytes"] <= res["sum_standalone_bytes"]
    assert res["merged_bytes"] >= max(res["standalone_bytes"].values())


@given(st.lists(st.tuples(st.sampled_from([4, 8, 16]),
                          st.sampled_from([4, 8])), min_size=1, max_size=4))
@settings(max_examples=30, deadline=None)
def test_merge_plan_invariants(bit_choices):
    profs = [Profile(f"p{i}", {ln: bits for ln in LAYERS})
             for i, bits in enumerate(bit_choices)]
    plan = merge_plan(profs)
    # partition property
    assert set(plan.shared_layers) | set(plan.switched_layers) == set(LAYERS)
    assert not set(plan.shared_layers) & set(plan.switched_layers)
    # selector indexes into distinct specs and reproduces each profile
    for ln in LAYERS:
        for pi, p in enumerate(profs):
            assert plan.distinct_specs[ln][plan.selector[ln][pi]] == p.bits[ln]
    # distinct specs are unique
    for ln in LAYERS:
        assert len(set(plan.distinct_specs[ln])) == len(plan.distinct_specs[ln])


def test_profile_table_and_engine_switching():
    profs = paper_profiles(LAYERS, inner_layers=["conv1"])
    idx = QuantIndex(LAYERS)
    x = jnp.linspace(-2, 2, 101)
    ss = jnp.asarray(np.array([1, 0], np.int32))

    def apply_fn(params, bits_row, x):
        a = fake_quant_dynamic(x, idx.a_bits(bits_row, "conv0"), ss)
        b = fake_quant_dynamic(x, idx.a_bits(bits_row, "conv1"), ss)
        return a, b

    eng = AdaptiveEngine(tuple(profs), idx, apply_fn)
    f = jax.jit(eng)
    a8, b8 = f(None, eng.profile_id("A8-W8"), x)
    am, bm = f(None, eng.profile_id("Mixed"), x)
    # shared layer conv0: identical; switched layer conv1: differs
    np.testing.assert_array_equal(np.asarray(a8), np.asarray(am))
    assert float(jnp.max(jnp.abs(b8 - bm))) > 0


def test_engine_one_compilation_for_all_profiles():
    profs = paper_profiles(LAYERS, inner_layers=["conv1"])
    idx = QuantIndex(LAYERS)
    calls = {"n": 0}

    def apply_fn(params, bits_row, x):
        calls["n"] += 1
        return fake_quant_dynamic(x, idx.a_bits(bits_row, "conv1"),
                                  jnp.asarray(np.array([1, 0], np.int32)))

    eng = AdaptiveEngine(tuple(profs), idx, apply_fn)
    f = jax.jit(eng)
    x = jnp.ones(8)
    for pid in range(len(profs)):
        f(None, pid, x)
    assert calls["n"] == 1  # traced once → profile switch is data, not recompile


def test_switch_images_selects():
    imgs = [jnp.zeros(3), jnp.ones(3), jnp.full(3, 2.0)]
    for i in range(3):
        out = switch_images(jnp.int32(i), imgs, lambda t: t)
        np.testing.assert_array_equal(np.asarray(out), np.full(3, float(i)))


def test_merge_report():
    profs = paper_profiles(LAYERS, inner_layers=["conv1"])
    idx = QuantIndex(LAYERS)
    eng = AdaptiveEngine(tuple(profs), idx, lambda p, br, x: x)
    rep = eng.merge_report({"conv0": (3, 3, 1, 64), "conv1": (3, 3, 64, 64),
                            "fc": (3136, 10)})
    assert rep["n_layers"] == 3 and "resources" in rep

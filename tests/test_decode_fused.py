"""Fused scan decode == seed per-token loop (tokens, traces, energy ledger)."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import paper_profiles
from repro.analysis.tracker import DispatchAudit
from repro.models import transformer as T
from repro.serving.engine import AdaptiveServer, Request, ServingConfig


def _build(arch):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


@pytest.fixture(scope="module")
def dense_parts():
    return _build("granite-3-2b")


def _manager():
    stats = [ProfileStats(n, acc, e, 1e-3) for n, acc, e in [
        ("A16-W8", 0.99, 4.0), ("A16-W4", 0.953, 2.0), ("A8-W8", 0.988, 3.0),
        ("A8-W4", 0.953, 1.5), ("A4-W4", 0.958, 1.0), ("Mixed", 0.975, 2.0)]]
    return ProfileManager(stats, accuracy_target=0.985, accuracy_floor=0.90,
                          budget_j=120.0, low_energy=0.5)


@pytest.mark.parametrize("kv_bits", [16, 8])
def test_fused_matches_stepwise(dense_parts, kv_bits):
    """Scan-based generate: token-for-token identical output, identical
    realized profile trace, and identical energy accounting vs the seed
    per-step host loop — under an active ProfileManager (profiles switch
    mid-generation as the budget drains)."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, kv_bits=kv_bits, max_batch=4)
    m_fused, m_step = _manager(), _manager()
    srv_fused = AdaptiveServer(cfg, params, eng, scfg, manager=m_fused)
    srv_step = AdaptiveServer(cfg, params, eng, scfg, manager=m_step)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (3, 8)).astype(np.int32)
    out_f = srv_fused.generate(prompts, max_new=10)
    out_s = srv_step.generate_stepwise(prompts, max_new=10)
    assert out_f["tokens"] == out_s["tokens"]
    assert out_f["profile_trace"] == out_s["profile_trace"]
    assert len(set(out_f["profile_trace"])) >= 2      # adaptivity survived
    assert abs(m_fused.spent_j - m_step.spent_j) < 1e-9


def test_fused_is_single_decode_dispatch(dense_parts):
    """The decode hot loop is one jitted dispatch: generate must never touch
    the per-token ``_decode`` executable or sync logits to host per step
    (named invariant ``no-per-token-dispatch``, via DispatchAudit)."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng, ServingConfig(slots=64))
    prompts = np.zeros((2, 4), np.int32)
    with DispatchAudit(srv, ["_decode", "_generate"]) as audit:
        audit.forbid("_decode")  # any per-token dispatch is a regression
        out = srv.generate(prompts, max_new=6)
        assert audit.calls("_generate") == 1
    assert len(out["tokens"]) == 2 and len(out["tokens"][0]) == 6


def test_schedule_is_data_no_retrace(dense_parts):
    """A different profile schedule (manager state moved on) must reuse the
    compiled scan — bits ride as data, switching never retraces (named
    invariant ``no-retrace``, via DispatchAudit)."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng, ServingConfig(slots=64),
                         manager=_manager())
    prompts = np.zeros((2, 4), np.int32)
    srv.generate(prompts, max_new=6)
    n0 = srv._generate._cache_size()
    with DispatchAudit(srv, ["_generate"]) as audit:
        srv.generate(prompts, max_new=6)  # ledger drained → new schedule
        audit.assert_no_retrace()
    assert srv._generate._cache_size() == n0 == 1


def test_row_budget_done_mask(dense_parts):
    """Tokens at index >= a row's budget come back masked (−1), live rows are
    unaffected by frozen neighbours."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng, ServingConfig(slots=64))
    prompts = np.random.default_rng(2).integers(
        0, cfg.vocab, (3, 6)).astype(np.int32)
    full = srv.generate(prompts, max_new=8)
    masked = srv.generate(prompts, max_new=8,
                          row_budget=np.asarray([8, 3, 5], np.int32))
    for row, budget in enumerate([8, 3, 5]):
        assert masked["tokens"][row][:budget] == full["tokens"][row][:budget]
        assert all(t == -1 for t in masked["tokens"][row][budget:])


def test_serve_heterogeneous_budgets_match_solo_runs(dense_parts):
    """serve() batches requests with different max_new into one padded fused
    call; each result must equal running that request alone (dense rows are
    independent, the done-mask freezes finished rows)."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    rng = np.random.default_rng(3)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                    max_new=mn) for mn in (7, 2, 4)]
    results = srv.serve(reqs)
    solo = AdaptiveServer(cfg, params, eng, scfg)
    for req, res in zip(reqs, results):
        assert len(res["tokens"]) == req.max_new
        ref = solo.generate_stepwise(req.tokens[None, :], req.max_new)
        assert res["tokens"] == ref["tokens"][0][:req.max_new]


def test_fused_matches_stepwise_ssm():
    """Scan carry also threads SSM recurrent state (no KV cache)."""
    cfg, params, eng = _build("mamba2-130m")
    scfg = ServingConfig(slots=32)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab, (2, 6)).astype(np.int32)
    out_f = srv.generate(prompts, max_new=5)
    out_s = srv.generate_stepwise(prompts, max_new=5)
    assert out_f["tokens"] == out_s["tokens"]


def test_plan_schedule_matches_select_account_loop():
    """plan_schedule is the vectorized form of the seed select/account loop:
    same ids, same ledger evolution."""
    m_plan, m_loop = _manager(), _manager()
    sched = m_plan.plan_schedule(20, n_per_step=4)
    loop = []
    for _ in range(20):
        pid = m_loop.select()
        m_loop.account(pid, 4)
        loop.append(pid)
    assert sched.dtype == np.int32
    assert sched.tolist() == loop
    assert abs(m_plan.spent_j - m_loop.spent_j) < 1e-12

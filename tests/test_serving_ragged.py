"""Ragged-batch serving correctness + continuous-batching scheduler.

The load-bearing property: a mixed-length left-padded ``serve()`` batch (and a
continuous-batching slot pool) must emit token-for-token what each request
would emit solo — per-row rope offsets, pad-key masks, logical-position KV
handoff, SSM pad masking, and per-row ``pos0`` all have to line up for that
to hold across dense, sliding-window, and SSM stacks.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.serving.engine import AdaptiveServer, Request, ServingConfig
from repro.serving.scheduler import ContinuousScheduler


def _build(arch):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


@pytest.fixture(scope="module")
def dense_parts():
    return _build("granite-3-2b")


def _manager():
    stats = [ProfileStats(n, acc, e, 1e-3) for n, acc, e in [
        ("A16-W8", 0.99, 4.0), ("A16-W4", 0.953, 2.0), ("A8-W8", 0.988, 3.0),
        ("A8-W4", 0.953, 1.5), ("A4-W4", 0.958, 1.0), ("Mixed", 0.975, 2.0)]]
    return ProfileManager(stats, accuracy_target=0.985, accuracy_floor=0.90,
                          budget_j=150.0, low_energy=0.5)


# prompt lengths {4, 9, 17} in ONE group: 17 > the smoke sliding window (16),
# so the hymba case exercises the block-skipping SWA prefill path too
MIXED_LENS = (4, 9, 17)


@pytest.mark.parametrize("arch", ["granite-3-2b",   # dense, full attention
                                  "hymba-1.5b",     # hybrid: SWA + SSM
                                  "mamba2-130m"])   # pure SSM
def test_ragged_serve_matches_solo(arch):
    """Mixed-length serve(): every row == its solo run (the seed left-padded
    rows with shifted rope positions, attended to pad keys, and started decode
    at the padded length — all three were wrong)."""
    cfg, params, eng = _build(arch)
    scfg = ServingConfig(slots=64, max_batch=4)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    rng = np.random.default_rng(7)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=6) for n in MIXED_LENS]
    results = srv.serve(reqs)
    solo = AdaptiveServer(cfg, params, eng, scfg)
    for req, res in zip(reqs, results):
        ref = solo.generate(req.tokens[None, :], req.max_new)
        assert res["tokens"] == ref["tokens"][0], \
            f"{arch} len={len(req.tokens)}"


def test_ragged_serve_matches_solo_int8_kv(dense_parts):
    """Ragged handoff also holds for the int8 KV cache: dequant scales must
    calibrate over real tokens only, never the pad junk."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4, kv_bits=8)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    rng = np.random.default_rng(9)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=5) for n in MIXED_LENS]
    results = srv.serve(reqs)
    solo = AdaptiveServer(cfg, params, eng, scfg)
    for req, res in zip(reqs, results):
        ref = solo.generate(req.tokens[None, :], req.max_new)
        assert res["tokens"] == ref["tokens"][0]


def test_profile_trace_sliced_per_request(dense_parts):
    """Each serve() result's trace covers its own max_new, not the group max
    (the seed returned the whole group's trace to every request)."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4),
                         manager=_manager())
    rng = np.random.default_rng(3)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                    max_new=mn) for mn in (7, 2, 4)]
    results = srv.serve(reqs)
    for req, res in zip(reqs, results):
        assert len(res["profile_trace"]) == req.max_new
        assert len(res["tokens"]) == req.max_new


# ---------------------------------------------------------------------------
# continuous-batching scheduler
# ---------------------------------------------------------------------------

def test_continuous_matches_solo(dense_parts):
    """Slot-pool decode with mid-stream refills: every request's tokens equal
    its solo run; results cover every request (incl. a max_new=1 retire-at-
    admission edge case)."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(11)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=mn)
            for n, mn in [(4, 7), (9, 3), (17, 10), (5, 1), (12, 6), (6, 9)]]
    for r in reqs:
        sched.submit(r)
    results = sched.run()
    assert len(results) == len(reqs)
    solo = AdaptiveServer(cfg, params, eng, scfg)
    for req, res in zip(reqs, results):
        ref = solo.generate(req.tokens[None, :], req.max_new)
        assert res["tokens"] == ref["tokens"][0]
        assert len(res["profile_trace"]) == req.max_new


def test_continuous_single_segment_executable(dense_parts):
    """Every decode segment of the scheduler's lifetime — any mix of live,
    retiring, and freshly admitted rows — reuses ONE compiled executable."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng, ServingConfig(slots=64, max_batch=4))
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(2)
    for n, mn in [(4, 9), (9, 2), (6, 5), (5, 12), (8, 3)]:
        sched.submit(Request(tokens=rng.integers(0, cfg.vocab, n)
                             .astype(np.int32), max_new=mn))
    sched.run()
    assert srv._segment._cache_size() == 1


def test_continuous_ledger_matches_stepwise_oracle(dense_parts):
    """Per-segment re-planning with actual live-row counts: replaying the
    scheduler's billing events (admission prefills + per-step live rows)
    through a fresh manager reproduces both the profile choices and the
    exact ledger — the energy accounting a stepwise per-row oracle would do."""
    cfg, params, eng = dense_parts
    mgr = _manager()
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4), manager=mgr)
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(5)
    for n, mn in [(4, 8), (9, 3), (6, 12), (5, 6), (8, 2), (7, 9)]:
        sched.submit(Request(tokens=rng.integers(0, cfg.vocab, n)
                             .astype(np.int32), max_new=mn,
                             accuracy_critical=(mn == 12)))
    sched.run()
    assert mgr.spent_j > 0
    oracle = _manager()
    for pid, n_rows, critical in sched.events:
        assert oracle.select(accuracy_critical=critical) == pid
        oracle.account(pid, n_rows)
    assert abs(oracle.spent_j - mgr.spent_j) < 1e-9


def test_admission_fifo_under_full_pool(dense_parts):
    """With the slot pool full, later submissions queue and are admitted
    strictly FIFO as rows retire."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng, ServingConfig(slots=64, max_batch=2))
    sched = ContinuousScheduler(srv, quantum=2)
    rng = np.random.default_rng(4)
    rids = [sched.submit(Request(tokens=rng.integers(0, cfg.vocab, 5)
                                 .astype(np.int32), max_new=mn))
            for mn in (6, 3, 5, 4, 2)]
    assert sched.admit() == 2                  # pool of 2 fills...
    assert sched.pending == 3                  # ...the rest wait in FIFO
    assert sched.admit() == 0                  # full pool admits nothing
    results = sched.run()
    assert sched.admission_log == rids         # admitted in submission order
    assert sched.pending == 0 and sched.live_rows == 0
    solo = AdaptiveServer(cfg, params, eng, ServingConfig(slots=64, max_batch=2))
    for rid, res in zip(rids, results):
        req = sched._reqs[rid]
        ref = solo.generate(req.tokens[None, :], req.max_new)
        assert res["tokens"] == ref["tokens"][0]


def test_moe_group_bucketing_bounds_executables():
    """MoE serve() buckets group sizes to powers of two: groups of 4 and 3
    share one (4-row) executable instead of compiling per group size; pad
    rows are dropped from the expert-capacity dispatch."""
    cfg, params, eng = _build("qwen2-moe-a2.7b")
    srv = AdaptiveServer(cfg, params, eng, ServingConfig(slots=32, max_batch=4))
    rng = np.random.default_rng(6)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                    max_new=3) for _ in range(7)]       # groups: 4 + 3
    results = srv.serve(reqs)
    assert all(len(r["tokens"]) == 3 for r in results)
    assert srv._prefill._cache_size() == 1
    assert srv._generate._cache_size() == 1

"""Pallas kernels vs pure-jnp oracles (interpret mode; shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec, quantize_native
from repro.kernels import ref
from repro.kernels.ops import qmatmul, qmatmul_qt
from repro.kernels.qkv_attention import qkv_attention_pallas


@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (64, 256, 384),
                                   (5, 100, 70), (1, 512, 256), (33, 96, 40)])
@pytest.mark.parametrize("bits", [8, 4])
def test_qmatmul_matches_oracle(m, k, n, bits):
    key = jax.random.PRNGKey(m * 1000 + n + bits)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.1
    qt = quantize_native(w, QuantSpec(bits=bits, per_channel=True,
                                      channel_axis=-1, po2_scale=False))
    scale = jnp.asarray(qt.scale, jnp.float32).reshape(-1)
    y_ref = ref.qmatmul_ref(x, qt.data, scale, bits)
    y = qmatmul_qt(x, qt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_qmatmul_dtypes(xdtype):
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (16, 128), jnp.float32).astype(xdtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (128, 128)) * 0.1
    qt = quantize_native(w, QuantSpec(bits=8))
    y = qmatmul_qt(x, qt)
    y_ref = ref.qmatmul_ref(x.astype(jnp.float32), qt.data,
                            jnp.asarray(qt.scale).reshape(-1), 8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=3e-2 if xdtype == jnp.bfloat16 else 1e-4)


def test_qmatmul_fused_requant():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (16, 128))
    w = jax.random.normal(jax.random.fold_in(key, 2), (128, 128)) * 0.1
    qt = quantize_native(w, QuantSpec(bits=8))
    scale = jnp.asarray(qt.scale).reshape(-1)
    for out_bits, out_scale in [(8, 0.25), (4, 0.5)]:
        y = qmatmul_qt(x, qt, out_bits=out_bits, out_scale=out_scale)
        y_ref = ref.qmatmul_ref(x, qt.data, scale, 8,
                                out_scale=out_scale, out_bits=out_bits)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
        # output lands on the fixed-point grid
        q = np.asarray(y) / out_scale
        np.testing.assert_allclose(q, np.round(q), atol=1e-4)


def test_qmatmul_batched_and_grad():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, 3, 128))
    w = jax.random.normal(jax.random.fold_in(key, 1), (128, 64)) * 0.1
    qt = quantize_native(w, QuantSpec(bits=8))
    y = qmatmul_qt(x, qt)
    assert y.shape == (2, 3, 64)
    g = jax.grad(lambda x_: qmatmul_qt(x_, qt).sum())(x)
    # dx == dy @ dequant(w).T with dy = 1
    wd = np.asarray(ref.dequant_ref(qt.data, jnp.asarray(qt.scale).reshape(-1), 8))
    np.testing.assert_allclose(np.asarray(g), np.broadcast_to(
        wd.sum(-1), x.shape), rtol=2e-2, atol=1e-3)


@pytest.mark.parametrize("s,block", [(128, 64), (256, 256), (192, 64)])
def test_qkv_attention_matches_oracle(s, block):
    key = jax.random.PRNGKey(s)
    g, hg, d = 3, 2, 32
    q = jax.random.normal(key, (g, hg, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (g, s, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (g, s, d))
    ks = jnp.abs(k).max(axis=(1, 2)) / 127.0
    vs = jnp.abs(v).max(axis=(1, 2)) / 127.0
    kq = jnp.clip(jnp.round(k / ks[:, None, None]), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(v / vs[:, None, None]), -127, 127).astype(jnp.int8)
    lengths = jnp.asarray([s, s // 2, 3], jnp.int32)
    out = qkv_attention_pallas(q, kq, vq, ks, vs, lengths, block_s=block,
                               interpret=True)
    for gi in range(g):
        L = int(lengths[gi])
        kf = jnp.broadcast_to((kq[gi, :L].astype(jnp.float32)
                               * ks[gi])[None, None], (1, hg, L, d))
        vf = jnp.broadcast_to((vq[gi, :L].astype(jnp.float32)
                               * vs[gi])[None, None], (1, hg, L, d))
        o_ref = ref.qkv_attention_ref(q[gi][None, :, None, :], kf, vf,
                                      1.0, 1.0)[0, :, 0, :]
        np.testing.assert_allclose(np.asarray(out[gi]), np.asarray(o_ref),
                                   atol=1e-4)


@pytest.mark.parametrize("m,n,bits,po2", [(64, 128, 8, True), (100, 64, 4, True),
                                          (257, 96, 8, False), (8, 32, 2, True)])
def test_aquant_matches_fake_quant(m, n, bits, po2):
    """Fused activation-quant kernel == fake_quant numerics (bit-exact)."""
    from repro.kernels.aquant import aquant_pallas
    x = jax.random.normal(jax.random.PRNGKey(m + n), (m, n), jnp.float32) * 3.7
    y = aquant_pallas(x, bits=bits, po2=po2, block_rows=64, interpret=True)
    y_ref = ref.aquant_ref(x, bits=bits, po2=po2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-6)


def test_aquant_idempotent_and_grid():
    from repro.kernels.aquant import aquant_pallas
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    y = aquant_pallas(x, bits=6, interpret=True)
    y2 = aquant_pallas(y, bits=6, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-6)
    # output values land on at most 2^bits distinct levels
    assert len(np.unique(np.asarray(y))) <= 2 ** 6

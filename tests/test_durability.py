"""Crash-consistent serving: journal, checkpoints, token-identical restart.

The load-bearing properties of the durability layer (docs/serving.md
§Durability, invariant 12 — *no accepted request is lost by a restart*):

* **kill-at-every-flush-boundary recovery is token-identical**: for every
  round boundary of a mixed workload (priority classes, speculation,
  chunked prefill, CoW shared prefixes), abandoning the process there and
  recovering from journal + newest checkpoint delivers exactly the token
  streams of an uninterrupted twin, at kv16 and kv8, with statuses
  terminal, the allocator audit clean and zero leaked blocks;
* **the journal is crash-consistent**: a torn tail (partial last line,
  bad checksum) is truncated on reopen and ignored by ``scan``, and the
  write-ahead submit record alone — no checkpoint at all — is enough to
  recover every accepted request;
* **corruption degrades, never loses**: a checkpoint leaf that fails its
  manifest checksum drops only the affected row to re-prefill-from-prompt
  (``recover_info["refilled"]``) — the request still completes with the
  exact twin stream;
* **the energy ledger survives restart**: replaying the recovered
  scheduler's event log through a fresh ProfileManager reproduces the
  ledger, and total billed inferences ≡ delivered tokens;
* **graceful drain** finishes live rows without admitting new ones,
  leaves queued requests queued, and a cold restart completes them;
* the pool-lifetime single-``_segment``-executable and ≤2-prefill-waves
  invariants hold across the restart (SchedulerAudit-guarded).
"""
import os

import jax
import numpy as np
import pytest

from repro.analysis.tracker import SchedulerAudit
from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.serving.durability import Durability, RequestJournal, recover
from repro.serving.engine import (AdaptiveServer, Request, RequestStatus,
                                  ServingConfig)
from repro.serving.scheduler import ContinuousScheduler


def _build(arch="granite-3-2b"):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


@pytest.fixture(scope="module")
def dense_parts():
    return _build()


@pytest.fixture(scope="module")
def spec16(dense_parts):
    """kv16 + speculation + CoW prefix sharing (plain pool-as-master)."""
    cfg, params, eng = dense_parts
    return AdaptiveServer(cfg, params, eng,
                          ServingConfig(slots=64, max_batch=4, block_size=8,
                                        pool_blocks=64, priority_classes=2,
                                        speculate=True, draft_k=2))


@pytest.fixture(scope="module")
def chunk8(dense_parts):
    """kv8 + chunked prefill + CoW prefix sharing (int-KV masters)."""
    cfg, params, eng = dense_parts
    return AdaptiveServer(cfg, params, eng,
                          ServingConfig(slots=64, max_batch=4, block_size=8,
                                        pool_blocks=64, priority_classes=2,
                                        kv_bits=8, prefill_chunk=16))


def _manager():
    stats = [ProfileStats(n, acc, e, 1e-3) for n, acc, e in [
        ("A16-W8", 0.99, 4.0), ("A16-W4", 0.953, 2.0), ("A8-W8", 0.988, 3.0),
        ("A8-W4", 0.953, 1.5), ("A4-W4", 0.958, 1.0), ("Mixed", 0.975, 2.0)]]
    return ProfileManager(stats, accuracy_target=0.985, accuracy_floor=0.90,
                          budget_j=150.0, low_energy=0.5)


def _workload(cfg, seed=0):
    """Mixed classes + a 16-token shared system prefix (CoW, two block-
    aligned sharers) + one 40-token prompt (chunks at prefill_chunk=16)."""
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    mk = lambda n: rng.integers(0, cfg.vocab, n).astype(np.int32)
    return [
        Request(tokens=np.concatenate([sys_p, mk(5)]), max_new=6, priority=1),
        Request(tokens=np.concatenate([sys_p, mk(7)]), max_new=5, priority=0),
        Request(tokens=mk(40), max_new=4, priority=1),
        Request(tokens=mk(6), max_new=8, priority=0),
        Request(tokens=mk(9), max_new=6, priority=1),
        Request(tokens=mk(5), max_new=4, priority=0),
    ]


def _pattern(sched, reqs, stop_after=None):
    """The canonical client pattern: four requests up front, the rest
    arrive after round 1. Returns rounds stepped (or stops early to
    simulate a crash at the ``stop_after``-th flush boundary)."""
    for r in reqs[:4]:
        sched.submit(r)
    steps = 0
    while True:
        if stop_after is not None and steps == stop_after:
            return steps
        more = sched.step()
        steps += 1
        if steps == 1 and len(reqs) > 4:
            for r in reqs[4:]:
                sched.submit(r)
            more = True
        if not more:
            return steps


def _finish(sched, reqs):
    """Drive a recovered scheduler to completion, re-submitting any late
    arrivals the crash predates (rids are dense: ``_n`` counts accepted
    submissions, so ``reqs[_n:]`` is exactly the unjournaled tail)."""
    if sched._n < len(reqs):
        for r in reqs[sched._n:]:
            sched.submit(r)
    while sched.step():
        pass


def _assert_identical(sched, reqs, twin):
    for rid in range(len(reqs)):
        got = sched.results[rid]
        assert got["status"] is RequestStatus.COMPLETED, (rid, got)
        assert [int(x) for x in got["tokens"]] == \
               [int(x) for x in twin[rid]["tokens"]], rid
    sched.check()
    assert sched.allocator.used_blocks == 0


# ---------------------------------------------------------------------------
# journal unit tests (pure host, no model)
# ---------------------------------------------------------------------------

def test_journal_torn_tail_truncated_on_reopen(tmp_path):
    """A crash mid-write leaves a torn tail; scan stops at it and reopen
    truncates it, so the next append produces a clean suffix."""
    p = str(tmp_path / "journal.jsonl")
    j = RequestJournal(p)
    j.append({"t": "submit", "rid": 0}, sync=True)
    j.append({"t": "final", "rid": 0})
    j.close()
    with open(p, "ab") as f:
        f.write(b'deadbeef {"t": "gar')          # no newline: torn
    assert [r["t"] for _, r in RequestJournal.scan(p)] == ["submit", "final"]
    j2 = RequestJournal(p)                       # reopen truncates the tail
    j2.append({"t": "cancel", "rid": 0})
    j2.close()
    recs = RequestJournal.scan(p)
    assert [r["t"] for _, r in recs] == ["submit", "final", "cancel"]
    assert recs[-1][0] == os.path.getsize(p)     # byte-exact valid prefix


def test_journal_checksum_gates_suffix(tmp_path):
    """A bit-flip in a middle record invalidates it AND everything after —
    scan returns only the intact prefix (no resynchronization guessing)."""
    p = str(tmp_path / "journal.jsonl")
    j = RequestJournal(p)
    for rid in range(3):
        j.append({"t": "submit", "rid": rid})
    j.close()
    raw = open(p, "rb").read().splitlines(keepends=True)
    raw[1] = raw[1][:12] + b"X" + raw[1][13:]    # corrupt record 1's payload
    with open(p, "wb") as f:
        f.writelines(raw)
    recs = RequestJournal.scan(p)
    assert [r["rid"] for _, r in recs] == [0]


# ---------------------------------------------------------------------------
# the tentpole: kill + restore at EVERY flush boundary, kv16 and kv8
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("which", ["kv16-spec", "kv8-chunked"])
def test_crash_restart_token_identity_every_boundary(which, spec16, chunk8,
                                                     tmp_path):
    """For every round boundary k of the workload, abandon the scheduler
    after k rounds (checkpoint_every=1: the newest checkpoint IS that
    boundary's cut) and recover into a fresh scheduler over the same
    server. Delivered streams ≡ the uninterrupted twin, per request, and
    the pool drains clean. k=0 exercises journal-only recovery (no
    checkpoint committed yet); the midpoint trial additionally audits the
    single-segment and ≤2-prefill-waves invariants after restart."""
    srv = spec16 if which == "kv16-spec" else chunk8
    reqs = _workload(srv.cfg)
    tw = ContinuousScheduler(srv, quantum=4)
    rounds = _pattern(tw, reqs)
    twin = [tw.results[i] for i in range(len(reqs))]
    assert rounds >= 3                            # matrix is non-trivial

    for k in range(rounds):
        jd = str(tmp_path / f"{which}-k{k}")
        s1 = ContinuousScheduler(srv, quantum=4)
        Durability(s1, jd, checkpoint_every=1)
        _pattern(s1, reqs, stop_after=k)          # CRASH: abandon s1
        s2 = recover(srv, jd, checkpoint_every=1, quantum=4)
        assert s2.recover_info["recovery_s"] >= 0.0
        if k == rounds // 2:
            with SchedulerAudit(s2) as audit:
                _finish(s2, reqs)
            audit.assert_single_segment()
            audit.assert_max_prefill_waves(2)
        else:
            _finish(s2, reqs)
        _assert_identical(s2, reqs, twin)


def test_journal_only_recovery_no_checkpoint(spec16, tmp_path):
    """checkpoint_every=0: the write-ahead submit records alone recover
    every accepted request (invariant 12 needs no checkpoint — a
    checkpoint only bounds recovery recompute)."""
    srv = spec16
    reqs = _workload(srv.cfg, seed=3)
    tw = ContinuousScheduler(srv, quantum=4)
    _pattern(tw, reqs)
    twin = [tw.results[i] for i in range(len(reqs))]

    jd = str(tmp_path / "jd")
    s1 = ContinuousScheduler(srv, quantum=4)
    Durability(s1, jd)                            # journal only, no cadence
    _pattern(s1, reqs, stop_after=3)              # CRASH mid-flight
    s2 = recover(srv, jd, quantum=4)
    # everything restarts from the prompt: nothing resumed, nothing lost
    assert s2.recover_info["resumed_rows"] == 0
    assert s2._n >= 4
    _finish(s2, reqs)
    _assert_identical(s2, reqs, twin)


def test_recover_is_idempotent_on_recrash(chunk8, tmp_path):
    """Crashing again immediately after recovery (before any new round)
    recovers to the same state: the fresh checkpoint recover() writes
    makes a re-crash a no-op, not a replay storm."""
    srv = chunk8
    reqs = _workload(srv.cfg, seed=5)
    tw = ContinuousScheduler(srv, quantum=4)
    _pattern(tw, reqs)
    twin = [tw.results[i] for i in range(len(reqs))]

    jd = str(tmp_path / "jd")
    s1 = ContinuousScheduler(srv, quantum=4)
    Durability(s1, jd, checkpoint_every=1)
    _pattern(s1, reqs, stop_after=2)              # crash #1
    recover(srv, jd, checkpoint_every=1, quantum=4)   # crash #2: abandon too
    s3 = recover(srv, jd, checkpoint_every=1, quantum=4)
    assert s3.recover_info["replayed"] == 0       # nothing past the cut
    _finish(s3, reqs)
    _assert_identical(s3, reqs, twin)


# ---------------------------------------------------------------------------
# corruption: checksum failure degrades to re-prefill, never loses
# ---------------------------------------------------------------------------

def test_corrupted_snapshot_refills_from_prompt(spec16, tmp_path):
    """Flip a live row's master-K leaf inside the newest checkpoint. The
    manifest checksum catches it, recovery drops ONLY that row to
    re-prefill-from-prompt (recover_info["refilled"]) and the request
    still completes with the exact twin stream."""
    srv = spec16
    reqs = _workload(srv.cfg, seed=7)
    tw = ContinuousScheduler(srv, quantum=4)
    rounds = _pattern(tw, reqs)
    twin = [tw.results[i] for i in range(len(reqs))]

    for k in range(2, rounds):
        jd = str(tmp_path / f"k{k}")
        s1 = ContinuousScheduler(srv, quantum=4)
        Durability(s1, jd, checkpoint_every=1)
        _pattern(s1, reqs, stop_after=k)
        step = s1.durable.manager.latest_step()
        sdir = os.path.join(jd, "checkpoints", f"step_{step:09d}")
        with np.load(os.path.join(sdir, "arrays.npz")) as z:
            flat = {n: z[n] for n in z.files}
        victims = [n for n in flat if n.startswith("rows/")
                   and n.endswith("/mk")]
        if not victims:
            continue                              # no live row at this cut
        flat[victims[0]] = flat[victims[0]] + 1.0    # silent bit-rot
        np.savez(os.path.join(sdir, "arrays.npz"), **flat)

        s2 = recover(srv, jd, checkpoint_every=1, quantum=4)
        rid = int(victims[0].split("/")[1])
        assert rid in s2.recover_info["refilled"]
        assert s2.recover_info["corrupt_keys"]
        _finish(s2, reqs)
        _assert_identical(s2, reqs, twin)
        return
    pytest.fail("no crash point left a live row in the checkpoint")


# ---------------------------------------------------------------------------
# ledger: billed ≡ delivered through the restart
# ---------------------------------------------------------------------------

def test_billed_equals_delivered_through_restart(dense_parts, tmp_path):
    """The manager ledger is part of the cut: after recovery, replaying
    the (restored + re-run) event log through a fresh ProfileManager
    reproduces profiles and spend exactly, and total billed inferences
    equal total delivered tokens — the re-run rounds re-bill precisely
    what the discarded post-cut rounds had billed."""
    cfg, params, eng = dense_parts
    mgr = _manager()
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=2, block_size=8,
                                       priority_classes=2), manager=mgr)
    reqs = _workload(cfg, seed=11)[:4]
    jd = str(tmp_path / "jd")
    s1 = ContinuousScheduler(srv, quantum=3)
    Durability(s1, jd, checkpoint_every=1)
    _pattern(s1, reqs, stop_after=2)              # CRASH past one ledger cut
    s2 = recover(srv, jd, checkpoint_every=1, quantum=3)
    _finish(s2, reqs)
    for rid, req in enumerate(reqs):
        assert s2.results[rid]["status"] is RequestStatus.COMPLETED
        assert len(s2.results[rid]["tokens"]) == req.max_new
    oracle = _manager()
    for pid, n_rows, critical in s2.events:
        assert oracle.select(accuracy_critical=critical) == pid
        oracle.account(pid, n_rows)
    assert abs(oracle.spent_j - mgr.spent_j) < 1e-9
    assert sum(n for _, n, _ in s2.events) == sum(r.max_new for r in reqs)
    s2.check()


# ---------------------------------------------------------------------------
# graceful drain + cold restart
# ---------------------------------------------------------------------------

def test_drain_finishes_live_keeps_queued_restart_completes(spec16, tmp_path):
    """drain() stops admitting, runs live rows to completion, and leaves
    queued requests queued; a final checkpoint + cold restart completes
    them token-identically (the SIGTERM path in launch/serve.py)."""
    srv = spec16
    reqs = _workload(srv.cfg, seed=13)[:5]        # max_batch=4: one queues
    tw = ContinuousScheduler(srv, quantum=4)
    for r in reqs:
        tw.submit(r)
    tw.run()
    twin = [tw.results[i] for i in range(len(reqs))]

    jd = str(tmp_path / "jd")
    s1 = ContinuousScheduler(srv, quantum=4)
    dur = Durability(s1, jd, checkpoint_every=2)
    for r in reqs:
        s1.submit(r)
    s1.step()
    s1.drain()
    assert s1.live_rows == 0 and not s1._inflight
    n_done = sum(1 for rid in range(len(reqs))
                 if s1.results.get(rid, {}).get("status")
                 is RequestStatus.COMPLETED)
    assert n_done == 4 and s1.pending == 1        # queued request survives
    dur.checkpoint()                              # shutdown cut

    s2 = recover(srv, jd, checkpoint_every=2, quantum=4)
    assert s2.pending == 1 and not s2.draining    # drain doesn't persist
    _finish(s2, reqs)
    _assert_identical(s2, reqs, twin)


# ---------------------------------------------------------------------------
# kv16 f32 masters (ServingConfig.kv16_masters)
# ---------------------------------------------------------------------------

def test_kv16_masters_registry_and_crash_identity(dense_parts, tmp_path):
    """kv16_masters=True keeps f32 masters alongside shared blocks: the
    registry carries both (structural bit-exactness for every
    continuation), streams match the plain-kv16 server exactly, and a
    crash/recover cycle restores shared prefixes from the masters."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4, block_size=8,
                                       pool_blocks=64, kv16_masters=True))
    assert srv.masters_mode and srv._collect_masters
    reqs = _workload(cfg, seed=17)[:4]
    tw = ContinuousScheduler(srv, quantum=4)
    rounds = _pattern(tw, reqs)
    twin = [tw.results[i] for i in range(len(reqs))]
    assert any(e.master_k is not None and e.block_ids is not None
               for e in tw.registry._entries.values())

    jd = str(tmp_path / "jd")
    s1 = ContinuousScheduler(srv, quantum=4)
    Durability(s1, jd, checkpoint_every=1)
    _pattern(s1, reqs, stop_after=max(2, rounds // 2))
    s2 = recover(srv, jd, checkpoint_every=1, quantum=4)
    _finish(s2, reqs)
    _assert_identical(s2, reqs, twin)

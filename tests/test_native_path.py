"""Native integer-carrier deployment path (serving): structure + numerics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.profiles import Profile, profile_table
from repro.core.quantizers import QTensor
from repro.models import transformer as T
from repro.models.native import to_native


@pytest.mark.parametrize("arch", ["granite-3-2b", "deepseek-moe-16b"])
@pytest.mark.parametrize("w_bits", [8, 4])
def test_to_native_structure(arch, w_bits):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    nat = to_native(params, w_bits)
    # linears converted, norms untouched
    assert isinstance(nat["layers"]["qkv"]["wq"], QTensor)
    assert "w" not in nat["layers"]["qkv"]
    assert "g" in nat["layers"]["norm_attn"]
    # stacked leaves keep the layer dim (scan compatibility)
    L = cfg.n_layers
    assert nat["layers"]["qkv"]["wq"].data.shape[0] == L
    assert nat["layers"]["qkv"]["wq"].scale.shape[0] == L
    if cfg.moe is not None:
        assert isinstance(nat["layers"]["moe"]["w_in"], QTensor)
    # int4 packs two per byte on the last dim
    if w_bits == 4:
        w = params["layers"]["qkv"]["w"]
        assert nat["layers"]["qkv"]["wq"].data.shape[-1] == w.shape[-1] // 2


@pytest.mark.parametrize("arch", ["granite-3-2b", "mamba2-130m"])
def test_native_decode_close_to_fake(arch):
    """W8 native decode ≈ the fake-quant path (different scale granularity:
    per-channel float vs per-tensor po2 → loose tolerance, same argmax)."""
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    names = T.quant_layer_names(cfg)
    # activations float, weights 8-bit → isolates the weight path
    prof = Profile("A32-W8", {n: (32, 8) for n in names})
    br = profile_table([prof], names)[0]
    nat = to_native(params, 8)
    B = 2
    caches_f = T.init_caches(cfg, B, 16, kv_bits=32)
    caches_n = T.init_caches(cfg, B, 16, kv_bits=32)
    toks = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    pos = jnp.zeros((B,), jnp.int32)
    lg_f, _ = T.decode_step(params, cfg, br, toks, pos, caches_f)
    lg_n, _ = T.decode_step(nat, cfg, br, toks, pos, caches_n)
    rel = (float(jnp.max(jnp.abs(lg_n - lg_f)))
           / max(1e-9, float(jnp.max(jnp.abs(lg_f)))))
    assert rel < 0.15, rel
    assert (np.argmax(np.asarray(lg_n), -1) == np.argmax(np.asarray(lg_f), -1)).mean() >= 0.5


def test_native_forward_runs_all_families():
    for arch in ["qwen2-vl-2b", "hymba-1.5b", "hubert-xlarge"]:
        cfg = get_smoke(arch)
        key = jax.random.PRNGKey(2)
        params = to_native(T.init_params(cfg, key), 8)
        names = T.quant_layer_names(cfg)
        br = profile_table([Profile.float32(names)], names)[0]
        B, S = 2, 32
        if cfg.frontend == "audio":
            batch = {"features": jax.random.normal(key, (B, S, cfg.feature_dim))}
        elif cfg.frontend == "vision":
            batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
                     "patch_embeds": jax.random.normal(
                         key, (B, cfg.n_patches, cfg.d_model))}
        else:
            batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
        h, _, _ = T.forward(params, cfg, br, batch)
        assert np.isfinite(np.asarray(h)).all(), arch

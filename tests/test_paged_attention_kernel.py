"""In-place Pallas paged-attention kernel + chunked prefill.

The load-bearing properties of the serving hot-path rewrite:

* the kernel (interpret mode) matches the gather-view ``decode_attention``
  oracle to float precision at kv16 and kv8, across block-boundary cache
  lengths, fragmented/out-of-order block tables, and dead rows (both the
  ``-1`` and the ``>= n_blocks`` unmapped sentinels);
* the ``pallas`` segment backend is token-identical to the ``gather``
  backend / solo generation at kv16 and kv8 — including shared-prefix
  copy-on-write rows — while materializing **no** ``[B, n_lblk*bs]`` view
  and no exit fold-back (guarded at the dispatch level and in the jaxpr);
* chunked prefill emits exactly the tokens of an unchunked admission.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.budgets import SegmentBudget, trace_segment
from repro.analysis.jaxpr_check import has_adjacent_dims
from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.profiles import paper_profiles
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention_pallas
from repro.models import transformer as T
from repro.serving.engine import AdaptiveServer, Request, ServingConfig
from repro.serving.scheduler import ContinuousScheduler


def _build(arch="granite-3-2b"):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


@pytest.fixture(scope="module")
def dense_parts():
    return _build()


def _solo_tokens(parts, req, kv_bits=16, slots=64):
    cfg, params, eng = parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=slots, max_batch=4,
                                       kv_bits=kv_bits))
    return srv.generate(req.tokens[None, :], req.max_new)["tokens"][0]


# ---------------------------------------------------------------------------
# kernel vs gather-view oracle (interpret mode)
# ---------------------------------------------------------------------------

def _pool_case(seed, lengths, *, n_blocks=16, bs=8, n_lblk=4, hkv=2, hg=2,
               d=16, kv_bits=16, dead_sentinels=()):
    """Fragmented paged state: per-row out-of-order physical blocks, cache
    lengths straddling block boundaries, optional dead rows whose tables
    hold only unmapped sentinels."""
    rng = np.random.default_rng(seed)
    b = len(lengths) + len(dead_sentinels)
    q = jnp.asarray(rng.normal(size=(b, hkv, hg, d)), jnp.float32)
    if kv_bits == 8:
        kp = jnp.asarray(rng.integers(-127, 128, (n_blocks, bs, hkv, d)),
                         jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, (n_blocks, bs, hkv, d)),
                         jnp.int8)
        ks = jnp.asarray(rng.uniform(0.01, 0.1, (b, hkv)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.01, 0.1, (b, hkv)), jnp.float32)
    else:
        kp = jnp.asarray(rng.normal(size=(n_blocks, bs, hkv, d)),
                         jnp.float32).astype(jnp.bfloat16)
        vp = jnp.asarray(rng.normal(size=(n_blocks, bs, hkv, d)),
                         jnp.float32).astype(jnp.bfloat16)
        ks = vs = jnp.ones((b, hkv), jnp.float32)
    # fragmented, out-of-order physical placement (one block per row+lblk)
    perm = rng.permutation(n_blocks)
    tidx = np.full((n_blocks, bs), -1, np.int32)
    bt = np.full((b, n_lblk), n_blocks, np.int32)
    pos = np.zeros((b,), np.int32)
    nxt = 0
    for r, ln in enumerate(lengths):
        pos[r] = ln - 1                       # current token = last written
        for lb in range(-(-ln // bs)):
            p = int(perm[nxt]); nxt += 1
            bt[r, lb] = p
            nv = min(ln - lb * bs, bs)
            tidx[p, :nv] = lb * bs + np.arange(nv)
    for i, sent in enumerate(dead_sentinels):
        bt[len(lengths) + i, :] = sent        # -1 or n_blocks: both unmapped
    return (q, kp, vp, ks, vs, jnp.asarray(tidx), jnp.asarray(bt),
            jnp.asarray(pos))


@pytest.mark.parametrize("kv_bits", [16, 8])
def test_kernel_matches_gather_oracle(kv_bits):
    """Block-boundary lengths 7/8/9/16/17 through fragmented out-of-order
    tables + two dead rows (−1 and ≥ n_blocks sentinels): the kernel's
    output equals the gather-view oracle to float precision, and dead rows
    flush exact zeros on both paths."""
    case = _pool_case(3, (7, 8, 9, 16, 17), n_blocks=24, kv_bits=kv_bits,
                      dead_sentinels=(-1, 24))
    out_k = paged_attention_pallas(*case, bits=kv_bits, interpret=True)
    out_r = ref.paged_attention_ref(*case, bits=kv_bits)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-5, rtol=1e-5)
    assert np.all(np.asarray(out_k)[-2:] == 0)      # dead rows: exact zeros
    assert np.all(np.asarray(out_r)[-2:] == 0)


def test_kernel_windowed_matches_oracle():
    """Sliding-window masking (ring semantics via token_idx) agrees."""
    case = _pool_case(11, (9, 17, 23), n_blocks=16, kv_bits=16)
    out_k = paged_attention_pallas(*case, bits=16, window=8, interpret=True)
    out_r = ref.paged_attention_ref(*case, bits=16, window=8)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# pallas segment backend: token identity + no-view guard
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits", [16, 8])
def test_pallas_backend_token_identity(dense_parts, kv_bits):
    """The in-place kernel backend emits exactly the gather/solo tokens for
    prompts straddling block boundaries, at bf16 and int8 KV."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4, kv_bits=kv_bits,
                         block_size=8, paged_backend="pallas")
    srv = AdaptiveServer(cfg, params, eng, scfg)
    assert srv.paged_backend == "pallas"
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(13)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=mn)
            for n, mn in [(7, 6), (9, 5), (17, 6)]]
    for r in reqs:
        sched.submit(r)
    results = sched.run()
    for req, res in zip(reqs, results):
        assert res["tokens"] == _solo_tokens(dense_parts, req, kv_bits)


def test_pallas_backend_shared_cow_identity(dense_parts):
    """Shared-prefix CoW rows decode through the kernel against blocks they
    map but must never write: both sharers match solo and the shared
    blocks' bytes are untouched."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4, block_size=8,
                         paged_backend="pallas")
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=2)
    rng = np.random.default_rng(29)
    sys_prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    r1 = Request(tokens=np.concatenate(
        [sys_prompt, rng.integers(0, cfg.vocab, 4).astype(np.int32)]),
        max_new=8)
    r2 = Request(tokens=np.concatenate(
        [sys_prompt, rng.integers(0, cfg.vocab, 7).astype(np.int32)]),
        max_new=6)
    sched.submit(r1)
    sched.step()                              # r1 admitted cold + registered
    entry = max(sched.registry._entries.values(), key=lambda e: e.n_tokens)
    bids = np.asarray(entry.block_ids)
    pool = sched._caches["kv"]
    snap_k = np.asarray(pool.k[:, bids]).copy()
    sched.submit(r2)                          # shares while r1 is still live
    while sched.step():
        pass
    assert sched.registry.hits == 1
    pool = sched._caches["kv"]
    assert np.array_equal(np.asarray(pool.k[:, bids]), snap_k)
    results = sched.run()
    for req, res in zip((r1, r2), results):
        assert res["tokens"] == _solo_tokens(dense_parts, req)


_VIEW_BUDGET = SegmentBudget(
    name="test-no-view", arch="granite-3-2b", batch=3, slots=40,
    block_size=8, pool_blocks=None, kv_bits=16, steps=4,
    max_aval_bytes=10 ** 9)


def test_segment_pallas_no_view_materialization(dense_parts, monkeypatch):
    """Dispatch + jaxpr guard for the acceptance criterion: the pallas
    segment executable contains NO ``[B, n_lblk*bs]`` view materialization
    or exit fold-back. ``paged_view`` is never even traced, and no
    intermediate in the jaxpr carries the dense-view shape — while the
    gather backend (the oracle) demonstrably produces both, proving the
    guard detects what it claims to. Enforced via the named ``analysis``
    invariant ``no-gather-view`` (budgets.trace_segment +
    jaxpr_check.has_adjacent_dims)."""
    import repro.models.transformer as TT
    calls = {"n": 0}
    orig = TT.paged_view

    def counting(cache):
        calls["n"] += 1
        return orig(cache)

    monkeypatch.setattr(TT, "paged_view", counting)
    dims = (_VIEW_BUDGET.batch, _VIEW_BUDGET.slots_padded)
    jaxpr_p = trace_segment(dense_parts, "pallas", _VIEW_BUDGET)
    assert calls["n"] == 0                      # never dispatched
    assert not has_adjacent_dims(jaxpr_p, dims)

    jaxpr_g = trace_segment(dense_parts, "gather", _VIEW_BUDGET)
    assert calls["n"] > 0                       # oracle path gathers
    assert has_adjacent_dims(jaxpr_g, dims)


# ---------------------------------------------------------------------------
# intra-wave prefix dedup
# ---------------------------------------------------------------------------

def test_intra_wave_prefix_dedup(dense_parts):
    """Two identical prompts admitted in the SAME cold wave: the second
    defers its lookup past the wave that registers the prefix and rides
    the shared path (registry hit) instead of prefilling the prefix again
    — and both still match solo generation exactly."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4, block_size=8)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(5)
    sys_p = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    r1 = Request(tokens=np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab, 4).astype(np.int32)]), max_new=6)
    r2 = Request(tokens=r1.tokens.copy(), max_new=6)        # identical
    r3 = Request(tokens=np.concatenate(                     # same sys prefix
        [sys_p, rng.integers(0, cfg.vocab, 3).astype(np.int32)]), max_new=5)
    for r in (r1, r2, r3):
        sched.submit(r)
    assert sched.admit() == 3                 # ONE round admits all three
    assert sched.registry.hits == 2           # r2 and r3 both deduped
    results = sched.run()
    for req, res in zip((r1, r2, r3), results):
        assert res["tokens"] == _solo_tokens(dense_parts, req)


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits", [16, 8])
def test_chunked_prefill_token_identity(dense_parts, kv_bits):
    """Long prompts admitted in block-aligned chunks (interleaved with
    decode segments) emit exactly the unchunked-admission tokens — at kv8
    the accumulated-amax recalibration reproduces the cold scale."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4, kv_bits=kv_bits,
                         block_size=8, prefill_chunk=16)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    assert srv.chunk_tokens == 16
    sched = ContinuousScheduler(srv, quantum=2)
    rng = np.random.default_rng(41)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=mn)
            for n, mn in [(40, 5), (33, 4), (6, 3)]]   # 2 chunked, 1 short
    for r in reqs:
        sched.submit(r)
    sched.admit()
    assert len(sched._chunk_state) == 2          # long prompts mid-admission
    results = sched.run()
    assert not sched._chunk_state
    for req, res in zip(reqs, results):
        assert res["tokens"] == _solo_tokens(dense_parts, req, kv_bits)


def test_chunked_prefill_interleaves_decode(dense_parts):
    """While a long prompt chunks in, already-live rows keep emitting: the
    short request completes before the chunked one's admission finishes —
    the admission-wave stall the feature removes."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=96, max_batch=4, block_size=8,
                         prefill_chunk=16)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=2)
    rng = np.random.default_rng(7)
    short = Request(tokens=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=4)
    long = Request(tokens=rng.integers(0, cfg.vocab, 80).astype(np.int32),
                   max_new=4)
    sched.submit(short)
    sched.submit(long)
    sched.step()                 # both admitted: short live, long chunk 1/5
    assert sched._chunk_state and sched.live_rows == 1
    while sched._chunk_state:
        sched.step()
    done = [rid for rid, _ in sched.poll_completed()]
    assert 0 in done             # short finished while long was still chunking
    results = sched.run()
    assert len(results[1]["tokens"]) == long.max_new
    assert results[1]["tokens"] == _solo_tokens(dense_parts, long, slots=96)

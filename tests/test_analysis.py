"""The analyzer analyzed: lint rules, jaxpr budgets, trackers, CI canary.

Each lint rule gets a minimal positive (fires) and negative (clean) source
pair; the jaxpr checks get toy jitted functions on both sides of their
ceilings; and the seeded-violation fixtures prove the ``check_static``
gate exits non-zero for both the lint and the budget violation classes.
"""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_check
from repro.analysis.budgets import REFERENCE_BUDGETS, check_budget, trace_segment
from repro.analysis.lint import ALL_HOT, lint_source
from repro.analysis.tracker import DispatchAudit

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "static_analysis"

sys.path.insert(0, str(REPO / "scripts"))
import check_static  # noqa: E402


def _rules(src: str) -> set[str]:
    return {f.rule for f in lint_source(src, "probe.py", ALL_HOT)}


# ---------------------------------------------------------------------------
# lint rules: positive / negative per rule
# ---------------------------------------------------------------------------

def test_host_sync_item():
    assert "host-sync" in _rules(
        "import jax.numpy as jnp\n"
        "def f(tok):\n"
        "    return jnp.sum(tok).item()\n")


def test_host_sync_coercion_on_device_value():
    assert "host-sync" in _rules(
        "import jax.numpy as jnp\n"
        "def f():\n"
        "    x = jnp.ones((4,))\n"
        "    return float(x.sum())\n")


def test_host_sync_np_asarray_of_jnp():
    assert "host-sync" in _rules(
        "import jax.numpy as jnp\nimport numpy as np\n"
        "def f():\n"
        "    x = jnp.ones((4,))\n"
        "    return np.asarray(x)\n")


def test_host_sync_block_until_ready():
    assert "host-sync" in _rules(
        "import jax.numpy as jnp\n"
        "def f():\n"
        "    jnp.ones((4,)).block_until_ready()\n")


def test_host_sync_negative_pure_host():
    # numpy-only code never fires: no device taint anywhere.
    assert _rules(
        "import numpy as np\n"
        "def f(xs):\n"
        "    a = np.asarray(xs)\n"
        "    return float(a.sum()), int(a.max())\n") == set()


def test_host_sync_negative_materialized_then_coerced():
    # np.asarray(device) fires once; int() on the HOST copy must not
    # double-report.
    src = ("import jax.numpy as jnp\nimport numpy as np\n"
           "def f():\n"
           "    x = jnp.ones((4,))\n"
           "    a = np.asarray(x)\n"
           "    return int(a[0])\n")
    findings = lint_source(src, "probe.py", ALL_HOT)
    assert [f.rule for f in findings] == ["host-sync"]


def test_missing_donate_fires_and_fixed_negative():
    pos = ("import jax\n"
           "def step(params, caches):\n"
           "    return params, caches\n"
           "step_jit = jax.jit(step)\n")
    neg = ("import jax\n"
           "def step(params, caches):\n"
           "    return params, caches\n"
           "step_jit = jax.jit(step, donate_argnums=(1,))\n")
    assert "missing-donate" in _rules(pos)
    assert "missing-donate" not in _rules(neg)


def test_tracer_branch_fires_and_negative():
    pos = ("import jax\n"
           "def f(flag, x):\n"
           "    if flag:\n"
           "        return x\n"
           "    return x + 1\n"
           "g = jax.jit(f)\n")
    # Same branch in a NON-jitted function: host code may branch freely.
    neg = ("def f(flag, x):\n"
           "    if flag:\n"
           "        return x\n"
           "    return x + 1\n")
    assert "tracer-branch" in _rules(pos)
    assert "tracer-branch" not in _rules(neg)


def test_late_closure_fires_and_negative():
    pos = ("def outer():\n"
           "    def inner(x):\n"
           "        return x + scale\n"
           "    scale = 3.0\n"
           "    return inner\n")
    neg = ("def outer():\n"
           "    scale = 3.0\n"
           "    def inner(x):\n"
           "        return x + scale\n"
           "    return inner\n")
    assert "late-closure" in _rules(pos)
    assert "late-closure" not in _rules(neg)


def test_device_constant_fires_and_small_negative():
    pos = ("import jax.numpy as jnp\n"
           "def f(x):\n"
           "    return x + jnp.array([0.0] * 64)\n")
    neg = ("import jax.numpy as jnp\n"
           "def f(x):\n"
           "    return x + jnp.array([0.0, 1.0, 2.0])\n")
    assert "device-constant" in _rules(pos)
    assert "device-constant" not in _rules(neg)


# ---------------------------------------------------------------------------
# allow pragmas: same line, line above, enclosing def
# ---------------------------------------------------------------------------

def test_allow_same_line_and_line_above():
    same = ("import jax.numpy as jnp\n"
            "def f():\n"
            "    x = jnp.ones((4,))\n"
            "    return x.sum().item()  # repro: allow(host-sync) reduced\n")
    above = ("import jax.numpy as jnp\n"
             "def f():\n"
             "    x = jnp.ones((4,))\n"
             "    # repro: allow(host-sync) reduced scalar, sync intended\n"
             "    return x.sum().item()\n")
    assert _rules(same) == set()
    assert _rules(above) == set()


def test_allow_on_def_line_covers_whole_function():
    src = ("import jax.numpy as jnp\n"
           "def oracle(tok):  # repro: allow(host-sync) per-step oracle\n"
           "    x = jnp.ones((2,))\n"
           "    a = x.sum().item()\n"
           "    b = float(x.max())\n"
           "    return a, b\n")
    assert _rules(src) == set()


def test_allow_is_rule_specific():
    # allow(host-sync) must NOT silence a different rule on the same line.
    src = ("import jax\n"
           "def step(params, caches):\n"
           "    return params, caches\n"
           "step_jit = jax.jit(step)  # repro: allow(host-sync) wrong id\n")
    assert "missing-donate" in _rules(src)


# ---------------------------------------------------------------------------
# jaxpr checks on toy jitted functions
# ---------------------------------------------------------------------------

def _toy_jaxpr(n=64):
    def f(x):
        return (x * 2.0 + 1.0).sum()

    return jax.make_jaxpr(f)(jnp.zeros((n, n), jnp.float32))


def test_aval_budget_pass_and_fail():
    jaxpr = _toy_jaxpr(64)              # biggest intermediate: 64*64*4 bytes
    assert jaxpr_check.max_aval_bytes(jaxpr) == 64 * 64 * 4
    assert jaxpr_check.check_aval_budget(jaxpr, 64 * 64 * 4) == []
    over = jaxpr_check.check_aval_budget(jaxpr, 64 * 64 * 4 - 1)
    assert over and all(v.nbytes > 64 * 64 * 4 - 1 for v in over)


def test_forbid_aval_shape_and_adjacent_dims():
    def f(x):
        y = x.reshape(4, 16)            # the "forbidden" intermediate
        return y.sum()

    jaxpr = jax.make_jaxpr(f)(jnp.zeros((64,), jnp.float32))
    assert jaxpr_check.has_adjacent_dims(jaxpr, (4, 16))
    assert not jaxpr_check.has_adjacent_dims(jaxpr, (4, 17))
    hits = jaxpr_check.forbid_aval_shape(jaxpr, lambda s: s == (4, 16))
    assert hits and hits[0].shape == (4, 16)


def test_iter_eqns_recurses_into_scan():
    def f(x):
        def body(c, _):
            return c * 2.0, c
        return jax.lax.scan(body, x, None, length=3)

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,)))
    counts = jaxpr_check.count_primitives(jaxpr)
    assert counts["scan"] == 1
    assert counts["mul"] >= 1           # found inside the scan body


def test_verify_donation_positive_and_negative():
    def f(x, caches):
        return x + 1.0, caches * 2.0

    donated = jax.jit(f, donate_argnums=(1,))
    plain = jax.jit(f)
    x, c = jnp.zeros((4,)), jnp.ones((8,))
    assert jaxpr_check.verify_donation(donated, x, c)
    assert not jaxpr_check.verify_donation(plain, x, c)


# ---------------------------------------------------------------------------
# runtime tracker
# ---------------------------------------------------------------------------

class _Host:
    def __init__(self):
        self._step = jax.jit(lambda x: x + 1.0)
        self._other = jax.jit(lambda x: x * 2.0)


def test_dispatch_audit_counts_and_restores():
    host = _Host()
    orig = host._step
    with DispatchAudit(host, ["_step"]) as audit:
        host._step(jnp.zeros((2,)))
        host._step(jnp.zeros((2,)))
        assert audit.calls("_step") == 2
    assert host._step is orig           # unwrapped on exit


def test_dispatch_audit_forbid():
    host = _Host()
    with DispatchAudit(host, ["_other"]) as audit:
        audit.forbid("_other")
        with pytest.raises(AssertionError, match="forbidden"):
            host._other(jnp.zeros((2,)))


def test_dispatch_audit_retrace_detection():
    host = _Host()
    host._step(jnp.zeros((2,)))         # warm: one cached executable
    with DispatchAudit(host, ["_step"]) as audit:
        host._step(jnp.zeros((2,)))     # same shape: cache hit
        audit.assert_no_retrace()
        host._step(jnp.zeros((3,)))     # new shape: retrace
        with pytest.raises(AssertionError, match="retraced"):
            audit.assert_no_retrace()


# ---------------------------------------------------------------------------
# reference budgets + the CI gate canaries
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_parts():
    return check_static._parts()


def test_reference_budgets_pass_on_pallas(smoke_parts):
    """The smallest reference point passes on pallas, and the gather
    backend trips the no-gather-view detector at the same geometry —
    proving the budget distinguishes the two paths."""
    budget = REFERENCE_BUDGETS[-1]      # bench6 chaos point (cheapest)
    report = check_budget(smoke_parts, budget, backend="pallas")
    assert report.ok, report.render()
    gather = trace_segment(smoke_parts, "gather", budget)
    assert jaxpr_check.has_adjacent_dims(
        gather, (budget.batch, budget.slots_padded))


def test_gate_fails_on_seeded_lint_fixtures():
    rc = check_static.main(["--lint-root", str(FIXTURES)])
    assert rc != 0


def test_seeded_fixtures_cover_both_classes():
    # every lint rule fires at least once across the bad_* fixtures ...
    from repro.analysis.lint import lint_tree
    rules = {f.rule for f in lint_tree(FIXTURES, ALL_HOT)}
    assert rules == {"host-sync", "missing-donate", "tracer-branch",
                     "late-closure", "device-constant"}
    # ... and none of them fire in the allowlisted negative fixture
    good = (FIXTURES / "good_hot.py").read_text()
    assert lint_source(good, "good_hot.py", ALL_HOT) == []


def test_gate_fails_on_budget_canary():
    rc = check_static.main(["--canary-budget"])
    assert rc != 0

"""Optimizer, gradient compression, and data-pipeline substrates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip cleanly when absent
from hypothesis import given, settings, strategies as st

from repro.data.digits import make_dataset
from repro.data.tokens import TokenStream
from repro.optim.adam import (AdamConfig, adam_init, adam_update, global_norm,
                              warmup_cosine)
from repro.optim.compression import (compress_tree, decompress_tree,
                                     init_error_feedback)


def test_adam_converges_quadratic():
    cfg = AdamConfig(lr=0.05, total_steps=200, warmup_steps=5,
                     weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adam_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, m = adam_update(cfg, g, opt, params)
    assert float(loss(params)) < 1e-2


def test_warmup_cosine_shape():
    cfg = AdamConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(warmup_cosine(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0 and abs(lrs[10] - 1.0) < 1e-6
    assert abs(lrs[100] - 0.1) < 1e-6
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # decays


def test_grad_clip_applied():
    cfg = AdamConfig(clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    opt = adam_init(params)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, m = adam_update(cfg, g, opt, params)
    assert float(m["grad_norm"]) > 99  # pre-clip norm reported


def test_compression_error_feedback_preserves_signal():
    """With error feedback, the *accumulated* compressed signal tracks the
    accumulated true gradient (bias-free up to one step of residual)."""
    key = jax.random.PRNGKey(0)
    g_true = {"w": jax.random.normal(key, (256,)) * 0.1}
    state = init_error_feedback(g_true)
    acc = jnp.zeros(256)
    for i in range(20):
        q, s, state = compress_tree(g_true, state, jax.random.PRNGKey(i))
        acc = acc + decompress_tree(q, s)["w"]
    target = 20 * g_true["w"]
    resid = float(jnp.max(jnp.abs(acc + state.residual["w"] - target)))
    assert resid < 1e-3  # EF invariant: sent + residual == total signal


def test_compression_wire_is_int8():
    g = {"w": jnp.linspace(-1, 1, 64)}
    q, s, _ = compress_tree(g, init_error_feedback(g), jax.random.PRNGKey(0))
    assert q["w"].dtype == jnp.int8


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_token_stream_restart_deterministic(step):
    ts = TokenStream(vocab=97, seq_len=16, batch=4, seed=5)
    b1 = ts.batch_at(step)
    b2 = ts.batch_at(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_token_stream_learnable_structure():
    ts = TokenStream(vocab=50, seq_len=64, batch=8, seed=1, noise=0.1)
    b = ts.batch_at(0)
    perm = np.random.default_rng(1).permutation(50)
    match = (perm[b["tokens"]] == b["labels"]).mean()
    assert match > 0.8  # ≈ 1 − noise


def test_digits_deterministic_and_balanced():
    x1, y1 = make_dataset(512, seed=9)
    x2, y2 = make_dataset(512, seed=9)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (512, 28, 28, 1)
    assert x1.min() >= 0.0 and x1.max() <= 1.0
    counts = np.bincount(y1, minlength=10)
    assert counts.min() > 20  # all classes present

"""Packed int4 KV end to end: kernel, serving stack, precision policy.

The load-bearing properties of the kv4 precision rung (docs/serving.md
§Precision ladder):

* ``pack_int4``/``unpack_int4`` round-trip the full signed nibble range
  with the documented layout (low nibble = even index);
* the paged-attention kernel (interpret mode) matches the gather-view
  oracle at kv4 across block-boundary cache lengths, fragmented
  out-of-order tables, and dead rows — nibbles unpacked in VMEM,
  dequantize-first operation order;
* the serving stack carries kv4 through every lifecycle the pool
  supports: continuous scheduling (both backends, token-identical to
  solo), preempt/resume, crash/restart recovery, and shared-prefix CoW;
* unsupported combinations fail loudly (kv4 + ``kv16_masters``) and a
  kernel-less precision degrades ``paged_backend`` with a warning, never
  silently;
* a per-layer mixed bit-width schedule (kv4/kv8/kv16 layers) rides the
  jitted decode as *data*: scheduler ≡ solo under the same policy, zero
  retraces (DispatchAudit-guarded), the critical profile's pinned all-16
  row is token-identical to the no-policy baseline, and billed ≡
  delivered.
"""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.tracker import DispatchAudit
from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import paper_profiles
from repro.core.qtypes import pack_int4, unpack_int4
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention_pallas
from repro.models import transformer as T
from repro.serving.durability import Durability, recover
from repro.serving.engine import (AdaptiveServer, Request, RequestStatus,
                                  ServingConfig)
from repro.serving.scheduler import ContinuousScheduler


def _build(arch="granite-3-2b"):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


@pytest.fixture(scope="module")
def dense_parts():
    return _build()


def _solo_tokens(parts, req, kv_bits=16, slots=64, policy=None):
    cfg, params, eng = parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=slots, max_batch=4,
                                       kv_bits=kv_bits,
                                       precision_policy=policy))
    return srv.generate(req.tokens[None, :], req.max_new)["tokens"][0]


def _mixed_policy(parts):
    """One kv4/kv8/kv16-striped row for every profile (n_layers-agnostic)."""
    cfg, _, eng = parts
    row = tuple((4, 8, 16)[l % 3] for l in range(cfg.n_layers))
    return tuple(row for _ in eng.profile_names)


# ---------------------------------------------------------------------------
# pack/unpack units
# ---------------------------------------------------------------------------

def test_pack_unpack_roundtrip():
    """Every signed nibble value (-8..7) survives pack → unpack across
    ranks, the carrier halves the trailing axis, and dtype stays int8."""
    rng = np.random.default_rng(0)
    for shape in [(8,), (3, 4), (2, 5, 6), (4, 1, 2, 16)]:
        x = rng.integers(-8, 8, shape).astype(np.int8)
        p = pack_int4(jnp.asarray(x))
        assert p.shape == shape[:-1] + (shape[-1] // 2,)
        assert p.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(unpack_int4(p)), x)


def test_pack_nibble_layout():
    """The documented byte layout: low nibble = even index, high = odd —
    the order the kernel's VMEM unpack and the oracle both assume."""
    p = pack_int4(jnp.asarray([[1, -2, 7, -8]], jnp.int8))
    def byte(lo, hi):
        v = (lo & 0xF) | ((hi & 0xF) << 4)
        return v - 256 if v > 127 else v
    assert [int(p[0, 0]), int(p[0, 1])] == [byte(1, -2), byte(7, -8)]


# ---------------------------------------------------------------------------
# kernel vs gather-view oracle at kv4 (interpret mode)
# ---------------------------------------------------------------------------

def _pool_case4(seed, lengths, *, n_blocks=16, bs=8, n_lblk=4, hkv=2, d=16,
                hg=2, dead_sentinels=()):
    """Fragmented kv4 paged state: packed [n_blocks, bs, hkv, d/2] pools,
    out-of-order physical blocks, lengths straddling block boundaries,
    optional dead rows whose tables hold only unmapped sentinels."""
    rng = np.random.default_rng(seed)
    b = len(lengths) + len(dead_sentinels)
    q = jnp.asarray(rng.normal(size=(b, hkv, hg, d)), jnp.float32)
    kp = pack_int4(jnp.asarray(rng.integers(-7, 8, (n_blocks, bs, hkv, d)),
                               jnp.int8))
    vp = pack_int4(jnp.asarray(rng.integers(-7, 8, (n_blocks, bs, hkv, d)),
                               jnp.int8))
    ks = jnp.asarray(rng.uniform(0.05, 0.2, (b, hkv)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.05, 0.2, (b, hkv)), jnp.float32)
    perm = rng.permutation(n_blocks)
    tidx = np.full((n_blocks, bs), -1, np.int32)
    bt = np.full((b, n_lblk), n_blocks, np.int32)
    pos = np.zeros((b,), np.int32)
    nxt = 0
    for r, ln in enumerate(lengths):
        pos[r] = ln - 1
        for lb in range(-(-ln // bs)):
            p = int(perm[nxt]); nxt += 1
            bt[r, lb] = p
            nv = min(ln - lb * bs, bs)
            tidx[p, :nv] = lb * bs + np.arange(nv)
    for i, sent in enumerate(dead_sentinels):
        bt[len(lengths) + i, :] = sent
    return (q, kp, vp, ks, vs, jnp.asarray(tidx), jnp.asarray(bt),
            jnp.asarray(pos))


def test_kernel_matches_ref_kv4():
    """Block-boundary lengths 7/8/9/16/17 through fragmented out-of-order
    tables + two dead rows (−1 and ≥ n_blocks sentinels): the packed-int4
    kernel equals the gather-view oracle to float precision, and dead rows
    flush exact zeros on both paths."""
    case = _pool_case4(3, (7, 8, 9, 16, 17), n_blocks=24,
                       dead_sentinels=(-1, 24))
    out_k = paged_attention_pallas(*case, bits=4, interpret=True)
    out_r = ref.paged_attention_ref(*case, bits=4)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-5, rtol=1e-5)
    assert np.all(np.asarray(out_k)[-2:] == 0)
    assert np.all(np.asarray(out_r)[-2:] == 0)


def test_kernel_windowed_kv4():
    """Sliding-window masking agrees at kv4 too."""
    case = _pool_case4(11, (9, 17, 23), n_blocks=16, n_lblk=4, bs=8)
    out_k = paged_attention_pallas(*case, bits=4, window=8, interpret=True)
    out_r = ref.paged_attention_ref(*case, bits=4, window=8)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# serving thread-through: scheduler identity, config validation, degrade
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["gather", "pallas"])
def test_scheduler_token_identity_kv4(dense_parts, backend):
    """kv4 through the continuous scheduler — both decode backends emit
    exactly the solo tokens for prompts straddling block boundaries."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4, kv_bits=4,
                                       block_size=8, paged_backend=backend))
    assert srv.paged_backend == backend       # kv4 has a kernel path
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(13)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=mn)
            for n, mn in [(7, 6), (9, 5), (17, 6)]]
    for r in reqs:
        sched.submit(r)
    results = sched.run()
    for req, res in zip(reqs, results):
        assert res["tokens"] == _solo_tokens(dense_parts, req, kv_bits=4)


def test_kv4_kv16_masters_rejected(dense_parts):
    """kv16_masters is a bf16-pool knob: combining it with a lossy int4
    pool is a config error, not a silent ignore."""
    cfg, params, eng = dense_parts
    with pytest.raises(ValueError, match="kv16_masters"):
        AdaptiveServer(cfg, params, eng,
                       ServingConfig(slots=64, max_batch=4, kv_bits=4,
                                     kv16_masters=True))


def test_paged_backend_degrade_warns(dense_parts, caplog):
    """A precision with no kernel path degrades pallas → gather with an
    explicit one-line warning — never silently."""
    cfg, params, eng = dense_parts
    with caplog.at_level(logging.WARNING, logger="repro.serving"):
        srv = AdaptiveServer(cfg, params, eng,
                             ServingConfig(slots=64, max_batch=4, kv_bits=32,
                                           paged_backend="pallas"))
    assert srv.paged_backend == "gather"
    assert any("degraded pallas -> gather" in r.message for r in caplog.records)


def test_shared_prefix_identity_kv4(dense_parts):
    """Shared-prefix reuse at kv4: int pools share via host-master replay
    (``block_ids`` is kv16-only — a lossy pool never CoW-maps physical
    blocks), so the second sharer rides a registry hit, replays the
    prefix nibbles bit-exactly into its own blocks, and both sharers
    match solo generation through the packed kernel."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4, kv_bits=4,
                                       block_size=8, paged_backend="pallas"))
    sched = ContinuousScheduler(srv, quantum=2)
    rng = np.random.default_rng(29)
    sys_prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    r1 = Request(tokens=np.concatenate(
        [sys_prompt, rng.integers(0, cfg.vocab, 4).astype(np.int32)]),
        max_new=8)
    r2 = Request(tokens=np.concatenate(
        [sys_prompt, rng.integers(0, cfg.vocab, 7).astype(np.int32)]),
        max_new=6)
    sched.submit(r1)
    sched.step()
    entry = max(sched.registry._entries.values(), key=lambda e: e.n_tokens)
    assert entry.block_ids is None        # int pool: masters, never CoW
    sched.submit(r2)
    while sched.step():
        pass
    assert sched.registry.hits == 1
    results = sched.run()
    for req, res in zip((r1, r2), results):
        assert res["tokens"] == _solo_tokens(dense_parts, req, kv_bits=4)


# ---------------------------------------------------------------------------
# preempt/resume and crash/restart at kv4
# ---------------------------------------------------------------------------

def test_preempt_resume_token_identity_kv4(dense_parts):
    """A preempted-then-resumed kv4 row emits exactly the tokens of an
    uninterrupted run — the packed-nibble snapshot/rebuild round-trips."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=2, block_size=8,
                                       kv_bits=4, priority_classes=2,
                                       preemption=True))
    sched = ContinuousScheduler(srv, quantum=2)
    rng = np.random.default_rng(17)
    sys_p = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    s1 = Request(tokens=np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab, 4).astype(np.int32)]),
        max_new=18, priority=1)
    s2 = Request(tokens=np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab, 7).astype(np.int32)]),
        max_new=16, priority=1)
    crit = Request(tokens=rng.integers(0, cfg.vocab, 7).astype(np.int32),
                   max_new=4, priority=0)
    sched.submit(s1)
    sched.step()
    sched.submit(s2)
    sched.step()
    sched.step()
    sched.submit(crit)              # pool pressure → policy evicts a saver
    while sched.step():
        pass
    assert sched.preemptions >= 1 and sched.resumes == sched.preemptions
    for rid, req in enumerate([s1, s2, crit]):
        assert sched.results[rid]["tokens"] == \
            _solo_tokens(dense_parts, req, kv_bits=4), f"rid={rid}"
        assert len(sched.results[rid]["tokens"]) == req.max_new


def test_crash_restart_token_identity_kv4(dense_parts, tmp_path):
    """Abandon a kv4 scheduler mid-flight and recover from journal +
    checkpoint: every request completes with exactly the uninterrupted
    twin's stream — the int-nibble masters restore the packed pool."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4, block_size=8,
                                       pool_blocks=64, kv_bits=4,
                                       priority_classes=2))
    rng = np.random.default_rng(23)
    sys_p = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    mk = lambda n: rng.integers(0, cfg.vocab, n).astype(np.int32)
    reqs = [
        Request(tokens=np.concatenate([sys_p, mk(5)]), max_new=12,
                priority=1),
        Request(tokens=np.concatenate([sys_p, mk(7)]), max_new=5, priority=0),
        Request(tokens=mk(9), max_new=6, priority=1),
        Request(tokens=mk(6), max_new=10, priority=0),
    ]
    tw = ContinuousScheduler(srv, quantum=4)
    for r in reqs:
        tw.submit(r)
    tw.run()
    twin = [tw.results[i] for i in range(len(reqs))]

    jd = str(tmp_path / "kv4-crash")
    s1 = ContinuousScheduler(srv, quantum=4)
    Durability(s1, jd, checkpoint_every=1)
    for r in reqs:
        s1.submit(r)
    s1.step(); s1.step()                       # CRASH after two boundaries
    s2 = recover(srv, jd, checkpoint_every=1, quantum=4)
    assert s2.recover_info["resumed_rows"] >= 1
    while s2.step():
        pass
    for rid in range(len(reqs)):
        got = s2.results[rid]
        assert got["status"] is RequestStatus.COMPLETED, rid
        assert [int(x) for x in got["tokens"]] == \
               [int(x) for x in twin[rid]["tokens"]], rid
    s2.check()
    assert s2.allocator.used_blocks == 0


# ---------------------------------------------------------------------------
# per-layer precision policy: identity, no-retrace, billing, pinning
# ---------------------------------------------------------------------------

def test_mixed_schedule_scheduler_identity(dense_parts):
    """A kv4/kv8/kv16-striped per-layer schedule through the continuous
    scheduler (pallas backend): token-identical to a solo run under the
    same policy, distinct from the no-policy baseline, and the whole run
    dispatches ONE segment executable with zero retraces — the schedule is
    data, not a trace axis."""
    cfg, params, eng = dense_parts
    policy = _mixed_policy(dense_parts)
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4, block_size=8,
                                       paged_backend="pallas",
                                       precision_policy=policy))
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(37)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=mn)
            for n, mn in [(7, 6), (9, 5), (17, 6)]]
    with DispatchAudit(srv, ["_segment"]) as audit:
        for r in reqs:
            sched.submit(r)
        results = sched.run()
        audit.assert_no_retrace()
    assert srv._segment._cache_size() == 1
    drifted = False
    for req, res in zip(reqs, results):
        assert res["tokens"] == _solo_tokens(dense_parts, req, policy=policy)
        drifted |= res["tokens"] != _solo_tokens(dense_parts, req)
    assert drifted       # the refined layers actually changed the stream


def test_all16_policy_is_exact_passthrough(dense_parts):
    """The all-16 row is byte-exact: a policy of 16s emits exactly the
    no-policy tokens — the refine boundary at eff>=16 is an identity."""
    cfg, _, eng = dense_parts
    policy = tuple((16,) * cfg.n_layers for _ in eng.profile_names)
    rng = np.random.default_rng(41)
    req = Request(tokens=rng.integers(0, cfg.vocab, 9).astype(np.int32),
                  max_new=6)
    assert _solo_tokens(dense_parts, req, policy=policy) == \
        _solo_tokens(dense_parts, req)


def test_speculate_policy_rejected(dense_parts):
    """Draft/verify windows do not thread the per-layer schedule — the
    combination is a config error."""
    cfg, params, eng = dense_parts
    with pytest.raises(ValueError, match="speculate"):
        AdaptiveServer(cfg, params, eng,
                       ServingConfig(slots=64, max_batch=4, block_size=8,
                                     speculate=True, draft_k=2,
                                     precision_policy=_mixed_policy(
                                         dense_parts)))


def test_critical_pinned_identity_and_billing(dense_parts):
    """Priority classes under a searched-style policy: the accuracy-bound
    profiles pin the all-16 row, so a critical request's stream is
    token-identical to the no-policy twin even while saver rows ride the
    mixed frontier row — and the ledger bills exactly the delivered
    tokens (billed ≡ delivered)."""
    cfg, params, eng = dense_parts
    stats = [ProfileStats(n, acc, e, 1e-3) for n, acc, e in [
        ("A16-W8", 0.99, 4.0), ("A16-W4", 0.953, 2.0), ("A8-W8", 0.988, 3.0),
        ("A8-W4", 0.953, 1.5), ("A4-W4", 0.958, 1.0), ("Mixed", 0.975, 2.0)]]
    mixed = _mixed_policy(dense_parts)[0]
    policy = tuple((16,) * cfg.n_layers if s.accuracy >= 0.985 else mixed
                   for s in stats)

    def run(pol):
        mgr = ProfileManager(stats, accuracy_target=0.985,
                             accuracy_floor=0.90, budget_j=60.0,
                             low_energy=0.5)
        srv = AdaptiveServer(cfg, params, eng,
                             ServingConfig(slots=64, max_batch=4,
                                           block_size=8, priority_classes=2,
                                           precision_policy=pol),
                             manager=mgr)
        sched = ContinuousScheduler(srv, quantum=3)
        rng = np.random.default_rng(43)
        mk = lambda n: rng.integers(0, cfg.vocab, n).astype(np.int32)
        reqs = [Request(tokens=mk(7), max_new=6, priority=0,
                        accuracy_critical=True),
                Request(tokens=mk(9), max_new=8, priority=1),
                Request(tokens=mk(6), max_new=8, priority=1)]
        for r in reqs:
            sched.submit(r)
        sched.run()
        return sched, reqs

    s_pol, reqs = run(policy)
    s_base, _ = run(None)
    # the saver regime engaged (mixed row exercised) on both runs
    assert any("A4-W4" in s_pol.results[rid]["profile_trace"]
               for rid in range(len(reqs)))
    # identical profile evolution (billing is policy-independent) ...
    assert s_pol.events == s_base.events
    # ... and the critical request's stream is pinned to the baseline
    assert s_pol.results[0]["profile_trace"] == \
        s_base.results[0]["profile_trace"]
    assert s_pol.results[0]["tokens"] == s_base.results[0]["tokens"]
    # billed ≡ delivered: every event bills live rows, Σ = Σ max_new
    assert sum(n for _, n, _ in s_pol.events) == sum(r.max_new for r in reqs)

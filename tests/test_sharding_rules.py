"""Sharding-rule unit tests (pure spec computation — no multi-device runtime;
the real 256/512-device lowering is exercised by the dry-run)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P


class FakeMesh:
    """Duck-typed mesh exposing .shape / .axis_names for the rule table."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


from repro.launch.sharding import batch_specs, cache_specs, param_specs

MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _sds(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_embed_and_head_specs():
    tree = {"embed": {"w": _sds(152064, 8192)}, "lm_head": {"w": _sds(8192, 152064)}}
    specs = param_specs(tree, MESH1)
    assert specs["embed"]["w"] == P("model", "data")
    assert specs["lm_head"]["w"] == P("data", "model")


def test_column_row_pairs():
    tree = {"layers": {"qkv": {"w": _sds(80, 8192, 10240)},
                       "attn_out": {"w": _sds(80, 8192, 8192)},
                       "mlp": {"w_in": {"w": _sds(80, 8192, 59136)},
                               "w_out": {"w": _sds(80, 29568, 8192)}}}}
    specs = param_specs(tree, MESH1)
    assert specs["layers"]["qkv"]["w"] == P(None, "data", "model")
    assert specs["layers"]["attn_out"]["w"] == P(None, "model", "data")
    assert specs["layers"]["mlp"]["w_in"]["w"] == P(None, "data", "model")
    assert specs["layers"]["mlp"]["w_out"]["w"] == P(None, "model", "data")


def test_multipod_fsdp_uses_pod_and_data():
    tree = {"layers": {"qkv": {"w": _sds(80, 8192, 10240)}}}
    specs = param_specs(tree, MESH2)
    assert specs["layers"]["qkv"]["w"] == P(None, ("pod", "data"), "model")


def test_moe_expert_parallel():
    tree = {"layers": {"moe": {"w_in": _sds(28, 64, 2048, 2816),
                               "w_out": _sds(28, 64, 1408, 2048),
                               "router": {"w": _sds(28, 2048, 64)}}}}
    specs = param_specs(tree, MESH1)
    assert specs["layers"]["moe"]["w_in"] == P(None, "model", "data")
    assert specs["layers"]["moe"]["w_out"][1] == "model"
    assert specs["layers"]["moe"]["router"]["w"] == P(None, "data")


def test_norms_replicated():
    specs = param_specs({"layers": {"norm_attn": {"g": _sds(80, 8192)}}}, MESH1)
    assert specs["layers"]["norm_attn"]["g"] == P()


def test_divisibility_fallback():
    """Dims that don't divide the axis are silently replicated, not errors."""
    tree = {"layers": {"qkv": {"w": _sds(2, 100, 999)}}}  # 999 % 16 != 0
    specs = param_specs(tree, MESH1)
    assert specs["layers"]["qkv"]["w"] == P()  # both dims dropped (100 too)


def test_batch_specs_and_long500k_fallback():
    b = {"tokens": _sds(256, 4096), "labels": _sds(256, 4096)}
    specs = batch_specs(b, MESH1)
    assert specs["tokens"] == P("data")
    one = batch_specs({"tokens": _sds(1, 524288)}, MESH1)
    assert one["tokens"] == P()  # batch=1 can't shard → replicate, don't fail


def test_cache_specs():
    from repro.models.attention import KVCache
    kv = KVCache(k=_sds(80, 128, 32768, 8, 128), v=_sds(80, 128, 32768, 8, 128),
                 k_scale=_sds(80, 128, 8), v_scale=_sds(80, 128, 8),
                 token_idx=jax.ShapeDtypeStruct((80, 128, 32768), jnp.int32))
    specs = cache_specs({"kv": kv}, MESH1)
    assert specs["kv"].k[1] == "data"          # batch over data
    assert specs["kv"].k[2] == "model"         # Hkv=8 % 16 → slots sharded
    assert specs["kv"].token_idx == P(None, "data", "model")
    # divisible Hkv → heads sharded instead
    kv16 = KVCache(k=_sds(28, 128, 32768, 16, 128), v=_sds(28, 128, 32768, 16, 128),
                   k_scale=_sds(28, 128, 16), v_scale=_sds(28, 128, 16),
                   token_idx=jax.ShapeDtypeStruct((28, 128, 32768), jnp.int32))
    specs16 = cache_specs({"kv": kv16}, MESH1)
    assert specs16["kv"].k[3] == "model"

"""End-to-end behaviour: the paper's full loop — QAT → profiles → merged
adaptive engine → Profile-Manager-driven inference on a battery budget."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import activity_factor, step_energy
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.merge import merge_plan
from repro.core.profiles import paper_profiles, profile_table
from repro.data.digits import batches, make_dataset
from repro.models import cnn as C
from repro.optim.adam import AdamConfig, adam_init, adam_update


def test_end_to_end_adaptive_inference():
    cfg = C.CNNConfig(channels=8)  # reduced width; structure identical
    params = C.init_cnn(cfg, jax.random.PRNGKey(0))
    profs = paper_profiles(C.CNN_LAYERS, inner_layers=["conv1"])
    table = jnp.asarray(profile_table(profs, C.CNN_LAYERS))
    train_x, train_y = make_dataset(1024, seed=1)
    test_x, test_y = make_dataset(512, seed=2)
    acfg = AdamConfig(lr=3e-3, total_steps=100, warmup_steps=5)

    @jax.jit
    def step(params, opt, pid, x, y):
        (l, m), g = jax.value_and_grad(C.cnn_loss, has_aux=True)(
            params, table[pid], {"images": x, "labels": y})
        params, opt, _ = adam_update(acfg, g, opt, params)
        return params, opt, l

    opt = adam_init(params)
    it = batches(train_x, train_y, 128, seed=3)
    for i in range(100):
        x, y = next(it)
        params, opt, loss = step(params, opt, i % len(profs),
                                 jnp.asarray(x), jnp.asarray(y))

    # 1) QAT learned the task at every profile
    accs = {}
    for pid, prof in enumerate(profs):
        accs[prof.name] = C.cnn_accuracy(params, table[pid], test_x, test_y,
                                         batch=256)
        assert accs[prof.name] > 0.75, (prof.name, accs[prof.name])

    # 2) merged engine: paper pair shares conv0/fc, switches conv1
    by = {p.name: p for p in profs}
    plan = merge_plan([by["A8-W8"], by["Mixed"]])
    assert plan.shared_layers == ("conv0", "fc")

    # 3) manager runs the budgeted loop and prefers the cheap profile
    stats = [
        ProfileStats("A8-W8", accs["A8-W8"],
                     step_energy(1e-5, activity_factor(8, 8, 0.5)), 1e-5),
        ProfileStats("Mixed", accs["Mixed"],
                     step_energy(1e-5, activity_factor(8, 6, 0.45)), 1e-5),
    ]
    mgr = ProfileManager(stats, accuracy_target=min(0.99, accs["A8-W8"]),
                         accuracy_floor=0.8,
                         budget_j=stats[0].energy_j * 100)
    n = 0
    while not mgr.exhausted() and n < 1000:
        pid = mgr.select(accuracy_critical=(n % 10 == 0))
        mgr.account(pid)
        n += 1
    assert n > 100  # adaptive stretch beyond the 100-at-full-power budget

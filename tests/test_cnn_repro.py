"""Paper-model tests: the tiny CNN, its profiles, and the native merged engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.merge import merge_plan
from repro.core.profiles import paper_profiles, profile_table
from repro.models import cnn as C


@pytest.fixture(scope="module")
def setup():
    cfg = C.CNNConfig(channels=16)  # reduced width for test speed
    params = C.init_cnn(cfg, jax.random.PRNGKey(0))
    profs = paper_profiles(C.CNN_LAYERS, inner_layers=["conv1"])
    table = profile_table(profs, C.CNN_LAYERS)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (8, 28, 28, 1))
    return cfg, params, profs, table, imgs


def test_forward_shapes_finite(setup):
    cfg, params, profs, table, imgs = setup
    for pid in range(len(profs)):
        logits = C.cnn_forward(params, table[pid], imgs)
        assert logits.shape == (8, cfg.n_classes)
        assert np.isfinite(np.asarray(logits)).all()


def test_profiles_change_output(setup):
    cfg, params, profs, table, imgs = setup
    l16 = C.cnn_forward(params, table[0], imgs)  # A16-W8
    l4 = C.cnn_forward(params, table[4], imgs)   # A4-W4
    assert float(jnp.max(jnp.abs(l16 - l4))) > 1e-4


def test_loss_and_grad(setup):
    cfg, params, profs, table, imgs = setup
    labels = jnp.arange(8) % 10
    (l, m), g = jax.value_and_grad(C.cnn_loss, has_aux=True)(
        params, table[2], {"images": imgs, "labels": labels})
    assert np.isfinite(float(l))
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
    assert gn > 0


def test_native_engine_matches_fake_path(setup):
    """Native merged engine (integer images + lax.switch) == fake-quant path
    on the same po2 grids, for every profile in the merged pair."""
    cfg, params, profs, table, imgs = setup
    by_name = {p.name: p for p in profs}
    pair = [by_name["A8-W8"], by_name["Mixed"]]
    plan = merge_plan(pair)
    images = C.quantize_cnn_images(params, plan)
    # deduplicated images: conv0/fc shared (1 image), conv1 switched (2)
    assert len(images["conv0"]) == 1 and len(images["fc"]) == 1
    assert len(images["conv1"]) == 2
    pair_table = profile_table(pair, C.CNN_LAYERS)
    for pi, prof in enumerate(pair):
        selectors = jnp.asarray([plan.selector[ln][pi] for ln in C.CNN_LAYERS],
                                jnp.int32)
        lg_nat = C.cnn_forward_native(params, images, plan, selectors,
                                      pair_table[pi], imgs)
        lg_fake = C.cnn_forward(params, pair_table[pi], imgs)
        np.testing.assert_allclose(np.asarray(lg_nat), np.asarray(lg_fake),
                                   rtol=2e-2, atol=2e-2)


def test_native_switch_changes_inner_layer_only(setup):
    cfg, params, profs, table, imgs = setup
    by_name = {p.name: p for p in profs}
    pair = [by_name["A8-W8"], by_name["Mixed"]]
    plan = merge_plan(pair)
    images = C.quantize_cnn_images(params, plan)
    pair_table = profile_table(pair, C.CNN_LAYERS)
    sel0 = jnp.asarray([plan.selector[ln][0] for ln in C.CNN_LAYERS], jnp.int32)
    sel1 = jnp.asarray([plan.selector[ln][1] for ln in C.CNN_LAYERS], jnp.int32)
    out0 = C.cnn_forward_native(params, images, plan, sel0, pair_table[0], imgs)
    out1 = C.cnn_forward_native(params, images, plan, sel1, pair_table[1], imgs)
    assert float(jnp.max(jnp.abs(out0 - out1))) > 1e-5  # profiles really differ


def test_learns_quickly():
    """A few steps of QAT on digits reduces loss (end-to-end sanity)."""
    from repro.data.digits import make_dataset
    from repro.optim.adam import AdamConfig, adam_init, adam_update
    cfg = C.CNNConfig(channels=8)
    params = C.init_cnn(cfg, jax.random.PRNGKey(0))
    profs = paper_profiles(C.CNN_LAYERS, inner_layers=["conv1"])
    table = jnp.asarray(profile_table(profs, C.CNN_LAYERS))
    x, y = make_dataset(256, seed=4)
    acfg = AdamConfig(lr=2e-3, total_steps=30, warmup_steps=2)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt):
        (l, m), g = jax.value_and_grad(C.cnn_loss, has_aux=True)(
            params, table[2], {"images": jnp.asarray(x),
                               "labels": jnp.asarray(y)})
        params, opt, _ = adam_update(acfg, g, opt, params)
        return params, opt, l

    losses = []
    for _ in range(15):
        params, opt, l = step(params, opt)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.7

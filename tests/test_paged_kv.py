"""Paged KV cache: block-table decode, shared-prefix reuse, CoW, backpressure.

The load-bearing properties, mirroring docs/serving.md:

* paged decode (global block pool + per-row block tables) is token-identical
  to the contiguous ring layout — including prompts straddling block
  boundaries, sliding-window rings, SSM-hybrid stacks, and int8 KV;
* a shared-prefix admission (suffix-only prefill + mapped blocks) emits
  exactly what a cold full prefill would, even while the prefix owner is
  still decoding (copy-on-write: divergence lands in private blocks and the
  shared blocks' bytes never change);
* allocator exhaustion is clean backpressure — requests queue, FIFO order
  holds, nothing corrupts — and impossible requests fail loudly at submit.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.serving.engine import AdaptiveServer, Request, ServingConfig
from repro.serving.scheduler import ContinuousScheduler


def _build(arch):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


@pytest.fixture(scope="module")
def dense_parts():
    return _build("granite-3-2b")


def _solo_tokens(cfg, params, eng, req, kv_bits=16):
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4,
                                       kv_bits=kv_bits))
    return srv.generate(req.tokens[None, :], req.max_new)["tokens"][0]


@pytest.mark.parametrize("kv_bits", [16, 8])
def test_paged_block_boundary_matches_solo(dense_parts, kv_bits):
    """Prompt lengths straddling the block size (7/8/9 around bs=8): every
    row through the paged pool equals its solo (contiguous) run."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4, kv_bits=kv_bits,
                         block_size=8)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=4)
    assert sched.paged and sched.block_size == 8
    rng = np.random.default_rng(13)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=mn)
            for n, mn in [(7, 6), (8, 5), (9, 7), (16, 4), (17, 6)]]
    for r in reqs:
        sched.submit(r)
    results = sched.run()
    # after draining no block holds a live reference; blocks a registered
    # prefix still wants park in the retired-block LRU (chain entries of
    # one prompt share their leading blocks), resurrectable by a later hit
    # and reclaimable under pressure
    cached = set()
    for e in sched.registry._entries.values():
        cached.update(e.block_ids or ())
    assert sched.allocator.used_blocks == 0
    assert sched.allocator.lru_blocks == len(cached)
    for req, res in zip(reqs, results):
        assert res["tokens"] == _solo_tokens(cfg, params, eng, req, kv_bits)


@pytest.mark.parametrize("arch,kv_bits", [("hymba-1.5b", 16),
                                          ("hymba-1.5b", 8),
                                          ("mamba2-130m", 16)])
def test_paged_swa_ssm_matches_solo(arch, kv_bits):
    """Sliding-window (ring wrap inside one block table) and SSM stacks:
    the paged pool reproduces the contiguous slot pool token-for-token."""
    cfg, params, eng = _build(arch)
    scfg = ServingConfig(slots=64, max_batch=4, kv_bits=kv_bits)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=4)
    assert sched.paged == cfg.has_attn
    rng = np.random.default_rng(17)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=mn) for n, mn in [(4, 6), (9, 3), (17, 6)]]
    for r in reqs:
        sched.submit(r)
    results = sched.run()
    for req, res in zip(reqs, results):
        assert res["tokens"] == _solo_tokens(cfg, params, eng, req, kv_bits)


@pytest.mark.parametrize("kv_bits", [16, 8])
def test_shared_prefix_admission_matches_cold(dense_parts, kv_bits):
    """A hash-matched admission prefills only the suffix (prefix replayed
    from the registry) yet emits exactly the cold-prefill tokens, at bf16
    and int8 KV (int scales re-calibrated from the snapshotted amax)."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4, kv_bits=kv_bits,
                         block_size=8)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(21)
    sys_prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)  # 2 blocks
    reqs = [Request(tokens=np.concatenate([sys_prompt, t]), max_new=6)
            for t in (rng.integers(0, cfg.vocab, 5).astype(np.int32),
                      rng.integers(0, cfg.vocab, 3).astype(np.int32))]
    sched.submit(reqs[0])
    sched.run()               # cold: registers the 16- and 8-token prefixes
    assert sched.registry.hits == 0 and len(sched.registry) == 2
    sched.submit(reqs[1])
    results = sched.run()
    assert sched.registry.hits == 1           # second rode the shared path
    for req, res in zip(reqs, results):
        assert res["tokens"] == _solo_tokens(cfg, params, eng, req, kv_bits)


def test_shared_prefix_hits_across_block_boundary_tails(dense_parts):
    """The whole block-aligned prefix chain registers, so a request whose
    unique tail crosses a block boundary (changing its own longest-prefix
    hash) still matches the shared system prompt at a shorter key."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4, block_size=8)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(37)
    sys_prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)  # 2 blocks
    reqs = [Request(tokens=np.concatenate([sys_prompt, t]), max_new=5)
            for t in (rng.integers(0, cfg.vocab, 9).astype(np.int32),
                      rng.integers(0, cfg.vocab, 11).astype(np.int32))]
    sched.submit(reqs[0])
    sched.run()        # registers keys for 24- AND 16-token prefixes
    assert len(sched.registry) == 3            # chain: 3, 2, 1 blocks
    sched.submit(reqs[1])                      # 27 tokens: longest own key
    results = sched.run()                      # is 24 ≠ reqs[0]'s 24 — must
    assert sched.registry.hits == 1            # fall through to the 16-key
    for req, res in zip(reqs, results):
        assert res["tokens"] == _solo_tokens(cfg, params, eng, req)


def test_cow_divergence_shared_blocks_uncorrupted(dense_parts):
    """Two rows decoding concurrently off the same prefix blocks: divergent
    suffixes/generations land in private blocks only — the shared blocks'
    bytes are identical before and after, and both rows match solo."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4, block_size=8)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=2)
    rng = np.random.default_rng(29)
    sys_prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    r1 = Request(tokens=np.concatenate(
        [sys_prompt, rng.integers(0, cfg.vocab, 4).astype(np.int32)]),
        max_new=12)
    r2 = Request(tokens=np.concatenate(
        [sys_prompt, rng.integers(0, cfg.vocab, 7).astype(np.int32)]),
        max_new=8)
    sched.submit(r1)
    sched.step()                              # r1 admitted cold + registered
    entry = max(sched.registry._entries.values(), key=lambda e: e.n_tokens)
    bids = np.asarray(entry.block_ids)
    pool = sched._caches["kv"]
    snap_k = np.asarray(pool.k[:, bids]).copy()
    snap_v = np.asarray(pool.v[:, bids]).copy()
    sched.submit(r2)                          # shares while r1 is still live
    while sched.step():
        pass
    assert sched.registry.hits == 1
    pool = sched._caches["kv"]
    assert np.array_equal(np.asarray(pool.k[:, bids]), snap_k)
    assert np.array_equal(np.asarray(pool.v[:, bids]), snap_v)
    results = sched.run()
    for req, res in zip((r1, r2), results):
        assert res["tokens"] == _solo_tokens(cfg, params, eng, req)


def test_allocator_exhaustion_backpressure(dense_parts):
    """A full block pool stalls admission (FIFO-preserving backpressure)
    instead of corrupting live rows; impossible requests fail at submit."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=4, block_size=8,
                         pool_blocks=6, prefix_cache=False)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(31)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, 9).astype(np.int32),
                    max_new=7) for _ in range(5)]      # 2 blocks each
    rids = [sched.submit(r) for r in reqs]
    assert sched.admit() == 3                 # 6-block pool: 3 of 4 slots
    assert sched.pending == 2 and sched.allocator.free_blocks == 0
    assert sched.admit() == 0                 # exhausted: clean backpressure
    results = sched.run()
    assert sched.admission_log == rids        # FIFO held under pressure
    assert sched.allocator.used_blocks == 0   # everything returned
    for req, res in zip(reqs, results):
        assert res["tokens"] == _solo_tokens(cfg, params, eng, req)
    with pytest.raises(ValueError, match="KV blocks"):
        sched.submit(Request(tokens=rng.integers(0, cfg.vocab, 9)
                             .astype(np.int32), max_new=48))

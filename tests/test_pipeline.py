"""Pipeline-parallel streaming (subprocess: needs its own device count)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distrib.pipeline import pipeline_forward, stage_split

mesh = jax.make_mesh((4,), ("pod",))
L, D, B = 8, 16, 12
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (L, D, D)) / np.sqrt(D)
x = jax.random.normal(jax.random.fold_in(key, 1), (B, D))

def layer(w, h):
    return jnp.tanh(h @ w)

def stage_fn(sp, xm):  # sp: [L/S, D, D]
    def body(h, w):
        return layer(w, h), None
    h, _ = jax.lax.scan(body, xm, sp)
    return h

# sequential reference
ref = x
for l in range(L):
    ref = layer(ws[l], ref)

staged = stage_split(ws, 4)
from jax.sharding import NamedSharding, PartitionSpec as P
staged = jax.device_put(staged, NamedSharding(mesh, P("pod")))
y = pipeline_forward(stage_fn, staged, x, mesh=mesh, n_microbatches=4)
err = float(jnp.max(jnp.abs(y - ref)))
print("PIPE_ERR", err)
assert err < 1e-5
print("PIPE_OK")
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PIPE_OK" in r.stdout

"""Fault tolerance: injected failure → restart resumes bit-exactly; straggler
monitor flags injected latencies; preemption checkpoints cleanly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adam import AdamConfig
from repro.train.loop import StragglerMonitor, TrainConfig, train


def _setup():
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (8, 4))
    params = {"w": jnp.zeros((8, 4))}

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {"mse": l}

    def data_at(step):
        k = jax.random.PRNGKey(1000 + step)
        x = jax.random.normal(k, (32, 8))
        return {"x": x, "y": x @ w_true}

    return params, loss_fn, data_at


def test_restart_bit_exact(tmp_path):
    params, loss_fn, data_at = _setup()
    acfg = AdamConfig(lr=1e-2, total_steps=20, warmup_steps=2)

    # uninterrupted reference run
    ref = train(params, loss_fn, data_at,
                TrainConfig(steps=20, ckpt_dir=str(tmp_path / "ref"),
                            ckpt_every=5, log_every=100), acfg,
                log=lambda s: None)

    # failing run: dies at step 12, then restarts from checkpoint
    ckpt = str(tmp_path / "fail")
    with pytest.raises(RuntimeError, match="injected failure"):
        train(params, loss_fn, data_at,
              TrainConfig(steps=20, ckpt_dir=ckpt, ckpt_every=5,
                          fail_at_step=12, log_every=100), acfg,
              log=lambda s: None)
    resumed = train(params, loss_fn, data_at,
                    TrainConfig(steps=20, ckpt_dir=ckpt, ckpt_every=5,
                                log_every=100), acfg, log=lambda s: None)

    np.testing.assert_array_equal(np.asarray(ref["params"]["w"]),
                                  np.asarray(resumed["params"]["w"]))
    assert float(ref["history"][-1]) < float(ref["history"][0])  # it learns


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(window=10, k_sigma=3.0, min_steps=5)
    for i in range(10):
        assert not mon.record(i, 0.10 + 0.001 * (i % 3))
    assert mon.record(10, 0.5)          # 5× the mean → flagged
    assert mon.flagged and mon.flagged[0][0] == 10
    assert not mon.record(11, 0.101)    # back to normal


def test_preemption_checkpoints(tmp_path):
    import os
    import signal
    params, loss_fn, data_at = _setup()
    acfg = AdamConfig(lr=1e-2, total_steps=50, warmup_steps=2)
    ckpt = str(tmp_path / "pre")

    calls = {"n": 0}
    orig = data_at

    def data_with_sigterm(step):
        calls["n"] += 1
        if step == 7:
            os.kill(os.getpid(), signal.SIGTERM)  # simulated preemption
        return orig(step)

    out = train(params, loss_fn, data_with_sigterm,
                TrainConfig(steps=50, ckpt_dir=ckpt, ckpt_every=100,
                            log_every=100), acfg, log=lambda s: None)
    assert out["last_step"] < 49            # exited early
    from repro.checkpoint.manager import latest_step
    assert latest_step(ckpt) is not None    # checkpointed on the way out

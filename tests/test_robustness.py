"""Fault-tolerant serving: deadlines, cancellation, quarantine, shedding.

The load-bearing properties of the robustness layer:

* every request leaves through exactly ONE terminal ``RequestStatus``
  (COMPLETED / CANCELLED / EXPIRED / SHED / FAILED) on its result;
* cancellation and expiry mid-segment stay **oracle-exact**: replaying the
  event log through a fresh manager reproduces every profile choice and
  the ledger, and total billed inferences equal total delivered tokens —
  a reaped row bills exactly what it actually generated (kv16 AND kv8,
  shared-CoW rows included), with the ``paranoid`` allocator audit on
  after every step;
* deadline-aware admission rejects a request whose deadline the step-time
  EMA already rules unreachable — structured EXPIRED, never doomed work;
* a row caught producing non-finite logits (seeded ``FaultSchedule``
  injection through the one pool-lifetime segment executable) is
  quarantined, escalated one rung toward the accuracy target, retried
  from the prompt, and completes with output **token-identical to a clean
  run at the escalated profile**; persistent faults exhaust the bounded
  retry budget into FAILED — never a hang, never a leaked block;
* overload sheds the least urgent queued work with SHED (critical
  arrivals displace saver tails, never vice versa), injected allocator
  droughts turn into plain backpressure, and an injected flush stall
  trips the watchdog.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.serving.engine import (AdaptiveServer, Request, RequestStatus,
                                  ServingConfig)
from repro.serving.faults import FaultSchedule, Watchdog
from repro.serving.policy import FifoPolicy, PriorityPolicy, ShedPolicy, \
    default_classes
from repro.serving.scheduler import ContinuousScheduler


def _build(arch="granite-3-2b"):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


@pytest.fixture(scope="module")
def dense_parts():
    return _build()


def _manager():
    stats = [ProfileStats(n, acc, e, 1e-3) for n, acc, e in [
        ("A16-W8", 0.99, 4.0), ("A16-W4", 0.953, 2.0), ("A8-W8", 0.988, 3.0),
        ("A8-W4", 0.953, 1.5), ("A4-W4", 0.958, 1.0), ("Mixed", 0.975, 2.0)]]
    return ProfileManager(stats, accuracy_target=0.985, accuracy_floor=0.90,
                          budget_j=150.0, low_energy=0.5)


# ---------------------------------------------------------------------------
# pure host layer: fault schedule, watchdog, shed policy, queue surgery
# ---------------------------------------------------------------------------

def test_fault_schedule_deterministic_and_once():
    """Injection decisions are a pure function of (seed, kind, key) — two
    schedules with one seed agree regardless of query order — and a
    targeted (rid, attempt) fires exactly once."""
    a = FaultSchedule(seed=7, p_nan=0.4, p_alloc=0.3, p_stall=0.3)
    b = FaultSchedule(seed=7, p_nan=0.4, p_alloc=0.3, p_stall=0.3)
    keys = [(r, at) for r in range(12) for at in range(2)]
    fwd = {k: a.want_nan(*k) for k in keys}
    rev = {k: b.want_nan(*k) for k in reversed(keys)}
    assert fwd == rev and any(fwd.values()) and not all(fwd.values())
    assert [FaultSchedule(seed=7, p_alloc=0.3).alloc_dry(i)
            for i in range(20)] == [b2.alloc_dry(i) for b2, i in
                                    ((FaultSchedule(seed=7, p_alloc=0.3), i)
                                     for i in range(20))]
    tgt = FaultSchedule(nan_at={3: (1,)})
    assert not tgt.want_nan(3, 0)
    assert tgt.want_nan(3, 1) and not tgt.want_nan(3, 1)   # once, ever
    assert tgt.injected_nan == 1
    capped = FaultSchedule(seed=0, p_nan=1.0, max_nan=2)
    assert sum(capped.want_nan(r, 0) for r in range(10)) == 2
    st = FaultSchedule(stall_at=(2,), stall_s=0.5)
    assert st.flush_stall(0) == 0.0 and st.flush_stall(2) == 0.5
    wd = Watchdog(limit_s=0.1)
    assert not wd.record("fast", 0.05) and wd.record("slow", 0.2)
    assert wd.stalls == 1 and wd.flagged == [("slow", 0.2)]


def test_shed_policy_and_queue_surgery():
    """ShedPolicy thresholds, plus remove/rids/shed_tail on both queue
    disciplines (shed_tail = least urgent class's tail)."""
    sp = ShedPolicy(max_queue=3)
    assert not sp.triggered(3, 0) and sp.triggered(4, 0)
    assert ShedPolicy(max_predicted_miss=0).triggered(0, 1)
    assert not ShedPolicy().triggered(10**6, 10**6)       # default: never
    fifo = FifoPolicy()
    for rid in (5, 6, 7):
        fifo.enqueue(rid, Request(tokens=np.zeros(2, np.int32), max_new=1))
    assert fifo.rids() == [5, 6, 7] and fifo.shed_tail() == (7, 0)
    assert fifo.remove(6) and not fifo.remove(6)
    assert fifo.rids() == [5, 7]
    pol = PriorityPolicy(default_classes(2))
    crit = Request(tokens=np.zeros(2, np.int32), max_new=1, priority=0)
    savr = Request(tokens=np.zeros(2, np.int32), max_new=1, priority=1)
    pol.enqueue(1, savr)
    pol.enqueue(2, crit)
    pol.enqueue(3, savr)
    assert pol.rids() == [2, 1, 3]                        # critical first
    assert pol.shed_tail() == (3, 1)                      # saver tail sheds
    assert pol.remove(1) and pol.rids() == [2, 3]
    pol.remove(3)
    assert pol.shed_tail() == (2, 0)                      # only critical left


def test_shed_at_submit_protects_critical(dense_parts):
    """Overload sheds the least urgent party: a saver flood refuses the
    arrival once the queue cap trips, while a critical arrival displaces
    the queued saver tail — and never the other way around."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=2, block_size=8,
                                       priority_classes=2))
    sched = ContinuousScheduler(srv, shed=ShedPolicy(max_queue=2))
    rng = np.random.default_rng(3)
    mk = lambda pr: Request(tokens=rng.integers(0, cfg.vocab, 6)
                            .astype(np.int32), max_new=4, priority=pr)
    s0, s1 = sched.submit(mk(1)), sched.submit(mk(1))
    s2 = sched.submit(mk(1))              # depth 3 > 2: arrival sheds itself
    assert sched.results[s2]["status"] is RequestStatus.SHED
    assert "overload" in sched.results[s2]["reason"]
    c0 = sched.submit(mk(0))              # critical displaces the saver tail
    assert sched.results[s1]["status"] is RequestStatus.SHED
    assert c0 not in sched.results and sched.policy.rids() == [c0, s0]
    assert sched.cancel(s0) and \
        sched.results[s0]["status"] is RequestStatus.CANCELLED
    assert not sched.cancel(s2)           # already terminal
    assert not sched.cancel(9999)         # unknown
    assert sched.shed_count == 2 and sched.cancelled == 1
    done = dict(sched.poll_completed())
    assert {r["status"] for r in done.values()} == \
        {RequestStatus.SHED, RequestStatus.CANCELLED}


def test_deadline_aware_admission_rejects_doomed(dense_parts):
    """A request the step-time EMA rules unreachable is rejected at
    admission with structured EXPIRED — it never occupies a slot and
    never dispatches."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=2, block_size=8))
    box = [0.0]
    sched = ContinuousScheduler(srv, quantum=2, clock=lambda: box[0])
    sched._seg_dt = 5.0                   # calibrated: 5 s per segment
    rng = np.random.default_rng(4)
    rid = sched.submit(Request(tokens=rng.integers(0, cfg.vocab, 6)
                               .astype(np.int32), max_new=8,
                               deadline_ms=1000.0))   # needs ~20 s
    sched.step()
    res = sched.results[rid]
    assert res["status"] is RequestStatus.EXPIRED
    assert "unreachable" in res["reason"] and res["tokens"] == []
    assert rid not in sched.admission_log and sched.expired == 1


# ---------------------------------------------------------------------------
# execution core: cancellation / expiry stay oracle-exact (kv16 + kv8)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_bits", [16, 8])
def test_cancel_expiry_mid_segment_oracle_exact(dense_parts, kv_bits):
    """Cancel a live shared-prefix row mid-generation and expire another
    via an injected clock; the survivors complete, every terminal result
    carries its status, replaying the event log reproduces the ledger
    exactly, and billed inferences == delivered tokens — reaped rows bill
    precisely what they generated. Paranoid allocator audit on every
    step; pool fully released at drain."""
    cfg, params, eng = dense_parts
    mgr = _manager()
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4, block_size=8,
                                       kv_bits=kv_bits), manager=mgr)
    box = [0.0]
    sched = ContinuousScheduler(srv, quantum=2, clock=lambda: box[0],
                                paranoid=True)
    rng = np.random.default_rng(11)
    base = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    reqs = [
        Request(tokens=rng.integers(0, cfg.vocab, 9).astype(np.int32),
                max_new=10),
        Request(tokens=np.concatenate([base, rng.integers(
            0, cfg.vocab, 3).astype(np.int32)]), max_new=12),
        Request(tokens=np.concatenate([base, rng.integers(
            0, cfg.vocab, 5).astype(np.int32)]), max_new=12),
        Request(tokens=rng.integers(0, cfg.vocab, 7).astype(np.int32),
                max_new=40, deadline_ms=5000.0),
    ]
    rids = [sched.submit(r) for r in reqs]
    queued = sched.submit(Request(
        tokens=rng.integers(0, cfg.vocab, 6).astype(np.int32), max_new=6))
    assert sched.cancel(queued)           # never admitted: drops clean
    sched.step()
    sched.step()
    assert sched.cancel(rids[2])          # live CoW-sharing row, mid-segment
    assert not sched.cancel(rids[2])      # idempotent: already marked →
    box[0] = 10.0                         # terminal at the next boundary
    while sched.step():
        pass
    res = {rid: sched.results[rid] for rid in rids}
    assert res[rids[0]]["status"] is RequestStatus.COMPLETED
    assert len(res[rids[0]]["tokens"]) == 10
    assert res[rids[1]]["status"] is RequestStatus.COMPLETED
    assert res[rids[2]]["status"] is RequestStatus.CANCELLED
    assert 0 < len(res[rids[2]]["tokens"]) < 12      # partial, materialized
    assert res[rids[3]]["status"] is RequestStatus.EXPIRED
    assert 0 < len(res[rids[3]]["tokens"]) < 40
    assert sched.results[queued]["tokens"] == []
    # the ledger-oracle replay: profile choices and spend are reproduced,
    # and the engine billed exactly the tokens it delivered
    oracle = _manager()
    for pid, n_rows, critical in sched.events:
        assert oracle.select(accuracy_critical=critical) == pid
        oracle.account(pid, n_rows)
    assert abs(oracle.spent_j - mgr.spent_j) < 1e-9
    billed = sum(n for _, n, _ in sched.events)
    delivered = sum(len(r["tokens"]) for r in sched.results.values())
    assert billed == delivered
    sched.check()
    assert sched.allocator.used_blocks == 0


# ---------------------------------------------------------------------------
# fault injection -> quarantine -> precision-fallback recovery
# ---------------------------------------------------------------------------

def test_quarantine_escalates_and_recovers_token_identical(dense_parts):
    """The acceptance property: a row poisoned with NaN logits is detected
    by the in-scan finite-check, quarantined (blocks released, poisoned
    tokens discarded), escalated to the accuracy target, retried from the
    prompt — and the recovered output is token-identical to a clean run
    at that profile. Zero leaked blocks, recovery latency recorded."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=2, block_size=8),
                         manager=_manager())
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, cfg.vocab, 11).astype(np.int32)
    faults = FaultSchedule(nan_at={0: (0,)})      # first attempt poisoned
    sched = ContinuousScheduler(srv, quantum=4, faults=faults,
                                retry_budget=2, paranoid=True)
    rid = sched.submit(Request(tokens=prompt, max_new=8))
    res = sched.run()[rid]
    assert res["status"] is RequestStatus.COMPLETED and res["retries"] == 1
    assert len(res["tokens"]) == 8
    assert sched.faults_detected == 1 and sched.recovered == 1
    assert faults.injected_nan == 1
    assert len(sched.recovery_latency) == 1
    # the retry ran pinned to the accuracy target (the escalated rung)
    crit_names = {s.name for s in srv.manager.profiles
                  if s.accuracy >= 0.985}
    assert set(res["profile_trace"]) <= crit_names
    sched.check()
    assert sched.allocator.used_blocks == 0
    # clean accuracy-critical run on the same server: same executables,
    # fresh pool — must reproduce the recovered tokens exactly
    clean = ContinuousScheduler(srv, quantum=4)
    crid = clean.submit(Request(tokens=prompt, max_new=8,
                                accuracy_critical=True))
    assert clean.run()[crid]["tokens"] == res["tokens"]
    assert srv._segment._cache_size() == 1        # chaos rides ONE executable


def test_persistent_fault_bounded_failure(dense_parts):
    """A row that faults on every attempt exhausts the retry budget into
    FAILED — terminal, no hang, no tokens, no leaked blocks."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=2, block_size=8),
                         manager=_manager())
    rng = np.random.default_rng(22)
    faults = FaultSchedule(nan_at={0: (0, 1, 2)})
    sched = ContinuousScheduler(srv, quantum=4, faults=faults,
                                retry_budget=2, paranoid=True)
    rid = sched.submit(Request(
        tokens=rng.integers(0, cfg.vocab, 9).astype(np.int32), max_new=6))
    ok_rid = sched.submit(Request(
        tokens=rng.integers(0, cfg.vocab, 9).astype(np.int32), max_new=6))
    out = sched.run()
    assert out[rid]["status"] is RequestStatus.FAILED
    assert out[rid]["reason"] == "retry budget exhausted"
    assert out[rid]["tokens"] == [] and out[rid]["retries"] == 3
    assert out[ok_rid]["status"] is RequestStatus.COMPLETED
    assert len(out[ok_rid]["tokens"]) == 6        # neighbor rides through
    assert sched.failed == 1 and sched.recovered == 0
    sched.check()
    assert sched.allocator.used_blocks == 0


def test_alloc_drought_stall_and_watchdog(dense_parts):
    """An injected allocator drought turns into one round of plain
    backpressure (requests admit next round and complete), an injected
    flush stall trips the watchdog, and robustness_stats reports it all."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=2, block_size=8),
                         manager=_manager())
    rng = np.random.default_rng(23)
    faults = FaultSchedule(alloc_at=(1,), stall_at=(0,), stall_s=0.05)
    sched = ContinuousScheduler(srv, quantum=2, faults=faults,
                                watchdog_s=0.02, paranoid=True)
    rids = [sched.submit(Request(
        tokens=rng.integers(0, cfg.vocab, 8).astype(np.int32), max_new=5))
        for _ in range(2)]
    out = sched.run()
    assert all(out[r]["status"] is RequestStatus.COMPLETED and
               len(out[r]["tokens"]) == 5 for r in rids)
    stats = sched.robustness_stats()
    assert stats["alloc_injected_rounds"] == 1
    assert stats["injected_stall"] == 1
    assert stats["watchdog_stalls"] >= 1          # the 50 ms stall, at least
    assert sched.watchdog.flagged
    assert stats["cancelled"] == stats["failed"] == 0
    sched.check()
    assert sched.allocator.used_blocks == 0

"""Speculative decoding: draft/verify windows, token-identical to greedy.

The oracle-first contract of the speculative serving mode:

* **token identity** — every token a speculative scheduler delivers is
  exactly the token greedy stepwise decode would emit, at kv16 and kv8,
  for every draft depth ``k`` — acceptance only changes *when* tokens
  arrive, never *which*;
* **boundary exactness** — ``draft_override`` forces the acceptance
  boundaries (0 accepted, all-``k`` accepted, accept-then-done inside a
  window, quota clamp, per-row opt-out) and each must deliver precisely
  ``m = min(accepted + 1, remaining, quota)`` greedy tokens;
* **rollback is invisible** — after any pattern of rejected drafts, the
  carry (tok/pos) and every valid KV cache position (payload, token_idx,
  int-KV scales) bit-match a row that never speculated;
* **structural invariants survive** — ONE pool-lifetime segment
  executable, ≤2 prefill waves per admission round, zero stepwise
  ``_decode`` dispatches (SchedulerAudit / DispatchAudit);
* **accepted-token billing** (invariant 11) — the ledger bills verified
  delivered tokens only: replaying the planned ``events`` stream
  (select-exact) and the ``spec_billed`` actuals stream (spend-exact)
  through a fresh manager reproduces the ledger to float precision;
* **cross-feature** — speculation composes with preemption/resume,
  cancellation, NaN-fault quarantine/recovery, and CoW shared-prefix
  admission: terminal statuses, billed ≡ delivered, zero leaked blocks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.budgets import MAX_PREFILL_WAVES_PER_ROUND
from repro.analysis.tracker import DispatchAudit, SchedulerAudit
from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import Profile, paper_profiles, profile_table
from repro.models import transformer as T
from repro.serving.engine import (AdaptiveServer, Request, RequestStatus,
                                  ServingConfig)
from repro.serving.faults import FaultSchedule
from repro.serving.scheduler import ContinuousScheduler


def _build(arch="granite-3-2b"):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


@pytest.fixture(scope="module")
def dense_parts():
    return _build()


def _manager():
    stats = [ProfileStats(n, acc, e, 1e-3) for n, acc, e in [
        ("A16-W8", 0.99, 4.0), ("A16-W4", 0.953, 2.0), ("A8-W8", 0.988, 3.0),
        ("A8-W4", 0.953, 1.5), ("A4-W4", 0.958, 1.0), ("Mixed", 0.975, 2.0)]]
    return ProfileManager(stats, accuracy_target=0.985, accuracy_floor=0.90,
                          budget_j=150.0, low_energy=0.5)


def _solo_tokens(parts, req, kv_bits=16, slots=64):
    cfg, params, eng = parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=slots, max_batch=4,
                                       kv_bits=kv_bits))
    return srv.generate(req.tokens[None, :], req.max_new)["tokens"][0]


# solo oracles for a whole request list in ONE ragged dense generate (each
# row emits exactly its solo stream — the ragged-identity contract proven
# in test_serving_ragged), memoized across the k-parametrized cases
_SOLO_MEMO: dict = {}


def _solo_batch(parts, reqs, kv_bits):
    key = (kv_bits, tuple((r.tokens.tobytes(), len(r.tokens), r.max_new)
                          for r in reqs))
    if key in _SOLO_MEMO:
        return _SOLO_MEMO[key]
    cfg, params, eng = parts
    pl = np.asarray([len(r.tokens) for r in reqs], np.int32)
    length, mn = int(pl.max()), max(r.max_new for r in reqs)
    prompts = np.zeros((len(reqs), length), np.int32)
    for i, r in enumerate(reqs):
        prompts[i, length - len(r.tokens):] = r.tokens      # left-pad
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=length + mn + 2,
                                       max_batch=len(reqs), kv_bits=kv_bits,
                                       paged_kv=False))
    out = srv.generate(prompts, mn, prompt_len=pl,
                       row_budget=np.asarray([r.max_new for r in reqs]))
    _SOLO_MEMO[key] = [row[:r.max_new]
                       for row, r in zip(out["tokens"], reqs)]
    return _SOLO_MEMO[key]


def _assert_accepted_token_billing(sched, results):
    """Invariant 11 without a manager: every spec-billed token was
    delivered (the admission wave delivers each live row's first token;
    every other delivered token is billed through ``spec_billed``)."""
    live = [r for r in results if r and r["tokens"]]
    delivered = sum(len(r["tokens"]) for r in live)
    assert sum(n for _, n in sched.spec_billed) == delivered - len(live)


# ---------------------------------------------------------------------------
# drafter unit tests (pure jnp, no model)
# ---------------------------------------------------------------------------

def test_ngram_propose_periodic_cycle_exact():
    """A row whose history ends in a period-p cycle proposes the exact
    continuation — including wrapping past its own tail when p < k."""
    hn, vocab = 32, 100
    row3 = [-1] * (hn - 9) + [5, 7, 9, 5, 7, 9, 5, 7, 9]    # period 3
    row2 = [-1] * (hn - 6) + [3, 8, 3, 8, 3, 8]             # period 2 < k
    hist = jnp.asarray([row3, row2], jnp.int32)
    tok = jnp.asarray([9, 8], jnp.int32)
    prop = np.asarray(T.ngram_propose(hist, tok, 4, vocab))
    assert prop[0].tolist() == [5, 7, 9, 5]
    assert prop[1].tolist() == [3, 8, 3, 8]


def test_ngram_propose_fresh_history_repeats_current():
    """No match (fresh row: all pad + the current token) falls back to
    repeating the current token — never proposes from the −1 pad."""
    hn = 32
    hist = jnp.full((1, hn), -1, jnp.int32).at[0, -1].set(42)
    prop = np.asarray(T.ngram_propose(hist, jnp.asarray([42], jnp.int32),
                                      3, 100))
    assert prop[0].tolist() == [42, 42, 42]


def test_ngram_propose_longest_suffix_beats_recency():
    """A 2-gram context match earlier in history beats a more recent
    1-gram match — the longest-suffix weighting disambiguates branchy
    repeats a plain follower vote cannot."""
    hn = 32
    # ... a b F1 ... z b F2 ... a b   (current = b, previous = a)
    row = [-1] * (hn - 8) + [10, 11, 70, 4, 11, 80, 10, 11]
    prop = np.asarray(T.ngram_propose(jnp.asarray([row], jnp.int32),
                                      jnp.asarray([11], jnp.int32), 1, 100))
    assert prop[0, 0] == 70        # follower of the (a, b) bigram match


def test_ngram_propose_most_recent_tie_break():
    """Equal-length matches resolve to the most recent occurrence: the
    (10, 11) bigram appears twice with different followers, and the
    drafter proposes the later one's follower."""
    hn = 32
    row = [-1] * (hn - 9) + [10, 11, 70, 4, 10, 11, 80, 10, 11]
    prop = np.asarray(T.ngram_propose(jnp.asarray([row], jnp.int32),
                                      jnp.asarray([11], jnp.int32), 1, 100))
    assert prop[0, 0] == 80        # follower of the most recent (10, 11)


def test_ngram_propose_k_zero_empty():
    hist = jnp.full((2, 8), -1, jnp.int32)
    prop = T.ngram_propose(hist, jnp.zeros((2,), jnp.int32), 0, 10)
    assert prop.shape == (2, 0)


# ---------------------------------------------------------------------------
# acceptance boundaries: direct decode_segment_spec with draft_override
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def seg_state(dense_parts):
    """Prefilled dense kv16 state + its greedy reference stream."""
    cfg, params, _ = dense_parts
    names = T.quant_layer_names(cfg)
    table = jnp.asarray(profile_table([Profile.float32(names)], names))
    rng = np.random.default_rng(11)
    b, plen, steps = 3, 8, 16
    prompts = rng.integers(0, cfg.vocab, (b, plen)).astype(np.int32)
    logits, caches = T.prefill(params, cfg, table[0],
                               {"tokens": jnp.asarray(prompts)}, slots=48,
                               kv_bits=16)
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos0 = jnp.full((b,), plen, jnp.int32)
    ys, ok, gt, gp, _ = T.decode_segment(
        params, cfg, table, jnp.zeros((steps,), jnp.int32), tok0, pos0,
        caches, jnp.full((b,), steps, jnp.int32))
    assert bool(np.all(np.asarray(ok)))
    return {"cfg": cfg, "params": params, "table": table, "b": b,
            "plen": plen, "steps": steps, "tok0": tok0, "pos0": pos0,
            "caches": caches, "g": np.asarray(ys)}


def _spec_window(st, dov, *, n_iter=1, k=3, remaining=None, quota=None,
                 spec_on=None):
    b = st["b"]
    rem = (jnp.full((b,), st["steps"], jnp.int32) if remaining is None
           else jnp.asarray(remaining, jnp.int32))
    out = T.decode_segment_spec(
        st["params"], st["cfg"], st["table"],
        jnp.zeros((n_iter,), jnp.int32), st["tok0"], st["pos0"],
        st["caches"], rem, quota=quota, spec_on=spec_on, draft_k=k,
        draft_override=None if dov is None else jnp.asarray(dov, jnp.int32))
    toks, m, ok, tok, pos, caches = out
    assert bool(np.all(np.asarray(ok)))
    return (np.asarray(toks), np.asarray(m), np.asarray(tok),
            np.asarray(pos), caches)


def test_spec_zero_accepted_still_delivers_greedy_token(seg_state):
    """All-wrong drafts: m = 1 and the one delivered token is exactly the
    greedy token — the rejected tail is −1-padded out."""
    st, k = seg_state, 3
    g, b = st["g"], st["b"]
    dov = ((g[:, :k] + 1) % st["cfg"].vocab)[:, None, :]   # [B, 1, k] wrong
    toks, m, tok, pos, _ = _spec_window(st, dov, k=k)
    assert m[:, 0].tolist() == [1] * b
    assert np.array_equal(toks[:, 0, 0], g[:, 0])
    assert np.all(toks[:, 0, 1:] == -1)
    assert np.array_equal(tok, g[:, 0])
    assert pos.tolist() == [st["plen"] + 1] * b


def test_spec_rollback_then_continue_matches_greedy(seg_state):
    """After a fully-rejected window, continuing with the NATURAL drafter
    still reproduces the greedy stream — rejected cache junk is invisible
    to every later window (the rollback contract, end to end)."""
    st, k = seg_state, 3
    g, b = st["g"], st["b"]
    dov = ((g[:, :k] + 1) % st["cfg"].vocab)[:, None, :]
    out = T.decode_segment_spec(
        st["params"], st["cfg"], st["table"], jnp.zeros((1,), jnp.int32),
        st["tok0"], st["pos0"], st["caches"],
        jnp.full((b,), st["steps"], jnp.int32), draft_k=k,
        draft_override=jnp.asarray(dov, jnp.int32))
    _, m1, tok1, pos1, cch1 = out[0], np.asarray(out[1]), out[3], out[4], \
        out[5]
    toks2, m2, _, _, _, _ = T.decode_segment_spec(
        st["params"], st["cfg"], st["table"], jnp.zeros((3,), jnp.int32),
        tok1, pos1, cch1, jnp.full((b,), st["steps"] - 1, jnp.int32),
        draft_k=k)
    toks2, m2 = np.asarray(toks2), np.asarray(m2)
    for r in range(b):
        seq = [int(t) for it in range(3) for t in toks2[r, it, :m2[r, it]]]
        assert seq == st["g"][r, 1:1 + len(seq)].tolist(), f"row {r}"
        assert len(seq) >= 3           # every window delivers >= 1


def test_spec_all_k_accepted_full_window(seg_state):
    """Exact drafts: the whole window lands — k accepted + the bonus
    token, all equal to the greedy stream."""
    st, k = seg_state, 3
    g, b = st["g"], st["b"]
    toks, m, tok, pos, _ = _spec_window(st, g[:, :k][:, None, :], k=k)
    assert m[:, 0].tolist() == [k + 1] * b
    assert np.array_equal(toks[:, 0, :], g[:, :k + 1])
    assert np.array_equal(tok, g[:, k])
    assert pos.tolist() == [st["plen"] + k + 1] * b


def test_spec_accept_then_done_inside_window(seg_state):
    """A row with remaining=2 accepts a full window but delivers only 2
    tokens (budget clamp), then freezes: the next window delivers 0."""
    st, k = seg_state, 3
    g, b = st["g"], st["b"]
    dov = np.repeat(g[:, :k][:, None, :], 2, axis=1)       # [B, 2, k]
    toks, m, tok, pos, _ = _spec_window(st, dov, n_iter=2, k=k,
                                        remaining=np.full((b,), 2))
    assert m[:, 0].tolist() == [2] * b and m[:, 1].tolist() == [0] * b
    assert np.array_equal(toks[:, 0, :2], g[:, :2])
    assert np.all(toks[:, 0, 2:] == -1) and np.all(toks[:, 1] == -1)
    assert np.array_equal(tok, g[:, 1])
    assert pos.tolist() == [st["plen"] + 2] * b


def test_spec_quota_and_opt_out_clamp_to_one(seg_state):
    """quota=1 (fairness quantum in accepted tokens) and spec_on=False
    (per-class opt-out) each clamp a perfect window to m = 1."""
    st, k = seg_state, 3
    g, b = st["g"], st["b"]
    dov = g[:, :k][:, None, :]
    _, m_q, _, _, _ = _spec_window(st, dov, k=k,
                                   quota=jnp.ones((b,), jnp.int32))
    assert m_q[:, 0].tolist() == [1] * b
    _, m_s, _, _, _ = _spec_window(st, dov, k=k,
                                   spec_on=jnp.zeros((b,), bool))
    assert m_s[:, 0].tolist() == [1] * b


# ---------------------------------------------------------------------------
# scheduler: spec == greedy == solo, every k, both KV precisions
# ---------------------------------------------------------------------------

def _mixed_requests(cfg, seed=3):
    rng = np.random.default_rng(seed)
    spec = [(8, 12), (5, 9), (12, 1), (7, 17), (9, 5), (6, 12)]
    return [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=mn) for n, mn in spec]


@pytest.mark.parametrize("kv_bits,k", [(16, 1), (16, 2), (16, 4),
                                       (8, 1), (8, 2), (8, 4)])
def test_spec_scheduler_token_identity(dense_parts, kv_bits, k):
    """A speculative continuous scheduler is token-identical to each
    request's solo greedy run — mixed prompt lengths, mixed budgets
    (including max_new=1, which never enters a window), admission
    backpressure, paged pool — at every draft depth and KV precision."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4,
                                       kv_bits=kv_bits, block_size=8,
                                       speculate=True, draft_k=k))
    sched = ContinuousScheduler(srv, quantum=5)
    reqs = _mixed_requests(cfg)
    for r in reqs:
        sched.submit(r)
    out = sched.run()
    solos = _solo_batch(dense_parts, reqs, kv_bits)
    for rid, req in enumerate(reqs):
        assert out[rid]["status"] is RequestStatus.COMPLETED
        assert out[rid]["tokens"] == solos[rid], f"rid={rid} k={k}"
        assert len(out[rid]["tokens"]) == req.max_new
    _assert_accepted_token_billing(sched, out)
    sched.check()
    assert sched.allocator.used_blocks == 0


def test_spec_invariants_single_segment_no_stepwise(dense_parts):
    """Structural invariants under speculation: ONE pool-lifetime segment
    executable (no retrace across rounds), ≤2 prefill waves per admission
    round, and zero per-token ``_decode`` dispatches."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4, block_size=8,
                                       speculate=True, draft_k=4))
    sched = ContinuousScheduler(srv, quantum=8)
    reqs = _mixed_requests(cfg, seed=5)
    with SchedulerAudit(sched) as audit, \
            DispatchAudit(srv, ["_decode"]) as daudit:
        daudit.forbid("_decode")           # stepwise decode is a regression
        for r in reqs:
            sched.submit(r)
        while sched.step():
            pass
        audit.assert_max_prefill_waves(MAX_PREFILL_WAVES_PER_ROUND)
        audit.assert_single_segment()
    assert srv._segment._cache_size() == 1


# ---------------------------------------------------------------------------
# invariant 11: accepted-token billing, ledger replay oracle
# ---------------------------------------------------------------------------

def test_spec_ledger_replay_planned_and_actuals_exact(dense_parts):
    """The spec ledger replays exactly: the planned ``events`` stream is
    select-exact against a fresh oracle (each round planned provisionally
    from the post-flush ledger state, then rolled back), and the
    ``spec_billed`` actuals stream is spend-exact — the final ledger
    matches to float precision and every billed token was delivered."""
    cfg, params, eng = dense_parts
    mgr = _manager()
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4, block_size=8,
                                       speculate=True, draft_k=3),
                         manager=mgr)
    quantum, w = 6, 4
    n_iter = -(-quantum // w)
    sched = ContinuousScheduler(srv, quantum=quantum)
    rng = np.random.default_rng(7)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=mn)
            for n, mn in [(8, 5), (5, 9), (11, 14), (7, 3)]]
    for r in reqs:                  # all up front: ONE admission wave
        sched.submit(r)
    out = sched.run()
    assert all(r["status"] is RequestStatus.COMPLETED for r in out)

    events, billed = sched.events, sched.spec_billed
    assert len(events) == 1 + len(billed)       # 1 admit + planned windows
    assert len(billed) % n_iter == 0
    oracle = _manager()
    pid, n, crit = events[0]                    # the admission wave
    assert oracle.select(accuracy_critical=crit) == pid
    oracle.account(pid, n)
    for r in range(len(billed) // n_iter):
        spent0, saver0 = oracle.spent_j, oracle._saver
        for i in range(n_iter):                 # planned: select-exact
            pid, n, crit = events[1 + r * n_iter + i]
            assert oracle.select(accuracy_critical=crit) == pid
            oracle.account(pid, n)
        oracle.spent_j, oracle._saver = spent0, saver0   # plan was
        for i in range(n_iter):                 # provisional; bill actuals
            pid_a, n_a = billed[r * n_iter + i]
            assert pid_a == events[1 + r * n_iter + i][0]
            assert n_a >= 0
            oracle.account(pid_a, n_a)
        # the plan is optimistic (full-w acceptance): a late window can
        # bill more than planned, but never the round as a whole
        assert sum(billed[r * n_iter + i][1] for i in range(n_iter)) <= \
            sum(events[1 + r * n_iter + i][1] for i in range(n_iter))
    assert abs(oracle.spent_j - mgr.spent_j) < 1e-9
    # accepted-token billing: admission first-tokens + spec actuals cover
    # exactly the delivered tokens, never drafted-rejected overshoot
    assert events[0][1] + sum(n for _, n in billed) \
        == sum(r.max_new for r in reqs)


# ---------------------------------------------------------------------------
# property-based rollback: random accept prefixes vs a never-speculated twin
# ---------------------------------------------------------------------------

def _masked_kv_equal(spec_kv, twin_kv, end_pos, scales_exact=True):
    """Bit-compare every cache leaf at the real-token positions: logical
    position < the row's final ``pos`` (``end_pos [B]``). One slot past
    that is where BOTH paths park junk — the twin's frozen rows write
    there every dead step (by design: the parked write keeps a dead row
    off the ring), the spec path leaves its last window's rejected
    drafts there — so it carries a valid-looking ``token_idx`` with
    unspecified payload and is excluded, like the never-written tail.

    The int-KV running amax scales are per-row, not per-position: a twin
    that dead-steps folds its parked junk writes into the running max,
    so a freeze trial can only assert the one-sided rollback claim —
    spec's COMMITTED scale never exceeds the twin's (rejected drafts
    never reach it). ``scales_exact=True`` (a twin with zero dead steps)
    upgrades that to bitwise equality."""
    ti = np.asarray(twin_kv.token_idx)                      # [L, B, S]
    valid = (ti >= 0) & (ti < np.asarray(end_pos)[None, :, None])
    for name in ("k", "v", "token_idx", "k_scale", "v_scale"):
        a_s = np.asarray(getattr(spec_kv, name))
        a_t = np.asarray(getattr(twin_kv, name))
        assert a_s.shape == a_t.shape, name
        if a_s.ndim >= 3 and a_s.shape[:3] == valid.shape:
            m = valid.reshape(valid.shape + (1,) * (a_s.ndim - 3))
            assert np.array_equal(np.where(m, a_s, 0),
                                  np.where(m, a_t, 0)), name
        elif name in ("k_scale", "v_scale") and not scales_exact:
            assert np.all(a_s <= a_t), name
        else:
            assert np.array_equal(a_s, a_t), name


@pytest.mark.parametrize("kv_bits", [16, 8])
def test_property_rollback_bitmatch_never_speculated(dense_parts, kv_bits):
    """Random draft proposals with random accept prefixes: after any
    rollback pattern, delivered tokens, per-window counts, carry (tok,
    pos) and every valid KV position — payload, token_idx, and int-KV
    scales — bit-match a row that never speculated."""
    cfg, params, _ = dense_parts
    names = T.quant_layer_names(cfg)
    table = jnp.asarray(profile_table([Profile.float32(names)], names))
    b, plen, steps, k, n_iter = 2, 6, 16, 3, 3
    rng0 = np.random.default_rng(31)
    prompts = rng0.integers(0, cfg.vocab, (b, plen)).astype(np.int32)
    logits, caches = T.prefill(params, cfg, table[0],
                               {"tokens": jnp.asarray(prompts)}, slots=32,
                               kv_bits=kv_bits)
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos0 = jnp.full((b,), plen, jnp.int32)

    twin_fn = jax.jit(lambda rem: T.decode_segment(
        params, cfg, table, jnp.zeros((steps,), jnp.int32), tok0, pos0,
        caches, rem))
    spec_fn = jax.jit(lambda dov, rem: T.decode_segment_spec(
        params, cfg, table, jnp.zeros((n_iter,), jnp.int32), tok0, pos0,
        caches, rem, draft_k=k, draft_override=dov))

    ys, ok, _, _, _ = twin_fn(jnp.full((b,), steps, jnp.int32))
    assert bool(np.all(np.asarray(ok)))
    g = np.asarray(ys)                        # greedy stream after tok0

    for trial in range(6):
        rng = np.random.default_rng(100 + trial)
        rem_r = rng.integers(3, 10, b)
        dov = np.full((b, n_iter, k), -1, np.int32)
        exp = [[] for _ in range(b)]
        exp_m = np.zeros((b, n_iter), np.int32)
        p = np.zeros(b, int)                  # delivered so far per row
        remaining = rem_r.copy()
        for it in range(n_iter):
            for r in range(b):
                if remaining[r] <= 0:
                    continue
                a = int(rng.integers(0, k + 1))    # forced accept prefix
                for j in range(k):
                    true = int(g[r, p[r] + j])
                    dov[r, it, j] = true if j < a \
                        else (true + 1) % cfg.vocab
                m = min(a + 1, int(remaining[r]))
                exp[r].extend(int(t) for t in g[r, p[r]:p[r] + m])
                exp_m[r, it] = m
                p[r] += m
                remaining[r] -= m
        toks, m, ok, tok, pos, cch = spec_fn(
            jnp.asarray(dov), jnp.asarray(rem_r, jnp.int32))
        toks, m = np.asarray(toks), np.asarray(m)
        assert bool(np.all(np.asarray(ok)))
        assert np.array_equal(m, exp_m), f"trial {trial}"
        for r in range(b):
            got = [int(t) for it in range(n_iter)
                   for t in toks[r, it, :m[r, it]]]
            assert got == exp[r], f"trial {trial} row {r}"
            assert np.all(toks[r][np.arange(k + 1)[None] >= m[r][:, None]]
                          == -1)
        # carry: spec keeps the last DELIVERED token even after a row
        # freezes (the twin's carry feeds 0 for frozen rows, so the
        # greedy stream itself is the tok oracle); pos freezes in both
        assert np.asarray(tok).tolist() == \
            [int(g[r, p[r] - 1]) for r in range(b)]
        _, _, _, t_pos, t_cch = twin_fn(jnp.asarray(p, jnp.int32))
        assert np.array_equal(np.asarray(pos), np.asarray(t_pos))
        _masked_kv_equal(cch["kv"], t_cch["kv"], plen + p,
                         scales_exact=False)

    # exact-fill trials: random window compositions that deliver EXACTLY
    # T tokens per row, so the twin (T steps, remaining=T) takes zero
    # dead steps — no parked junk anywhere — and the cache comparison
    # upgrades to full bitwise equality INCLUDING the int-KV committed
    # scales: rejected drafts provably never reached the running amax
    nf = 8
    twin_exact = jax.jit(lambda: T.decode_segment(
        params, cfg, table, jnp.zeros((nf,), jnp.int32), tok0, pos0,
        caches, jnp.full((b,), nf, jnp.int32)))
    _, _, e_tok, e_pos, e_cch = twin_exact()
    for trial in range(4):
        rng = np.random.default_rng(200 + trial)
        dov = np.full((b, n_iter, k), -1, np.int32)
        exp_m = np.zeros((b, n_iter), np.int32)
        for r in range(b):
            while True:      # composition of nf into n_iter parts of [1, W]
                m1, m2 = rng.integers(1, k + 2, 2)
                if 1 <= nf - m1 - m2 <= k + 1:
                    break
            parts, q = [int(m1), int(m2), nf - int(m1) - int(m2)], 0
            for it, mi in enumerate(parts):
                for j in range(k):
                    true = int(g[r, q + j])
                    dov[r, it, j] = true if j < mi - 1 \
                        else (true + 1) % cfg.vocab
                exp_m[r, it] = mi
                q += mi
        toks, m, ok, tok, pos, cch = spec_fn(
            jnp.asarray(dov), jnp.full((b,), nf, jnp.int32))
        assert bool(np.all(np.asarray(ok)))
        assert np.array_equal(np.asarray(m), exp_m), f"exact trial {trial}"
        for r in range(b):
            got = [int(t) for it in range(n_iter)
                   for t in np.asarray(toks)[r, it, :exp_m[r, it]]]
            assert got == g[r, :nf].tolist(), f"exact trial {trial} row {r}"
        assert np.array_equal(np.asarray(tok), np.asarray(e_tok))
        assert np.array_equal(np.asarray(pos), np.asarray(e_pos))
        _masked_kv_equal(cch["kv"], e_cch["kv"], plen + np.full(b, nf),
                         scales_exact=True)


def test_property_spec_paranoid_pool_random_workloads(dense_parts):
    """Seeded random workloads through a paranoid spec scheduler: the
    BlockAllocator refcount audit runs after every step, completions are
    full-length, billing covers exactly the delivered tokens, and the
    pool drains to zero."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4, block_size=8,
                                       speculate=True, draft_k=2))
    for seed in (0, 1):
        rng = np.random.default_rng(50 + seed)
        sched = ContinuousScheduler(srv, quantum=5, paranoid=True)
        reqs = [Request(tokens=rng.integers(0, cfg.vocab, int(n))
                        .astype(np.int32), max_new=int(mn))
                for n, mn in zip(rng.integers(4, 13, 7),
                                 rng.integers(1, 15, 7))]
        for r in reqs:
            sched.submit(r)
        out = sched.run()
        for rid, req in enumerate(reqs):
            assert out[rid]["status"] is RequestStatus.COMPLETED
            assert len(out[rid]["tokens"]) == req.max_new
        _assert_accepted_token_billing(sched, out)
        sched.check()
        assert sched.allocator.used_blocks == 0


# ---------------------------------------------------------------------------
# cross-feature matrix: speculation × {preemption, cancel, faults, CoW}
# ---------------------------------------------------------------------------

def test_spec_preempt_resume_token_identity(dense_parts):
    """Speculation × preemption: a saver row evicted for a critical
    arrival resumes and still emits its exact solo stream; statuses
    terminal, billed ≡ delivered, zero leaked blocks."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=2, block_size=8,
                         priority_classes=2, preemption=True,
                         speculate=True, draft_k=2)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(17)
    sys_p = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    reqs = [Request(tokens=np.concatenate(
                [sys_p, rng.integers(0, cfg.vocab, 4).astype(np.int32)]),
                max_new=18, priority=1),
            Request(tokens=np.concatenate(
                [sys_p, rng.integers(0, cfg.vocab, 7).astype(np.int32)]),
                max_new=16, priority=1)]
    crit = Request(tokens=rng.integers(0, cfg.vocab, 7).astype(np.int32),
                   max_new=4, priority=0)
    sched.submit(reqs[0])
    sched.step()
    sched.submit(reqs[1])
    sched.step()
    sched.step()
    sched.submit(crit)               # pool full → policy evicts a saver
    reqs.append(crit)
    out = sched.run()
    assert sched.preemptions >= 1 and sched.resumes == sched.preemptions
    for rid, req in enumerate(reqs):
        assert out[rid]["status"] is RequestStatus.COMPLETED
        assert out[rid]["tokens"] == _solo_tokens(dense_parts, req), \
            f"rid={rid}"
    _assert_accepted_token_billing(sched, out)
    sched.check()
    assert sched.allocator.used_blocks == 0


def test_spec_cancel_mid_draft_window(dense_parts):
    """Speculation × cancellation: a row cancelled mid-stream keeps its
    delivered prefix (a prefix of the solo stream), a queued cancel
    delivers nothing, the survivor completes identically — and the
    ledger billed exactly what was delivered."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=2, block_size=8,
                                       speculate=True, draft_k=3))
    sched = ContinuousScheduler(srv, quantum=8, paranoid=True)
    rng = np.random.default_rng(23)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=24) for n in (9, 7, 8)]
    for r in reqs:
        sched.submit(r)
    sched.step()                     # rows 0/1 live, mid-generation
    assert sched.cancel(0) and sched.cancel(2)
    out = sched.run()
    assert out[0]["status"] is RequestStatus.CANCELLED
    assert out[2]["status"] is RequestStatus.CANCELLED
    assert out[1]["status"] is RequestStatus.COMPLETED
    solo0 = _solo_tokens(dense_parts, reqs[0])
    assert 0 < len(out[0]["tokens"]) < 24
    assert out[0]["tokens"] == solo0[:len(out[0]["tokens"])]
    assert out[2]["tokens"] == []
    assert out[1]["tokens"] == _solo_tokens(dense_parts, reqs[1])
    _assert_accepted_token_billing(sched, out)
    sched.check()
    assert sched.allocator.used_blocks == 0


def test_spec_nan_verify_quarantine_recovers(dense_parts):
    """Speculation × faults: NaN anywhere in a verify window (even at
    would-be-rejected positions) routes the row through quarantine; the
    escalated retry restarts from the prompt and the recovered output is
    token-identical to a clean accuracy-critical run. Zero leaks, the
    neighbour rides through untouched."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=2, block_size=8,
                                       speculate=True, draft_k=2),
                         manager=_manager())
    faults = FaultSchedule(nan_at={0: (0,)})
    sched = ContinuousScheduler(srv, quantum=4, faults=faults,
                                retry_budget=2, paranoid=True)
    rng = np.random.default_rng(29)
    p0 = rng.integers(0, cfg.vocab, 11).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    rid = sched.submit(Request(tokens=p0, max_new=8))
    ok_rid = sched.submit(Request(tokens=p1, max_new=6))
    out = sched.run()
    assert out[rid]["status"] is RequestStatus.COMPLETED
    assert out[rid]["retries"] == 1 and len(out[rid]["tokens"]) == 8
    assert sched.faults_detected >= 1 and sched.recovered == 1
    assert faults.injected_nan == 1
    assert out[ok_rid]["status"] is RequestStatus.COMPLETED
    assert len(out[ok_rid]["tokens"]) == 6
    sched.check()
    assert sched.allocator.used_blocks == 0
    # clean accuracy-critical twin on the same server (same executables)
    clean = ContinuousScheduler(srv, quantum=4)
    crid = clean.submit(Request(tokens=p0, max_new=8,
                                accuracy_critical=True))
    assert clean.run()[crid]["tokens"] == out[rid]["tokens"]
    assert srv._segment._cache_size() == 1


def test_spec_shared_prefix_cow_rows(dense_parts):
    """Speculation × CoW prefix sharing: the second request maps the
    registered prefix blocks copy-on-write, both rows speculate over the
    shared pool, and both still emit exact solo streams with zero leaks
    and exact billing."""
    cfg, params, eng = dense_parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=2, block_size=8,
                                       speculate=True, draft_k=2))
    sched = ContinuousScheduler(srv, quantum=5, paranoid=True)
    rng = np.random.default_rng(37)
    sys_p = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    reqs = [Request(tokens=np.concatenate(
                [sys_p, rng.integers(0, cfg.vocab, n).astype(np.int32)]),
                max_new=mn) for n, mn in [(5, 12), (8, 10)]]
    sched.submit(reqs[0])
    sched.step()                     # registers the shared prefix
    sched.submit(reqs[1])            # maps it CoW
    out = sched.run()
    assert sched.registry is not None and sched.registry.hits >= 1
    for rid, req in enumerate(reqs):
        assert out[rid]["status"] is RequestStatus.COMPLETED
        assert out[rid]["tokens"] == _solo_tokens(dense_parts, req), \
            f"rid={rid}"
    _assert_accepted_token_billing(sched, out)
    sched.check()
    assert sched.allocator.used_blocks == 0

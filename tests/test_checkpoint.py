"""Checkpointing: atomic commit, retention, torn-write GC, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, latest_step, restore, save


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layers": {"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros(8)},
            "step_scalar": jnp.asarray(3, jnp.int32)}


def test_roundtrip_bit_exact(tmp_path):
    t = _tree()
    save(str(tmp_path), 5, t, {"note": "x"})
    like = jax.tree.map(lambda a: jnp.zeros_like(a), t)
    out, meta = restore(str(tmp_path), like)
    assert meta["step"] == 5 and meta["metadata"]["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.latest_step() == 4
    kept = sorted(n for n in os.listdir(tmp_path) if n.endswith(".DONE"))
    assert len(kept) == 2  # keep-N retention


def test_torn_write_ignored(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    # simulate a torn write: directory without commit marker
    os.makedirs(tmp_path / "step_000000002")
    with open(tmp_path / "step_000000002" / "arrays.npz", "wb") as f:
        f.write(b"garbage")
    assert latest_step(str(tmp_path)) == 1  # torn step invisible
    out, meta = restore(str(tmp_path), jax.tree.map(jnp.zeros_like, t))
    assert meta["step"] == 1


def test_restore_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"w": jnp.zeros((3, 3))})


def test_missing_leaf_raises(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(KeyError):
        restore(str(tmp_path), {"w": jnp.zeros((2, 2)), "extra": jnp.zeros(1)})


def test_elastic_restore_with_shardings(tmp_path):
    """Restore re-places leaves on explicit device placements (the elastic
    rescale path: same bytes, different mesh)."""
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save(str(tmp_path), 2, t)
    dev = jax.devices()[0]
    out, _ = restore(str(tmp_path), jax.tree.map(jnp.zeros_like, t),
                     shardings={"w": dev})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))
    assert out["w"].devices() == {dev}


def test_manifest_detects_silent_corruption(tmp_path):
    """A bit-rotted leaf fails its manifest crc32: strict restore raises
    the named CheckpointIntegrityError; strict=False drops the leaf,
    lists it in meta["corrupt_keys"], and keeps every healthy leaf —
    the serving recovery path's per-row fallback contract."""
    from repro.checkpoint.manager import CheckpointIntegrityError
    t = {"good": jnp.arange(8.0), "bad": jnp.ones((3, 3))}
    save(str(tmp_path), 1, t)
    sdir = tmp_path / "step_000000001"
    with np.load(sdir / "arrays.npz") as z:
        flat = {n: z[n] for n in z.files}
    flat["bad"] = flat["bad"] + 1.0           # same shape/dtype, new bytes
    np.savez(sdir / "arrays.npz", **flat)
    with pytest.raises(CheckpointIntegrityError, match="bad"):
        restore(str(tmp_path))
    out, meta = restore(str(tmp_path), strict=False)
    assert meta["corrupt_keys"] == ["bad"]
    assert "bad" not in out
    np.testing.assert_array_equal(np.asarray(out["good"]), np.arange(8.0))


def test_restore_falls_back_when_gc_wins_race(tmp_path):
    """A commit marker whose payload directory vanished (retention _gc
    removes the marker first, but a lister may hold a stale snapshot)
    must not wedge restore: it falls back to the next older committed
    step instead of failing on the half-deleted newest."""
    import shutil
    save(str(tmp_path), 1, {"w": jnp.zeros(4)})
    save(str(tmp_path), 2, {"w": jnp.ones(4)})
    shutil.rmtree(tmp_path / "step_000000002")    # gc raced: dir gone,
    # marker still on disk (the stale-listing window)
    assert os.path.exists(tmp_path / "step_000000002.DONE")
    out, meta = restore(str(tmp_path))
    assert meta["step"] == 1
    np.testing.assert_array_equal(np.asarray(out["w"]), np.zeros(4))
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), step=2)            # explicit step: loud

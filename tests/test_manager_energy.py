"""Profile Manager policy + energy/roofline model."""
import numpy as np
import pytest

from repro.core.energy import TPU_V5E, activity_factor, roofline_terms, step_energy
from repro.core.manager import ProfileManager, ProfileStats, battery_simulation

STATS = [
    ProfileStats("hi", accuracy=0.99, energy_j=2.0, latency_s=1e-3),
    ProfileStats("lo", accuracy=0.95, energy_j=1.0, latency_s=1e-3),
]


def test_roofline_terms_dominance():
    t = roofline_terms(flops=1e15, hbm_bytes=1e9, coll_bytes=1e6, chips=1)
    assert t["dominant"] == "compute_s"
    t = roofline_terms(flops=1e9, hbm_bytes=1e13, coll_bytes=1e6, chips=1)
    assert t["dominant"] == "memory_s"
    t = roofline_terms(flops=1e9, hbm_bytes=1e6, coll_bytes=1e13, chips=1)
    assert t["dominant"] == "collective_s"
    assert t["t_step_s"] == max(t["compute_s"], t["memory_s"], t["collective_s"])


def test_activity_monotone_in_bits():
    a44 = activity_factor(4, 4)
    a88 = activity_factor(8, 8)
    a168 = activity_factor(16, 8)
    assert a44 < a88 < a168 <= 1.0
    assert step_energy(1.0, a44) < step_energy(1.0, a88)


def test_manager_prefers_cheapest_meeting_target():
    mgr = ProfileManager(STATS, accuracy_target=0.98, accuracy_floor=0.90,
                         budget_j=1e9)
    assert mgr.select() == 0  # only "hi" meets 0.98


def test_manager_saver_mode_and_hysteresis():
    mgr = ProfileManager(STATS, accuracy_target=0.98, accuracy_floor=0.90,
                         budget_j=100.0, low_energy=0.2, hysteresis=0.05)
    mgr.spent_j = 85.0  # 15% remaining < low_energy → saver
    assert mgr.select() == 1                      # cheapest above floor
    assert mgr.select(accuracy_critical=True) == 0  # critical overrides
    mgr.spent_j = 79.0  # 21% — inside hysteresis band, stays saver
    assert mgr.select() == 1
    mgr.spent_j = 70.0  # 30% — exits saver
    assert mgr.select() == 0


def test_zero_budget_is_unconstrained():
    """Regression: budget_j == 0 used to read as remaining_fraction 0.0,
    silently forcing battery-saver mode on an unconfigured manager. Zero
    budget must mean *unconstrained* — full fraction, no saver, target-grade
    profile selection."""
    mgr = ProfileManager(STATS, accuracy_target=0.98, accuracy_floor=0.90,
                         budget_j=0.0)
    assert mgr.remaining_fraction() == 1.0
    assert not mgr.exhausted()
    assert mgr.select() == 0        # "hi", not the saver-mode cheap profile
    assert not mgr._saver
    mgr.account(0, 100)             # spending never flips an unconstrained
    assert mgr.remaining_fraction() == 1.0
    assert not mgr.exhausted()
    assert mgr.select() == 0


def test_plan_schedule_ragged_bills_live_rows_only():
    """plan_schedule_ragged == stepwise select/account over the rows actually
    live at each step (heterogeneous budgets), not group-wide padding."""
    rem = np.asarray([5, 2, 0, 3])
    m_plan = ProfileManager(STATS, accuracy_target=0.98, accuracy_floor=0.90,
                            budget_j=40.0, low_energy=0.5)
    sched = m_plan.plan_schedule_ragged(5, rem, np.asarray([0, 1, 0, 0], bool))
    m_loop = ProfileManager(STATS, accuracy_target=0.98, accuracy_floor=0.90,
                            budget_j=40.0, low_energy=0.5)
    for i in range(5):
        live = rem > i
        pid = m_loop.select(accuracy_critical=bool(live[1]))
        m_loop.account(pid, int(live.sum()))
        assert sched[i] == pid
    assert abs(m_plan.spent_j - m_loop.spent_j) < 1e-12
    # step 0 bills 3 live rows, step 4 bills only the longest row
    assert m_plan.spent_j < sum(STATS[i].energy_j for i in sched) * 4


def test_plan_schedule_draft_window_clamps_to_row_budget():
    """Regression: a speculative draft window overshooting a row's budget
    by up to ``draft_w - 1`` must clamp its planned bill to the tokens the
    row can still emit — a row with 3 tokens left under ``draft_w=4``
    plans 3 bills for its final window, never 4 phantom ones (invariant
    11: accepted-token billing)."""
    rem = np.asarray([3, 9, 0, 5])
    w = 4
    m_plan = ProfileManager(STATS, accuracy_target=0.98, accuracy_floor=0.90,
                            budget_j=40.0, low_energy=0.5)
    sched = m_plan.plan_schedule_ragged(3, rem, draft_w=w)
    m_loop = ProfileManager(STATS, accuracy_target=0.98, accuracy_floor=0.90,
                            budget_j=40.0, low_energy=0.5)
    for i in range(3):
        pid = m_loop.select()
        # window i bills min(w, rem - i*w) per row, floored at 0 — the
        # per-row clamp a stepwise per-token oracle would apply
        m_loop.account(pid, int(np.minimum(w, np.maximum(rem - i * w, 0))
                                .sum()))
        assert sched[i] == pid
    assert abs(m_plan.spent_j - m_loop.spent_j) < 1e-12
    # total planned tokens == total row budget, exactly — no phantom bills
    total = sum(int(np.minimum(w, np.maximum(rem - i * w, 0)).sum())
                for i in range(3))
    assert total == int(rem.sum()) == 17


def test_plan_schedule_provisional_leaves_ledger_untouched():
    """``provisional=True`` plans the same profile ids but must restore the
    ledger AND the hysteresis state — the speculative flush bills actual
    delivered tokens instead."""
    rem = np.asarray([8, 8, 8, 8])
    m_real = ProfileManager(STATS, accuracy_target=0.98, accuracy_floor=0.90,
                            budget_j=40.0, low_energy=0.5)
    m_prov = ProfileManager(STATS, accuracy_target=0.98, accuracy_floor=0.90,
                            budget_j=40.0, low_energy=0.5)
    s_real = m_real.plan_schedule_ragged(2, rem, draft_w=4)
    s_prov = m_prov.plan_schedule_ragged(2, rem, draft_w=4,
                                         provisional=True)
    assert list(s_real) == list(s_prov)
    assert m_prov.spent_j == 0.0 and not m_prov._saver
    assert m_real.spent_j > 0.0


def test_manager_graceful_when_floor_unreachable():
    mgr = ProfileManager(STATS, accuracy_target=0.999, accuracy_floor=0.999,
                         budget_j=10.0)
    assert mgr.select() == 0  # degrades to most accurate, never crashes


def test_battery_adaptive_beats_fixed():
    budget = 1000.0
    adaptive = battery_simulation(STATS, budget, accuracy_target=0.98,
                                  accuracy_floor=0.90, critical_every=10)
    fixed = battery_simulation(STATS, budget, accuracy_target=0.98,
                               accuracy_floor=0.90, fixed_profile=0)
    # Fig. 4 claim: adaptive executes more classifications on the same budget
    assert adaptive["classifications"] > fixed["classifications"]
    # at a bounded accuracy cost
    assert adaptive["mean_accuracy"] > 0.95
    assert fixed["mean_accuracy"] == pytest.approx(0.99)


def test_battery_budget_exhaustion_exact():
    out = battery_simulation(STATS[:1], 10.0, 0.9, 0.9)
    assert out["classifications"] == 5  # 10 J / 2 J each

"""Rotary embeddings: RoPE properties and M-RoPE text-degeneracy."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.rotary import apply_mrope, apply_rope, text_mrope_positions


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    d = 32
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, d))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.asarray([[i]]))
        kj = apply_rope(k, jnp.asarray([[j]]))
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(5, 4)) > 1e-5


def test_mrope_text_equals_rope():
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y_rope = apply_rope(x, pos, theta=1e4)
    y_mrope = apply_mrope(x, text_mrope_positions(pos), (2, 3, 3), theta=1e4)
    np.testing.assert_allclose(np.asarray(y_rope), np.asarray(y_mrope),
                               atol=1e-5)


def test_mrope_streams_differ():
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 4, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(4)[None], (1, 4))
    p3 = text_mrope_positions(pos)
    p3_shift = p3.at[:, 1].add(7)  # shift the height stream only
    y0 = apply_mrope(x, p3, (2, 3, 3))
    y1 = apply_mrope(x, p3_shift, (2, 3, 3))
    assert float(jnp.max(jnp.abs(y0 - y1))) > 1e-4

"""Serving-path correctness: prefill + decode must equal the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.profiles import Profile, profile_table
from repro.models import transformer as T
from repro.models.transformer import _logits


@pytest.mark.parametrize("arch", ["granite-3-2b", "mamba2-130m", "hymba-1.5b",
                                  "deepseek-moe-16b"])
def test_prefill_decode_matches_forward(arch):
    import dataclasses
    cfg = get_smoke(arch)
    if cfg.moe is not None:
        # capacity dropping is order-dependent (prefill routes 31 competing
        # tokens, decode routes 1) — exactness needs drop-free capacity
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(42)
    params = T.init_params(cfg, key)
    names = T.quant_layer_names(cfg)
    br = profile_table([Profile.float32(names)], names)[0]
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    hidden, _, _ = T.forward(params, cfg, br, {"tokens": toks})
    lg_full = _logits(cfg, params, br, hidden[:, -1:])[:, 0]
    _, caches = T.prefill(params, cfg, br, {"tokens": toks[:, :S - 1]},
                          slots=S + 4, kv_bits=32)
    lg_dec, _ = T.decode_step(params, cfg, br, toks[:, S - 1:S],
                              jnp.full((B,), S - 1, jnp.int32), caches)
    rel = (float(jnp.max(jnp.abs(lg_dec - lg_full)))
           / max(1e-9, float(jnp.max(jnp.abs(lg_full)))))
    assert rel < 5e-5, rel


@pytest.mark.parametrize("arch", ["granite-3-2b"])
def test_int8_kv_cache_close(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(7)
    params = T.init_params(cfg, key)
    names = T.quant_layer_names(cfg)
    br = profile_table([Profile.float32(names)], names)[0]
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    hidden, _, _ = T.forward(params, cfg, br, {"tokens": toks})
    lg_full = _logits(cfg, params, br, hidden[:, -1:])[:, 0]
    _, c8 = T.prefill(params, cfg, br, {"tokens": toks[:, :S - 1]},
                      slots=S + 4, kv_bits=8)
    lg8, _ = T.decode_step(params, cfg, br, toks[:, S - 1:S],
                           jnp.full((B,), S - 1, jnp.int32), c8)
    rel = (float(jnp.max(jnp.abs(lg8 - lg_full)))
           / max(1e-9, float(jnp.max(jnp.abs(lg_full)))))
    assert rel < 0.25, rel  # int8-quant noise bound on an untrained net


def test_multi_step_greedy_decode_consistent():
    """Greedy decode token-by-token == argmax of teacher-forced forward."""
    cfg = get_smoke("granite-3-2b")
    key = jax.random.PRNGKey(3)
    params = T.init_params(cfg, key)
    names = T.quant_layer_names(cfg)
    br = profile_table([Profile.float32(names)], names)[0]
    B, S, new = 1, 16, 4
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    logits, caches = T.prefill(params, cfg, br, {"tokens": toks},
                               slots=S + new + 2, kv_bits=32)
    seq = toks
    for i in range(new):
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        seq = jnp.concatenate([seq, nxt], axis=1)
        logits, caches = T.decode_step(params, cfg, br, nxt,
                                       jnp.full((B,), S + i, jnp.int32), caches)
        # teacher-forced check
        hidden, _, _ = T.forward(params, cfg, br, {"tokens": seq})
        lg_tf = _logits(cfg, params, br, hidden[:, -1:])[:, 0]
        np.testing.assert_allclose(np.asarray(logits), np.asarray(lg_tf),
                                   rtol=1e-3, atol=2e-4)


def test_swa_ring_buffer_wraps():
    """Hymba SWA cache: decoding past the window stays finite & bounded."""
    cfg = get_smoke("hymba-1.5b")
    key = jax.random.PRNGKey(9)
    params = T.init_params(cfg, key)
    names = T.quant_layer_names(cfg)
    br = profile_table([Profile.float32(names)], names)[0]
    B = 1
    caches = T.init_caches(cfg, B, slots=64, kv_bits=16)
    slots = caches["kv"].token_idx.shape[-1]
    assert slots == cfg.sliding_window  # SWA bound, not the full 64
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(cfg.sliding_window * 2 + 3):  # wrap the ring twice
        logits, caches = T.decode_step(params, cfg, br, tok,
                                       jnp.full((B,), pos, jnp.int32), caches)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(caches["kv"].token_idx.max()) == cfg.sliding_window * 2 + 2


def test_int4_kv_cache_runs_and_is_close():
    """int4-packed KV cache (the §Perf decode next-lever): exact ring
    mechanics, quantization error bounded, half the int8 cache bytes."""
    cfg = get_smoke("granite-3-2b")
    key = jax.random.PRNGKey(7)
    params = T.init_params(cfg, key)
    names = T.quant_layer_names(cfg)
    br = profile_table([Profile.float32(names)], names)[0]
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    hidden, _, _ = T.forward(params, cfg, br, {"tokens": toks})
    from repro.models.transformer import _logits
    lg_full = _logits(cfg, params, br, hidden[:, -1:])[:, 0]
    _, c4 = T.prefill(params, cfg, br, {"tokens": toks[:, :S - 1]},
                      slots=S + 4, kv_bits=4)
    # packed: last dim halves
    assert c4["kv"].k.shape[-1] == cfg.hd // 2 and c4["kv"].bits == 4
    lg4, c4b = T.decode_step(params, cfg, br, toks[:, S - 1:S],
                             jnp.full((B,), S - 1, jnp.int32), c4)
    rel = (float(jnp.max(jnp.abs(lg4 - lg_full)))
           / max(1e-9, float(jnp.max(jnp.abs(lg_full)))))
    assert np.isfinite(np.asarray(lg4)).all()
    assert rel < 0.8, rel  # int4 noise on an untrained net; argmax sanity below
    agree = (np.argmax(np.asarray(lg4), -1) == np.argmax(np.asarray(lg_full), -1))
    assert agree.any()

"""Seeded jit-hygiene violations: donate, tracer branch, closure, constant."""
import jax
import jax.numpy as jnp


def step(params, caches):               # carry threaded ...
    return params, caches


step_jit = jax.jit(step)                # missing-donate: no donate_argnums


def branchy(flag, x):
    if flag:                            # tracer-branch: Python if on a param
        return x + 1
    return x


branchy_jit = jax.jit(branchy)


def make_closure():
    def inner(x):
        return x + scale                # late-closure: scale assigned below

    scale = 3.0
    return inner


def build_table(x):
    table = jnp.array([0.0] * 64)       # device-constant: 64-element literal
    return x + table

"""Seeded host-sync violations: every construct the lint must catch."""
import jax.numpy as jnp
import numpy as np


def decode_loop(tok, pos):
    x = jnp.ones((4,))
    y = float(x.sum())                  # host-sync: float() on device value
    arr = np.asarray(x * 2)             # host-sync: np.asarray of jnp value
    z = x.sum().item()                  # host-sync: .item()
    x.block_until_ready()               # host-sync: explicit barrier
    return y, arr, z

"""Negative fixture: device-clean hot code plus justified allowlisted syncs.

Linting this file with the all-hot spec must report ZERO findings.
"""
import jax
import jax.numpy as jnp
import numpy as np

_TABLE = jnp.zeros((128,))              # module-scope constant: fine


def decode_clean(tok, pos):
    x = jnp.ones((4,)) + _TABLE[:4]
    y = jnp.where(pos > 0, x, tok)      # device-side select: fine
    return y.sum()


def flush_boundary(tok):
    done = jnp.cumsum(tok)
    # repro: allow(host-sync) flush boundary materializes finished tokens
    arr = np.asarray(done)
    return arr.tolist()


def stepwise_oracle(tok, pos):  # repro: allow(host-sync) oracle syncs per step by design
    x = jnp.ones((2,)) + tok
    return int(x.sum()), float(x.max())


def step(params, caches):
    return params, caches


step_jit = jax.jit(step, donate_argnums=(1,))   # donated carry: fine

"""Adaptive serving engine: batched generation under the Profile Manager."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.serving.engine import AdaptiveServer, Request, ServingConfig


@pytest.fixture(scope="module")
def server_parts():
    cfg = get_smoke("granite-3-2b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


def test_generate_shapes_and_determinism(server_parts):
    cfg, params, eng = server_parts
    srv = AdaptiveServer(cfg, params, eng, ServingConfig(slots=64, max_batch=4))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    out1 = srv.generate(prompts, max_new=4)
    out2 = srv.generate(prompts, max_new=4)
    assert len(out1["tokens"]) == 2 and len(out1["tokens"][0]) == 4
    assert out1["tokens"] == out2["tokens"]  # greedy → deterministic


def test_manager_switches_profiles_under_budget(server_parts):
    cfg, params, eng = server_parts
    # profile 0 accurate/expensive, profile 3 cheap/low-accuracy
    stats = [ProfileStats(n, acc, e, 1e-3) for n, acc, e in [
        ("A16-W8", 0.99, 4.0), ("A16-W4", 0.953, 2.0), ("A8-W8", 0.988, 3.0),
        ("A8-W4", 0.953, 1.5), ("A4-W4", 0.958, 1.0), ("Mixed", 0.975, 2.0)]]
    mgr = ProfileManager(stats, accuracy_target=0.985, accuracy_floor=0.90,
                         budget_j=200.0, low_energy=0.5)
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4), manager=mgr)
    prompts = np.zeros((4, 8), np.int32)
    out = srv.generate(prompts, max_new=12)
    used = set(out["profile_trace"])
    # starts accurate, drops to a cheaper profile once the budget drains
    assert "A8-W8" in used or "A16-W8" in used
    assert len(used) >= 2, out["profile_trace"]
    assert mgr.spent_j > 0


def test_request_queue_batches_and_pads(server_parts):
    cfg, params, eng = server_parts
    srv = AdaptiveServer(cfg, params, eng, ServingConfig(slots=64, max_batch=2))
    rng = np.random.default_rng(1)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=3) for n in (3, 8, 5)]
    results = srv.serve(reqs)
    assert len(results) == 3
    for r in results:
        assert len(r["tokens"]) == 3


def test_profile_switch_does_not_recompile(server_parts):
    cfg, params, eng = server_parts
    srv = AdaptiveServer(cfg, params, eng, ServingConfig(slots=64))
    prompts = np.zeros((1, 4), np.int32)
    srv.generate(prompts, max_new=2)
    # switching profile id reuses the same compiled executables
    n0 = srv._decode._cache_size()
    for pid in range(len(eng.profiles)):
        logits, caches = srv._prefill(params, pid, {"tokens": jnp.asarray(prompts)})
    assert srv._prefill._cache_size() == 1
    assert srv._decode._cache_size() == n0

"""BlockAllocator invariants under random operation interleavings.

The paged-serving runtime's every safety property (no leaked pool blocks,
no double-mapped blocks, prefix sharing with exact refcounts) bottoms out
in :class:`repro.serving.paged.BlockAllocator` bookkeeping. This file
drives the allocator through long random interleavings of
``alloc`` / ``retain`` / ``release`` (with and without LRU caching) /
``activate`` / ``uncache`` / pressure reclaim, mirroring every operation
in an independent host-side model, and audits with
:meth:`BlockAllocator.check` (refcounts + free/LRU/live pool partition)
after **every single operation** — plus the PR-5 double-release contract:
releasing an already-free block raises ``RuntimeError`` instead of
corrupting the next owner's refcount.

The seeded numpy driver always runs; when ``hypothesis`` is installed the
same executor also runs under its shrinking fuzzer.
"""
import numpy as np
import pytest

from repro.serving.paged import BlockAllocator

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

N_BLOCKS = 12
OPS = ("alloc", "retain", "release", "activate", "uncache",
       "double_release", "double_release_same_call")


def _live(ref):
    return [int(b) for b in np.nonzero(ref > 0)[0]]


def _apply_op(alloc, ref, lru, op, rng):
    """Execute one operation against the allocator AND the model.

    ``ref`` (np.int64 per-block refcounts) and ``lru`` (set of cached ids)
    are the independent model; every path keeps them exactly in sync with
    what the allocator is specified to do.
    """
    if op == "alloc":
        n = int(rng.integers(1, 5))
        avail = int((ref == 0).sum())            # free + LRU-cached
        got = alloc.alloc(n)
        if n > avail:
            assert got is None, "alloc must refuse, not partially satisfy"
        else:
            assert got is not None and len(got) == n
            assert len(set(got)) == n, "duplicate ids in one allocation"
            for b in got:
                assert ref[b] == 0, f"allocated a live block {b}"
                ref[b] = 1
                lru.discard(int(b))              # pressure reclaim
    elif op == "retain":
        live = _live(ref)
        if live:
            pick = [int(b) for b in rng.choice(
                live, size=min(len(live), 2), replace=False)]
            alloc.retain(pick)
            for b in pick:
                ref[b] += 1
    elif op == "release":
        live = _live(ref)
        if live:
            pick = [int(b) for b in rng.choice(
                live, size=min(len(live), 3), replace=False)]
            cache = {b for b in pick if rng.random() < 0.5}
            alloc.release(pick, cache=cache)
            for b in pick:
                ref[b] -= 1
                if ref[b] == 0 and b in cache:
                    lru.add(b)
    elif op == "activate":
        cands = _live(ref) + sorted(lru)
        if cands:
            pick = [int(b) for b in rng.choice(
                cands, size=min(len(cands), 2), replace=False)]
            assert alloc.activate(pick) is True
            for b in pick:
                if ref[b] > 0:
                    ref[b] += 1                  # extra sharer
                else:
                    lru.discard(b)               # resurrect from the LRU
                    ref[b] = 1
        free_ids = [b for b in range(len(ref))
                    if ref[b] == 0 and b not in lru]
        if free_ids and cands:
            # all-or-nothing: one reclaimed/free id refuses the whole claim
            # with NO state change (check() below proves the no-change)
            assert alloc.activate([int(cands[0]), free_ids[0]]) is False
    elif op == "uncache":
        if lru:
            b = int(rng.choice(sorted(lru)))
            alloc.uncache([b])
            lru.discard(b)
        live = _live(ref)
        if live:                                 # live ids must no-op
            alloc.uncache([live[0]])
    elif op == "double_release":
        free_ids = [int(b) for b in np.nonzero(ref == 0)[0]]
        if free_ids:
            with pytest.raises(RuntimeError, match="double release"):
                alloc.release([free_ids[0]])
    elif op == "double_release_same_call":
        singles = [b for b in _live(ref) if ref[b] == 1]
        if singles:
            b = int(singles[0])
            with pytest.raises(RuntimeError, match="double release"):
                alloc.release([b, b])
            ref[b] = 0           # the first decrement lands before the raise
    alloc.check(expected=ref)


def _drain(alloc, ref):
    """Release every reference; the pool must come back whole."""
    for b in range(len(ref)):
        while ref[b] > 0:
            alloc.release([b])
            ref[b] -= 1
    alloc.check(expected=ref)
    assert alloc.used_blocks == 0
    assert alloc.available_blocks == alloc.n_blocks


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_allocator_random_interleaving(seed):
    """250 random ops, model-checked and partition-audited after each."""
    rng = np.random.default_rng(seed)
    alloc = BlockAllocator(N_BLOCKS, 8)
    reclaimed = []
    alloc.on_reclaim = reclaimed.append
    ref = np.zeros(N_BLOCKS, np.int64)
    lru: set = set()
    for _ in range(250):
        _apply_op(alloc, ref, lru, str(rng.choice(OPS)), rng)
    assert alloc.reclaimed_blocks == len(reclaimed)
    _drain(alloc, ref)


def test_check_flags_corruption():
    """The auditor actually bites: hand-rotted state raises, specifically."""
    alloc = BlockAllocator(4, 8)
    alloc._free.remove(2)                        # leak block 2
    with pytest.raises(RuntimeError, match="leaked"):
        alloc.check()
    alloc = BlockAllocator(4, 8)
    got = alloc.alloc(2)
    alloc.check(expected=[1, 1, 0, 0] if got == [0, 1] else None)
    alloc._ref[got[0]] = 0                       # refcount lies vs free list
    with pytest.raises(RuntimeError, match="leaked|partition"):
        alloc.check()
    alloc = BlockAllocator(4, 8)
    alloc.alloc(1)
    with pytest.raises(RuntimeError, match="disagree"):
        alloc.check(expected=np.zeros(4, np.int64))


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(ops=st.lists(st.sampled_from(OPS), max_size=120),
           seed=st.integers(0, 2**31 - 1))
    def test_allocator_property_hypothesis(ops, seed):
        """Same executor under hypothesis shrinking (skipped when absent)."""
        rng = np.random.default_rng(seed)
        alloc = BlockAllocator(N_BLOCKS, 8)
        ref = np.zeros(N_BLOCKS, np.int64)
        lru: set = set()
        for op in ops:
            _apply_op(alloc, ref, lru, op, rng)
        _drain(alloc, ref)
else:                                                 # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_allocator_property_hypothesis():
        pass

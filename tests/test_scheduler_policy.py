"""Policy-driven scheduling: priority classes, preemption, retired-block LRU.

The load-bearing properties of the policy refactor:

* a preempted-then-resumed row is **token-identical** to an uninterrupted
  run at kv16 and kv8 — including rows holding shared CoW prefix blocks —
  because the restore wave replays the suspended row's whole written span
  as the continuation prefix with an empty suffix (pure data movement:
  bf16 masters round-trip, int-KV re-quantization under the exact scale
  preimage reproduces every int);
* the pool-lifetime single-``_segment``-executable and the ≤2-prefill-
  dispatches-per-admission-round invariants hold under preemption
  (dispatch-count + executable-cache guard);
* the energy ledger stays exact under suspension: replaying the event log
  through a fresh manager reproduces profiles and ledger, and a request's
  total billed inferences are invariant under preemption;
* priority classes order admission (critical jumps saver queues) and bind
  profiles (a critical-class wave pins the accuracy target even in the
  battery-saver regime);
* the allocator's retired-block LRU makes retired prefixes reusable-but-
  reclaimable: a registry hit on a retired prompt's blocks survives until
  real allocation pressure reclaims them, double-release fails loudly,
  and ``paged_stats`` partitions the pool into live/LRU-cached/free.
"""
import jax
import numpy as np
import pytest

from repro.analysis.budgets import MAX_PREFILL_WAVES_PER_ROUND
from repro.analysis.tracker import SchedulerAudit
from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.serving.engine import AdaptiveServer, Request, ServingConfig
from repro.serving.paged import BlockAllocator
from repro.serving.policy import (FifoPolicy, PriorityPolicy, RowState,
                                  default_classes, default_victim_picker,
                                  make_policy)
from repro.serving.scheduler import ContinuousScheduler


def _build(arch="granite-3-2b"):
    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


@pytest.fixture(scope="module")
def dense_parts():
    return _build()


def _solo_tokens(parts, req, kv_bits=16, slots=64):
    cfg, params, eng = parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=slots, max_batch=4,
                                       kv_bits=kv_bits))
    return srv.generate(req.tokens[None, :], req.max_new)["tokens"][0]


def _manager():
    stats = [ProfileStats(n, acc, e, 1e-3) for n, acc, e in [
        ("A16-W8", 0.99, 4.0), ("A16-W4", 0.953, 2.0), ("A8-W8", 0.988, 3.0),
        ("A8-W4", 0.953, 1.5), ("A4-W4", 0.958, 1.0), ("Mixed", 0.975, 2.0)]]
    return ProfileManager(stats, accuracy_target=0.985, accuracy_floor=0.90,
                          budget_j=150.0, low_energy=0.5)


# ---------------------------------------------------------------------------
# policy layer (pure host objects, no jax)
# ---------------------------------------------------------------------------

def test_policy_queue_disciplines():
    """FIFO keeps submission order; the priority ladder serves strictly
    lowest-level-first with FIFO inside a class and front re-insertion for
    rollbacks/resumes."""
    fifo = FifoPolicy()
    for rid in (3, 1, 2):
        fifo.enqueue(rid, Request(tokens=np.zeros(4, np.int32), priority=0))
    assert [fifo.pop_head() for _ in range(3)] == [3, 1, 2]

    pol = PriorityPolicy(default_classes(3))
    reqs = {0: Request(np.zeros(4, np.int32), priority=2),    # saver
            1: Request(np.zeros(4, np.int32), priority=2),
            2: Request(np.zeros(4, np.int32), priority=0),    # critical
            3: Request(np.zeros(4, np.int32), priority=1)}    # standard
    for rid in (0, 1, 2, 3):
        pol.enqueue(rid, reqs[rid])
    assert pol.head() == 2 and len(pol) == 4
    assert pol.pop_head() == 2
    pol.push_front(1, reqs[1])            # no-op: 1 is already queued; the
    order = []                            # API contract is front-of-class
    while len(pol):
        order.append(pol.pop_head())
    assert order == [3, 1, 0, 1]          # standard < saver; 1 re-inserted


def test_default_victim_picker_lowest_class_fewest_tokens():
    """Victims: strictly-lower classes only, lowest class first, fewest
    generated tokens first, all-or-nothing on the resource ask."""
    rows = [RowState(0, 10, level=2, generated=9, blocks=3, preemptible=True),
            RowState(1, 11, level=2, generated=2, blocks=3, preemptible=True),
            RowState(2, 12, level=1, generated=1, blocks=3, preemptible=True),
            RowState(3, 13, level=0, generated=0, blocks=9,
                     preemptible=False)]
    v = default_victim_picker(0, rows, need_slots=1, need_blocks=0)
    assert [r.slot for r in v] == [1]            # saver with fewest tokens
    v = default_victim_picker(0, rows, need_slots=1, need_blocks=5)
    assert [r.slot for r in v] == [1, 0]         # accumulate blocks in order
    # equal-class arrivals never preempt their own class
    assert default_victim_picker(2, rows, 1, 0) == []
    # unsatisfiable asks evict nobody (partial eviction wastes work)
    assert default_victim_picker(0, rows, 1, 100) == []


def test_make_policy_from_config():
    assert isinstance(make_policy(ServingConfig()), FifoPolicy)
    pol = make_policy(ServingConfig(priority_classes=3, preemption=True))
    assert isinstance(pol, PriorityPolicy) and pol.preemptive
    assert [c.name for c in pol.classes] == ["critical", "standard", "saver"]
    assert pol.classes[0].accuracy_critical
    assert not pol.classes[0].preemptible and pol.classes[0].can_preempt
    assert pol.aging is None
    assert make_policy(ServingConfig(priority_classes=2, aging=5)).aging == 5


def test_aging_promotes_starved_saver_in_bounded_rounds():
    """Anti-starvation regression: under a sustained critical flood a
    saver request is promoted one level per ``aging`` rounds and reaches
    the head in bounded time; without aging it starves forever."""
    def flood_rounds_until_served(aging, budget=40):
        pol = PriorityPolicy(default_classes(3), aging=aging)
        saver = Request(np.zeros(4, np.int32), priority=2)
        pol.enqueue(0, saver)
        for rnd in range(1, budget + 1):
            crit = Request(np.zeros(4, np.int32), priority=0)
            pol.enqueue(100 + rnd, crit)           # one new critical/round
            pol.age_tick()
            if pol.pop_head() == 0:                # one service slot/round
                return rnd
        return None

    assert flood_rounds_until_served(aging=None) is None     # starves
    served = flood_rounds_until_served(aging=3)
    # two promotions (saver->standard->critical) then drain the critical
    # backlog ahead of it: bounded, and well inside the budget
    assert served is not None and served <= 3 * 2 + 8

    # default (aging=None) preserves strict lowest-level-first exactly
    pol = PriorityPolicy(default_classes(3))
    for rid, lvl in [(0, 2), (1, 0), (2, 1), (3, 2)]:
        pol.enqueue(rid, Request(np.zeros(4, np.int32), priority=lvl))
    for _ in range(10):
        pol.age_tick()                             # must be a no-op
    assert [pol.pop_head() for _ in range(4)] == [1, 2, 0, 3]


def test_aging_promotion_survives_queue_state_roundtrip():
    """Durability: queue_state()/restore_queue_state() round-trips earned
    promotions and wait counters — a restart does not reset a starved
    request's climb (docs/serving.md §Durability)."""
    pol = PriorityPolicy(default_classes(3), aging=2)
    pol.enqueue(0, Request(np.zeros(4, np.int32), priority=2))
    pol.enqueue(1, Request(np.zeros(4, np.int32), priority=1))
    pol.age_tick()
    pol.age_tick()                # rid 0 -> standard (behind 1), ages reset
    pol.age_tick()                # both waited 1 at level 1
    st = pol.queue_state()
    twin = PriorityPolicy(default_classes(3), aging=2)
    twin.restore_queue_state(st)
    assert twin.queue_state() == st
    twin.age_tick()               # head of standard hits aging -> critical
    assert twin.head() == 1
    assert [twin.pop_head(), twin.pop_head()] == [1, 0]


# ---------------------------------------------------------------------------
# block allocator: double-release + retired-block LRU
# ---------------------------------------------------------------------------

def test_double_release_raises_loudly():
    """Releasing an already-free id (or the same id twice in one call) is a
    RuntimeError — never a silent refcount corruption, and not a strippable
    ``assert``."""
    al = BlockAllocator(4, 8)
    ids = al.alloc(2)
    al.release(ids)
    with pytest.raises(RuntimeError, match="double release"):
        al.release([ids[0]])
    ids = al.alloc(1)
    with pytest.raises(RuntimeError, match="double release"):
        al.release([ids[0], ids[0]])      # duplicate within one call
    with pytest.raises(RuntimeError):
        al.retain([ids[0]])               # retain of the now-free block


def test_lru_free_list_mechanics():
    """Blocks released with a cache claim park in the LRU: still
    allocatable (oldest reclaimed first, with the on_reclaim callback),
    resurrectable all-or-nothing via activate()."""
    al = BlockAllocator(4, 8)
    a = al.alloc(2)
    b = al.alloc(2)
    al.release(a, cache=set(a))           # park both
    assert al.lru_blocks == 2 and al.free_blocks == 0
    assert al.available_blocks == 2 and al.used_blocks == 2
    assert al.activate(a)                 # resurrect: content still there
    assert al.lru_blocks == 0 and al.used_blocks == 4
    al.release(a, cache=set(a))
    reclaimed = []
    al.on_reclaim = reclaimed.append
    al.release(b)                         # plain free
    got = al.alloc(3)                     # 2 free + 1 reclaimed from LRU
    assert len(got) == 3 and reclaimed == [a[0]]   # oldest cached first
    assert al.lru_blocks == 1 and al.used_blocks == 3
    al.uncache([a[1]])                    # claim dropped: LRU → free
    assert al.lru_blocks == 0 and al.free_blocks == 1
    assert not al.activate([a[1]])        # nothing cached left: refused
    assert al.free_blocks == 1            # …and the refusal changed nothing


def test_registry_hit_on_retired_blocks_until_pressure(dense_parts):
    """A prompt resubmitted after its owner retired still hits: the
    registered blocks sit in the retired-block LRU and resurrect. Real
    allocation pressure reclaims them (invalidating the entries), after
    which the same prompt admits cold — correct either way."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=2, block_size=8, pool_blocks=8)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(21)
    sys_p = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    r1 = Request(tokens=np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab, 5).astype(np.int32)]), max_new=3)
    r2 = Request(tokens=np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab, 3).astype(np.int32)]), max_new=4)
    sched.submit(r1)
    sched.run()                           # r1 retired; prefix chain in LRU
    st = sched.paged_stats()
    assert st["live_blocks"] == 0 and st["lru_cached_blocks"] >= 2
    assert (st["live_blocks"] + st["lru_cached_blocks"]
            + st["free_blocks"] == st["pool_blocks"])
    sched.submit(r2)
    res = sched.run()
    assert sched.registry.hits == 1       # hit a RETIRED prompt's blocks
    assert res[1]["tokens"] == _solo_tokens(dense_parts, r2)
    # real pressure: a request needing more than free+live can give forces
    # the allocator to reclaim the LRU-cached blocks, killing the entries
    big = Request(tokens=rng.integers(0, cfg.vocab, 40).astype(np.int32),
                  max_new=16)             # 7 of 8 blocks
    sched.submit(big)
    sched.run()
    assert sched.registry.invalidated > 0
    assert sched.allocator.reclaimed_blocks > 0
    hits_before = sched.registry.hits
    sched.submit(Request(tokens=r2.tokens.copy(), max_new=4))
    res = sched.run()
    assert sched.registry.hits == hits_before    # entry gone: cold again
    assert res[3]["tokens"] == _solo_tokens(dense_parts, r2)


# ---------------------------------------------------------------------------
# priority classes through the scheduler
# ---------------------------------------------------------------------------

def test_priority_admission_order(dense_parts):
    """With a busy one-row pool, a critical-class submission overtakes
    earlier saver-class submissions in the admission order (no preemption
    needed — pure queue discipline)."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=1, priority_classes=2)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=2)
    rng = np.random.default_rng(5)
    mk = lambda pr, mn: Request(tokens=rng.integers(0, cfg.vocab, 6)
                                .astype(np.int32), max_new=mn, priority=pr)
    r_busy = sched.submit(mk(1, 4))
    sched.step()                          # occupies the single row
    r_s1 = sched.submit(mk(1, 3))
    r_s2 = sched.submit(mk(1, 3))
    r_c = sched.submit(mk(0, 3))          # critical: jumps both savers
    sched.run()
    assert sched.admission_log == [r_busy, r_c, r_s1, r_s2]


def test_critical_class_binds_profile(dense_parts):
    """Class→profile binding: in the battery-saver regime a critical-CLASS
    wave (no per-request flag) still selects at the accuracy target, while
    saver-class waves drop to the floor profiles."""
    cfg, params, eng = dense_parts
    stats = _manager().profiles
    mgr = ProfileManager(stats, accuracy_target=0.985, accuracy_floor=0.90,
                         budget_j=1e9, low_energy=0.5)
    mgr._saver = True                     # pin the saver regime
    mgr.low_energy, mgr.hysteresis = 2.0, 0.0   # hysteresis never exits it
    scfg = ServingConfig(slots=64, max_batch=2, priority_classes=2)
    srv = AdaptiveServer(cfg, params, eng, scfg, manager=mgr)
    sched = ContinuousScheduler(srv, quantum=2)
    rng = np.random.default_rng(9)
    sched.submit(Request(tokens=rng.integers(0, cfg.vocab, 6)
                         .astype(np.int32), max_new=2, priority=1))
    sched.run()
    saver_events = list(sched.events)
    assert all(not crit for _, _, crit in saver_events)
    floor_pid = saver_events[0][0]
    sched.submit(Request(tokens=rng.integers(0, cfg.vocab, 6)
                         .astype(np.int32), max_new=2, priority=0))
    sched.run()
    crit_events = [e for e in sched.events[len(saver_events):] if e[1] > 0]
    assert crit_events and all(crit for _, _, crit in crit_events)
    assert stats[crit_events[0][0]].accuracy >= 0.985
    assert stats[floor_pid].accuracy < 0.985


# ---------------------------------------------------------------------------
# preemption: token identity, invariants, ledger
# ---------------------------------------------------------------------------

def _preempt_scenario(parts, kv_bits, quantum=2):
    """Two saver rows fill the pool and get mid-decode; a critical arrival
    preempts one (slot pressure); everything drains. Returns (sched,
    requests). The first saver shares CoW prefix blocks with the second."""
    cfg, params, eng = parts
    scfg = ServingConfig(slots=64, max_batch=2, block_size=8,
                         kv_bits=kv_bits, priority_classes=2,
                         preemption=True)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=quantum)
    rng = np.random.default_rng(17)
    sys_p = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    s1 = Request(tokens=np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab, 4).astype(np.int32)]),
        max_new=18, priority=1)
    s2 = Request(tokens=np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab, 7).astype(np.int32)]),
        max_new=16, priority=1)
    crit = Request(tokens=rng.integers(0, cfg.vocab, 7).astype(np.int32),
                   max_new=4, priority=0)
    sched.submit(s1)
    sched.step()                 # s1 cold + registers the shared prefix
    sched.submit(s2)
    sched.step()                 # s2 maps the prefix blocks CoW
    sched.step()
    sched.submit(crit)           # pool full → policy evicts a saver
    while sched.step():
        pass
    return sched, [s1, s2, crit]


@pytest.mark.parametrize("kv_bits", [16, 8])
def test_preempt_resume_token_identity(dense_parts, kv_bits):
    """A preempted-then-resumed row emits exactly the tokens of an
    uninterrupted run, at bf16 and int8 KV — including the CoW sharer
    (the victim's snapshot covers the shared span it mapped; its resume
    rebuilds a fully private row bit-exactly)."""
    sched, reqs = _preempt_scenario(dense_parts, kv_bits)
    assert sched.preemptions >= 1 and sched.resumes == sched.preemptions
    if sched.registry is not None:        # CoW sharing actually happened
        assert sched.registry.hits >= 1
    for rid, req in enumerate(reqs):
        assert sched.results[rid]["tokens"] == \
            _solo_tokens(dense_parts, req, kv_bits), f"rid={rid}"
        assert len(sched.results[rid]["tokens"]) == req.max_new


def test_preemption_invariants_dispatch_count_and_segment(dense_parts):
    """The two structural invariants under preemption: every decode
    segment of the scheduler's lifetime reuses ONE compiled executable,
    and no admission round dispatches more than TWO prefill waves (cold /
    shared / resume — a third kind waits a round). Enforced via the named
    ``analysis`` invariants ``single-segment-executable`` and
    ``max-prefill-waves`` (SchedulerAudit)."""
    cfg, params, eng = dense_parts
    scfg = ServingConfig(slots=64, max_batch=2, block_size=8,
                         priority_classes=2, preemption=True)
    srv = AdaptiveServer(cfg, params, eng, scfg)
    sched = ContinuousScheduler(srv, quantum=2)
    rng = np.random.default_rng(17)
    sys_p = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    subs = [Request(tokens=np.concatenate(
        [sys_p, rng.integers(0, cfg.vocab, k).astype(np.int32)]),
        max_new=14, priority=1) for k in (4, 7)]
    with SchedulerAudit(sched) as audit:
        for r in subs:
            sched.submit(r)
        sched.step()
        sched.step()
        sched.submit(Request(tokens=rng.integers(0, cfg.vocab, 7)
                             .astype(np.int32), max_new=4, priority=0))
        while sched.step():
            pass
        assert sched.preemptions >= 1 and sched.resumes >= 1
        # ≤2 prefill waves per round
        audit.assert_max_prefill_waves(MAX_PREFILL_WAVES_PER_ROUND)
        assert max(audit.prefill_waves_per_round) <= 2
        audit.assert_single_segment()             # ONE segment executable
    assert srv._segment._cache_size() == 1


def test_ledger_exact_under_preemption(dense_parts):
    """Suspend/resume bills exactly: replaying the event log through a
    fresh manager reproduces every profile choice and the ledger to float
    precision, and the total billed inferences equal Σ(max_new) + nothing
    for the resume waves — a request's bill is invariant under
    preemption."""
    cfg, params, eng = dense_parts
    mgr = _manager()
    scfg = ServingConfig(slots=64, max_batch=2, block_size=8,
                         priority_classes=2, preemption=True)
    srv = AdaptiveServer(cfg, params, eng, scfg, manager=mgr)
    sched = ContinuousScheduler(srv, quantum=2)
    rng = np.random.default_rng(31)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=mn, priority=pr)
            for n, mn, pr in [(9, 14, 1), (12, 12, 1)]]
    for r in reqs:
        sched.submit(r)
    sched.step()
    sched.step()
    crit = Request(tokens=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                   max_new=3, priority=0)
    reqs.append(crit)
    sched.submit(crit)
    while sched.step():
        pass
    assert sched.preemptions >= 1
    oracle = _manager()
    for pid, n_rows, critical in sched.events:
        assert oracle.select(accuracy_critical=critical) == pid
        oracle.account(pid, n_rows)
    assert abs(oracle.spent_j - mgr.spent_j) < 1e-9
    billed = sum(n for _, n, _ in sched.events)
    assert billed == sum(r.max_new for r in reqs)


def test_preemption_config_validation(dense_parts):
    """Preemption on an unsupported stack (or without the paged pool)
    fails loudly at server construction, and a preemptive policy on a
    non-preemption server fails at scheduler construction."""
    cfg, params, eng = dense_parts
    with pytest.raises(ValueError, match="preemption"):
        AdaptiveServer(cfg, params, eng,
                       ServingConfig(slots=64, max_batch=2, paged_kv=False,
                                     preemption=True))
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=2))
    with pytest.raises(ValueError, match="preemptive"):
        ContinuousScheduler(
            srv, policy=PriorityPolicy(default_classes(2), preemptive=True))

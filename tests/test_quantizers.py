"""Property tests for the quantization core (hypothesis + targeted cases)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip cleanly when absent
from hypothesis import given, settings, strategies as st

from repro.core import (QuantSpec, compute_scale, fake_quant,
                        fake_quant_dynamic, pack_int4, qrange,
                        quantize_native, dequantize, unpack_int4)

SS = jnp.asarray(np.array([1, 0], np.int32))


@st.composite
def arrays(draw, max_size=64):
    n = draw(st.integers(2, max_size))
    vals = draw(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                         min_size=n, max_size=n))
    return np.asarray(vals, np.float32)


@given(arrays(), st.integers(2, 16), st.booleans())
@settings(max_examples=40, deadline=None)
def test_fake_quant_bounded_error(x, bits, po2):
    """|fq(x) − x| ≤ scale/2 inside the representable range (round-to-nearest)."""
    spec = QuantSpec(bits=bits, po2_scale=po2)
    xj = jnp.asarray(x)
    y = np.asarray(fake_quant(xj, spec))
    scale = float(compute_scale(xj, spec))
    qmin, qmax = qrange(spec)
    inside = (x >= qmin * scale) & (x <= qmax * scale)
    assert np.all(np.abs(y[inside] - x[inside]) <= scale / 2 + 1e-6)


@given(arrays(), st.integers(2, 12))
@settings(max_examples=30, deadline=None)
def test_fake_quant_idempotent(x, bits):
    spec = QuantSpec(bits=bits, po2_scale=True)
    xj = jnp.asarray(x)
    s = compute_scale(xj, spec)
    y1 = fake_quant(xj, spec, s)
    y2 = fake_quant(y1, spec, s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


@given(arrays(), st.integers(2, 12))
@settings(max_examples=30, deadline=None)
def test_fake_quant_monotone(x, bits):
    """Quantization preserves order (monotone non-decreasing)."""
    spec = QuantSpec(bits=bits)
    xs = np.sort(x)
    y = np.asarray(fake_quant(jnp.asarray(xs), spec))
    assert np.all(np.diff(y) >= -1e-6)


@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(seed, rows):
    rng = np.random.default_rng(seed)
    q = rng.integers(-8, 8, (rows, 16)).astype(np.int8)
    out = np.asarray(unpack_int4(pack_int4(jnp.asarray(q))))
    np.testing.assert_array_equal(out, q)


@given(arrays(max_size=32), st.sampled_from([4, 8]))
@settings(max_examples=25, deadline=None)
def test_native_matches_fake(x, bits):
    """quantize_native→dequantize == fake_quant on the same grid/scale."""
    if len(x) % 2:
        x = x[:-1]
    spec = QuantSpec(bits=bits, po2_scale=True)
    xj = jnp.asarray(x)
    s = compute_scale(xj, spec)
    fake = np.asarray(fake_quant(xj, spec, s))
    nat = np.asarray(dequantize(quantize_native(xj, spec, s), jnp.float32))
    np.testing.assert_allclose(nat, fake, atol=1e-5)


def test_dynamic_matches_static():
    x = jnp.linspace(-3, 3, 257)
    for bits in (2, 4, 8, 16):
        y_static = fake_quant(x, QuantSpec(bits=bits, po2_scale=True))
        y_dyn = fake_quant_dynamic(x, jnp.int32(bits), SS)
        np.testing.assert_allclose(np.asarray(y_static), np.asarray(y_dyn),
                                   atol=1e-6)


def test_dynamic_float_passthrough():
    x = jnp.linspace(-3, 3, 64)
    np.testing.assert_array_equal(
        np.asarray(fake_quant_dynamic(x, jnp.int32(32), SS)), np.asarray(x))


def test_ste_gradient_mask():
    x = jnp.asarray([-100.0, -0.5, 0.0, 0.5, 100.0])
    spec = QuantSpec(bits=8)
    g = jax.grad(lambda v: fake_quant(v, spec, jnp.asarray(0.01)).sum())(x)
    # inside clip range → 1, outside → 0
    np.testing.assert_allclose(np.asarray(g), [0, 1, 1, 1, 0], atol=1e-6)


def test_per_channel_scale_shape():
    w = jnp.ones((4, 6))
    spec = QuantSpec(bits=8, per_channel=True, channel_axis=-1)
    s = compute_scale(w, spec)
    assert s.shape == (1, 6)


def test_stochastic_rounding_unbiased():
    spec = QuantSpec(bits=8, stochastic=True)
    x = jnp.full((20000,), 0.3)
    s = jnp.asarray(1.0)
    y = fake_quant(x, spec, s, key=jax.random.PRNGKey(0))
    assert abs(float(y.mean()) - 0.3) < 0.02  # E[q] = x

"""Dry-run machinery on a tiny in-repo mesh (subprocess: needs its own
XLA_FLAGS before jax init). The full 256/512-chip sweep runs via
``python -m repro.launch.dryrun --all`` (artifacts in artifacts/dryrun)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args, devices="8"):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_DRYRUN_DEVICES=devices)
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200)


@pytest.mark.slow
def test_tiny_mesh_train_cell(tmp_path):
    r = _run_dryrun(["--arch", "granite-3-2b", "--shape", "train_4k",
                     "--mesh", "tiny", "--no-analysis",
                     "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(tmp_path / "granite-3-2b__train_4k__tiny.json"))
    assert rec["status"] == "ok"
    assert rec["production"]["flops"] > 0
    assert rec["production"]["memory"]["argument_bytes"] > 0
    # FSDP+TP sharding present → collectives in the schedule
    assert sum(rec["production"]["collectives"]["count"].values()) > 0


@pytest.mark.slow
def test_tiny_multipod_mesh_compiles(tmp_path):
    """The pod axis shards (2×2×2 = 8 devices) — the multi-pod proof at test
    scale; the 512-chip version is the artifact sweep."""
    r = _run_dryrun(["--arch", "granite-3-2b", "--shape", "train_4k",
                     "--mesh", "tiny2", "--no-analysis",
                     "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(tmp_path / "granite-3-2b__train_4k__tiny2.json"))
    assert rec["status"] == "ok" and rec["devices"] == 8


@pytest.mark.slow
def test_skip_cell_is_recorded(tmp_path):
    r = _run_dryrun(["--arch", "qwen2-72b", "--shape", "long_500k",
                     "--mesh", "tiny", "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(tmp_path / "qwen2-72b__long_500k__tiny.json"))
    assert rec["status"] == "skipped" and "sub-quadratic" in rec["reason"]


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = f32[64,256]{1,0} all-gather(%p0), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  %ar = f32[64,256]{1,0} all-reduce(%dot.1), channel_id=2, replica_groups=[2,4]<=[8], to_apply=%add
  %a2a = bf16[32,128]{1,0} all-to-all(%x), channel_id=3, replica_groups={{0,1,2,3}}
  %cp = s8[16]{0} collective-permute(%y), channel_id=4, source_target_pairs={{0,1}}
  %fusion = f32[2,8]{1,0} fusion(%all-reduce, %c), kind=kLoop, calls=%comp
"""
    out = collective_bytes(hlo)
    assert out["per_kind"]["all-gather"] == 64 * 256 * 4 // 4  # result/groupsize
    assert out["per_kind"]["all-reduce"] == 64 * 256 * 4
    assert out["per_kind"]["all-to-all"] == 32 * 128 * 2
    assert out["per_kind"]["collective-permute"] == 16
    assert out["count"]["all-reduce"] == 1  # fusion operand name not miscounted

"""Per-architecture smoke tests (reduced same-family configs, brief §ARCH):
one forward/train step on CPU asserting output shapes + finiteness, and one
decode step for causal archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke, SHAPES, shape_applicable
from repro.core import AdaptiveEngine, QuantIndex
from repro.core.profiles import paper_profiles
from repro.models import transformer as T


def _batch(cfg, key, B=2, S=32):
    if cfg.frontend == "audio":
        return {"features": jax.random.normal(key, (B, S, cfg.feature_dim)),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
                "patch_embeds": jax.random.normal(key, (B, cfg.n_patches,
                                                        cfg.d_model)),
                "labels": jnp.where(jnp.arange(S)[None] < cfg.n_patches, -100,
                                    jax.random.randint(key, (B, S), 0,
                                                       cfg.vocab))}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_and_decode(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names,
                           inner_layers=[n for n in names if n.startswith("L1.")])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    batch = _batch(cfg, key)
    loss, metrics = jax.jit(eng)(params, 2, batch)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["xent"]))
    # gradient step finiteness
    g = jax.grad(lambda p: eng(p, 2, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(l))) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    if cfg.causal:
        caches = T.init_caches(cfg, 2, 16, kv_bits=16)
        br = eng.bits_row(2)
        logits, new_caches = T.decode_step(
            params, cfg, br, jnp.zeros((2, 1), jnp.int32),
            jnp.zeros((2,), jnp.int32), caches)
        assert logits.shape == (2, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_brief(arch):
    """The full configs carry the exact published hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "mamba2-130m": (24, 768, 24, 24, 0, 50280),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff, cfg.vocab)
    assert got == expected
    if arch == "deepseek-moe-16b":
        assert (cfg.moe.n_routed, cfg.moe.top_k, cfg.moe.n_shared) == (64, 6, 2)
    if arch == "qwen2-moe-a2.7b":
        assert (cfg.moe.n_routed, cfg.moe.top_k, cfg.moe.n_shared) == (60, 4, 4)
    if arch == "mamba2-130m":
        assert cfg.ssm.d_state == 128
    if arch == "hymba-1.5b":
        assert cfg.ssm.d_state == 16 and cfg.sliding_window > 0
    if arch == "hubert-xlarge":
        assert not cfg.causal


def test_shape_skip_rules():
    """Brief-mandated skips: long_500k for full-attention, decode for encoder."""
    long5 = SHAPES["long_500k"]
    dec = SHAPES["decode_32k"]
    assert shape_applicable(get_config("mamba2-130m"), long5)[0]
    assert shape_applicable(get_config("hymba-1.5b"), long5)[0]
    for a in ("qwen2-72b", "glm4-9b", "deepseek-moe-16b", "hubert-xlarge"):
        assert not shape_applicable(get_config(a), long5)[0]
    assert not shape_applicable(get_config("hubert-xlarge"), dec)[0]
    assert shape_applicable(get_config("qwen2-72b"), dec)[0]


def test_quant_layer_names_cover_all_layers():
    cfg = get_smoke("granite-3-2b")
    names = T.quant_layer_names(cfg)
    assert names[0] == "embed" and names[1] == "lm_head"
    assert len(names) == 2 + cfg.n_layers * 4  # qkv/attn_out/mlp_in/mlp_out


def test_scan_vs_unrolled_equivalence():
    """The depth-unrolled analysis variant computes the same function."""
    import dataclasses
    cfg = get_smoke("granite-3-2b")
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    names = T.quant_layer_names(cfg)
    from repro.core.profiles import Profile, profile_table
    br = profile_table([Profile.float32(names)], names)[0]
    batch = _batch(cfg, key)
    h1, a1, _ = T.forward(params, cfg, br, batch)
    cfg2 = dataclasses.replace(cfg, scan_layers=False, unroll_inner=True)
    h2, a2, _ = T.forward(params, cfg2, br, batch)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-5, atol=2e-5)


def test_swa_block_skip_matches_masked():
    """The block-skipping SWA path (§Perf) is numerically exact vs masking."""
    from repro.models.attention import gqa_attention, swa_attention
    key = jax.random.PRNGKey(11)
    B, S, H, Hkv, D, w = 2, 160, 4, 2, 16, 48
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    ref = gqa_attention(q, k, v, causal=True, window=w, block_k=32)
    out = swa_attention(q, k, v, window=w, block_q=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_constraints_are_noop_when_disabled():
    from repro.models import pshard
    assert not pshard.enabled()
    x = jnp.ones((4, 8))
    y = pshard.constrain(x, "dp", "tp")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

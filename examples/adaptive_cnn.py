"""The paper's end-to-end scenario (§4): QAT the tiny CNN at several
``Ax-Wy`` profiles on digit classification, merge A8-W8 + Mixed into an
adaptive engine, and run it against a battery budget with the Profile
Manager — reproducing the Table 1 / Fig. 3 / Fig. 4 story.

Run:  PYTHONPATH=src python examples/adaptive_cnn.py [--steps 120]
(first run trains ≈ all profiles on CPU — minutes; results cached in
artifacts/repro/table1.json)
"""
import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--force", action="store_true", help="retrain, ignore cache")
    args = ap.parse_args()

    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import repro_cnn

    t1 = repro_cnn.run_table1(force=args.force, steps=args.steps)
    print("\n=== Table 1 analogue (per-profile engines) ===")
    print(f"{'profile':8s} {'acc%':>6s} {'lat_us':>7s} {'P_model(W)':>10s} {'w_bytes':>8s}")
    for name, r in t1["rows"].items():
        print(f"{name:8s} {r['accuracy_pct']:6.2f} {r['latency_us']:7.3f} "
              f"{r['power_w_model']:10.3f} {r['weight_bytes']:8d}")
    print("(paper reference: A16-W8 98.9%@160mW … A8-W4 95.3%@132mW; "
          "latency constant across profiles)")

    f4 = repro_cnn.run_fig4(t1)
    print("\n=== Fig. 4 analogue (adaptive engine: A8-W8 + Mixed) ===")
    m = f4["merge"]
    print(f"shared layers: {m['shared_layers']}  switched: {m['switched_layers']}")
    print(f"merged-engine overhead vs largest standalone: "
          f"{m['overhead_vs_largest']*100:.1f}% (paper: 'limited overhead')")
    print(f"profile switch: {f4['power_saving_pct']}% power saving at "
          f"{f4['accuracy_drop_pct']}% accuracy drop")
    b = f4["battery"]
    print(f"battery budget: adaptive {b['adaptive']['classifications']} vs "
          f"non-adaptive {b['non_adaptive']['classifications']} classifications "
          f"(+{b['extra_classifications_pct']}%)")


if __name__ == "__main__":
    main()

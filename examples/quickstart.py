"""Quickstart: the paper's flow in 60 lines.

1. Define a quantizable model (here: granite-family reduced LM).
2. Build the paper's profile family (A16-W8 … A4-W4 + Mixed).
3. Merge them into ONE adaptive engine (MDC analogue) — one compiled
   executable, profile switched by a scalar at runtime.
4. Inspect the merge report (shared vs switched layers = resource sharing).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.profiles import paper_profiles
from repro.models import transformer as T


def main():
    cfg = get_smoke("granite-3-2b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name}, {T.param_count(params)/1e6:.2f}M params")

    # per-layer quantization sites (the QONNX-graph analogue)
    names = T.quant_layer_names(cfg)
    print(f"quant sites: {len(names)} (first 6: {names[:6]})")

    # the paper's profiles; Mixed drops layer L1 to A4-W4
    inner = [n for n in names if n.startswith("L1.")]
    profs = paper_profiles(names, inner_layers=inner)

    engine = AdaptiveEngine(tuple(profs), QuantIndex(names),
                            lambda p, br, b: T.train_loss(p, cfg, br, b))
    report = engine.merge_report()
    print(f"merged engine: {report['n_layers']} sites, "
          f"{len(report['shared_layers'])} shared across all profiles, "
          f"sharing_ratio={report['sharing_ratio']:.2f}")

    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab),
    }
    step = jax.jit(engine)  # traced ONCE for every profile
    for name in engine.profile_names:
        loss, metrics = step(params, engine.profile_id(name), batch)
        print(f"  profile {name:7s}: loss {float(loss):.4f}")
    print("one executable, six profiles — switching is a scalar, not a re-jit.")


if __name__ == "__main__":
    main()

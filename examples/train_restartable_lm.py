"""Fault-tolerant LM training driver: joint QAT over the merged profile
family with checkpoint/restart. Kill it mid-run (Ctrl-C or SIGTERM) and
re-launch — it resumes bit-exactly from the last committed checkpoint.

Run:  PYTHONPATH=src python examples/train_restartable_lm.py \
          --steps 60 --ckpt-dir /tmp/aqe_ckpt
Scale note: the identical step function lowers on the 256/512-chip
production mesh via ``python -m repro.launch.dryrun`` (deliverable e).
"""
import argparse
import subprocess
import sys

from repro.launch.train import main as train_main


def main():
    # thin veneer over the launcher so the example stays a single entry point
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/aqe_ckpt")
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--ckpt-dir", args.ckpt_dir]
    if args.grad_compression:
        argv.append("--grad-compression")
    sys.argv = ["train"] + argv
    train_main()


if __name__ == "__main__":
    main()

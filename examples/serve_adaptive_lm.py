"""End-to-end serving driver (the paper is an inference paper, so the
brief's end-to-end requirement is served inference with batched requests):
a small LM behind the AdaptiveServer — batched prefill/decode, int8 KV cache
option, Profile Manager switching precision as the energy budget drains.

Run:  PYTHONPATH=src python examples/serve_adaptive_lm.py [--kv-bits 8]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.energy import step_energy, activity_factor
from repro.core.engine import AdaptiveEngine, QuantIndex
from repro.core.manager import ProfileManager, ProfileStats
from repro.core.profiles import paper_profiles
from repro.models import transformer as T
from repro.serving.engine import AdaptiveServer, Request, ServingConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kv-bits", type=int, default=16, choices=[8, 16])
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke("granite-3-2b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    engine = AdaptiveEngine(tuple(profs), QuantIndex(names),
                            lambda p, br, b: T.train_loss(p, cfg, br, b))

    # modeled per-inference energy per profile → manager policy inputs
    t_est = 2.0 * T.param_count(params) / 197e12
    stats = []
    for p in profs:
        a, w = next(iter(p.bits.values()))
        acc = {8: 0.989, 4: 0.953}.get(w, 0.998)
        stats.append(ProfileStats(
            p.name, acc, step_energy(t_est, activity_factor(
                min(a, 16), min(w, 16), min(w, 16) / 16)), t_est))
    mgr = ProfileManager(stats, accuracy_target=0.985, accuracy_floor=0.95,
                         budget_j=stats[0].energy_j * 80, low_energy=0.5)

    srv = AdaptiveServer(cfg, params, engine,
                         ServingConfig(slots=128, kv_bits=args.kv_bits,
                                       max_batch=4), manager=mgr)
    rng = np.random.default_rng(0)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, int(n)).astype(np.int32),
                    max_new=12, accuracy_critical=(i % 4 == 0))
            for i, n in enumerate(rng.integers(4, 20, args.requests))]
    results = srv.serve(reqs)
    for i, r in enumerate(results):
        print(f"req{i:02d}: {len(r['tokens'])} new tokens | "
              f"profiles {sorted(set(r['profile_trace']))}")
    print(f"\nkv_bits={args.kv_bits} (8 halves the decode memory-roofline term)"
          f"\nenergy: {mgr.spent_j:.2e} J spent, "
          f"{mgr.remaining_fraction()*100:.0f}% budget left, "
          f"saver_mode={mgr._saver}")


if __name__ == "__main__":
    main()

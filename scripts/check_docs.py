"""Docs gate: smoke-execute fenced python snippets + check markdown links.

Keeps README/docs honest the same way tests keep code honest:

* every ```` ```python ```` fence is executed in a fresh interpreter with
  ``PYTHONPATH=src`` from the repo root (a snippet opting out starts with a
  ``# doc: no-exec`` line — for fragments that illustrate rather than run);
* every relative markdown link/image target must exist on disk (external
  ``scheme://`` links and pure ``#anchors`` are not fetched).

  python scripts/check_docs.py README.md docs/serving.md
"""
from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links(md: pathlib.Path, text: str) -> list[str]:
    errors = []
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)  # code ≠ links
    for target in LINK.findall(text):
        if "://" in target or target.startswith(("#", "mailto:")):
            continue
        path = (md.parent / target.split("#")[0]).resolve()
        if not path.exists():
            errors.append(f"{md}: broken link -> {target}")
    return errors


def run_snippets(md: pathlib.Path, text: str) -> list[str]:
    errors = []
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src{os.pathsep}" + env.get("PYTHONPATH", "")
    for i, code in enumerate(FENCE.findall(text)):
        if code.lstrip().startswith("# doc: no-exec"):
            continue
        print(f"[docs] {md.name} snippet {i}: running "
              f"({len(code.splitlines())} lines)", flush=True)
        try:
            proc = subprocess.run([sys.executable, "-"], input=code,
                                  text=True, cwd=ROOT, env=env,
                                  capture_output=True, timeout=600)
        except subprocess.TimeoutExpired:
            errors.append(f"{md}: snippet {i} timed out after 600s")
            continue
        if proc.returncode != 0:
            errors.append(f"{md}: snippet {i} failed\n--- stderr ---\n"
                          f"{proc.stderr[-2000:]}")
        else:
            tail = proc.stdout.strip().splitlines()[-1:] or [""]
            print(f"[docs]   ok: {tail[0][:100]}")
    return errors


def main(paths: list[str]) -> int:
    errors = []
    for p in paths:
        md = (ROOT / p).resolve()
        try:
            text = md.read_text()
        except OSError as e:
            errors.append(f"{md}: unreadable ({e})")
            continue
        errors += check_links(md, text)
        errors += run_snippets(md, text)
    for e in errors:
        print(f"[docs] FAIL {e}", file=sys.stderr)
    print(f"[docs] {'FAILED' if errors else 'ok'}: {len(paths)} files")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["README.md", "docs/serving.md"]))

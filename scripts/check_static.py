#!/usr/bin/env python
"""Hot-path discipline gate: AST lint + jaxpr budgets + runtime audit.

Three blocking stages (any failure => non-zero exit):

1. **lint** — ``repro.analysis.lint`` over ``src/repro`` with the
   default hot-path spec; the tree must report zero unallowlisted
   findings.
2. **budgets** — every :data:`repro.analysis.budgets.REFERENCE_BUDGETS`
   point traced on the pallas backend must pass its aval-byte ceiling
   and the no-gather-view check; as a self-test, the gather backend must
   *fail* the view check at the first point (proving the detector
   detects).
3. **scenarios** — a smoke server + scheduler run under
   :class:`repro.analysis.tracker.SchedulerAudit` must satisfy the named
   runtime invariants: single pool-lifetime ``_segment`` executable,
   <= 2 prefill waves per admission round, no retrace after warmup, and
   zero dispatches of the stepwise ``_decode`` executable.

Flags for fixtures/tests:

- ``--lint-root PATH`` lints an alternate tree (every file hot) instead
  of ``src/repro`` — used by the seeded-violation canary.
- ``--canary-budget`` checks a toy jitted function against a 1-byte
  ceiling, which must fail — proving the budget class of violation is
  actually fatal.
- ``--skip-lint`` / ``--skip-budgets`` / ``--skip-scenarios`` narrow the
  run (the CI invocation runs all three).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


def _parts(arch: str = "granite-3-2b"):
    import jax

    from repro.configs import get_smoke
    from repro.core.engine import AdaptiveEngine, QuantIndex
    from repro.core.profiles import paper_profiles
    from repro.models import transformer as T

    cfg = get_smoke(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    names = T.quant_layer_names(cfg)
    profs = paper_profiles(names, inner_layers=[])
    eng = AdaptiveEngine(tuple(profs), QuantIndex(names),
                         lambda p, br, b: T.train_loss(p, cfg, br, b))
    return cfg, params, eng


def run_lint(lint_root: str | None) -> int:
    from repro.analysis.lint import ALL_HOT, DEFAULT_SPEC, lint_tree

    if lint_root is not None:
        findings = lint_tree(lint_root, ALL_HOT)
        label = lint_root
    else:
        findings = lint_tree(REPO / "src" / "repro", DEFAULT_SPEC)
        label = "src/repro"
    for f in findings:
        print(f.render())
    print(f"lint: {len(findings)} finding(s) in {label}")
    return 1 if findings else 0


def run_budgets(parts) -> int:
    from repro.analysis import jaxpr_check
    from repro.analysis.budgets import REFERENCE_BUDGETS, check_budget, trace_segment

    rc = 0
    for budget in REFERENCE_BUDGETS:
        report = check_budget(parts, budget, backend="pallas")
        print(report.render())
        if not report.ok:
            rc = 1
    # Self-test: the gather backend must trip the view detector at the
    # first reference point, or the guard is vacuous.
    first = REFERENCE_BUDGETS[0]
    gather = trace_segment(parts, "gather", first)
    if not jaxpr_check.has_adjacent_dims(
        gather, (first.batch, first.slots_padded)
    ):
        print("budgets: SELF-TEST FAILED — gather backend did not produce "
              "the view aval the detector claims to catch")
        rc = 1
    else:
        print("budgets: self-test ok (gather backend trips the view check)")
    return rc


def run_canary_budget() -> int:
    """A toy jitted fn vs a 1-byte ceiling: must report violations."""
    import jax
    import jax.numpy as jnp

    from repro.analysis import jaxpr_check

    def f(x):
        return (x * 2.0).sum()

    jaxpr = jax.make_jaxpr(f)(jnp.zeros((64, 64), jnp.float32))
    violations = jaxpr_check.check_aval_budget(jaxpr, 1)
    print(f"canary-budget: {len(violations)} violation(s) at 1-byte ceiling")
    return 1 if violations else 0


def run_scenarios(parts) -> int:
    import numpy as np

    from repro.analysis.budgets import MAX_PREFILL_WAVES_PER_ROUND
    from repro.analysis.tracker import DispatchAudit, SchedulerAudit
    from repro.serving.engine import AdaptiveServer, Request, ServingConfig
    from repro.serving.scheduler import ContinuousScheduler

    cfg, params, eng = parts
    srv = AdaptiveServer(cfg, params, eng,
                         ServingConfig(slots=64, max_batch=4, block_size=8))
    sched = ContinuousScheduler(srv, quantum=4)
    rng = np.random.default_rng(7)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, n).astype(np.int32),
                    max_new=mn)
            for n, mn in [(7, 5), (9, 4), (17, 5), (5, 4), (12, 4)]]
    rc = 0
    with SchedulerAudit(sched) as audit, \
            DispatchAudit(srv, ["_decode"]) as srv_audit:
        srv_audit.forbid("_decode")     # no-per-token-dispatch
        for r in reqs[:3]:
            sched.submit(r)
        while sched.step():
            pass
        for r in reqs[3:]:              # second admission round, warm pool
            sched.submit(r)
        res = sched.run()
        try:
            audit.assert_single_segment()           # single-segment-executable
            audit.assert_max_prefill_waves(MAX_PREFILL_WAVES_PER_ROUND)
            audit.assert_no_retrace(["_segment"])   # no-retrace
        except AssertionError as e:
            print(f"scenarios: FAIL — {e}")
            rc = 1
    if len(res) != len(reqs) or any(not r["tokens"] for r in res):
        print("scenarios: FAIL — scheduler did not complete all requests")
        rc = 1
    if rc == 0:
        print(f"scenarios: ok — segment dispatches={audit.calls('_segment')}, "
              f"prefill waves/round={audit.prefill_waves_per_round}, "
              f"stepwise _decode dispatches=0")
    return rc


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--lint-root", default=None,
                    help="lint this tree (all files hot) instead of src/repro")
    ap.add_argument("--canary-budget", action="store_true",
                    help="run the toy-budget canary (must fail => exit 1)")
    ap.add_argument("--skip-lint", action="store_true")
    ap.add_argument("--skip-budgets", action="store_true")
    ap.add_argument("--skip-scenarios", action="store_true")
    args = ap.parse_args(argv)

    if args.canary_budget:
        return run_canary_budget()

    rc = 0
    if not args.skip_lint:
        rc |= run_lint(args.lint_root)
    if args.lint_root is not None:
        # Fixture lint runs don't trace the real model.
        return rc
    parts = None
    if not (args.skip_budgets and args.skip_scenarios):
        parts = _parts()
    if not args.skip_budgets:
        rc |= run_budgets(parts)
    if not args.skip_scenarios:
        rc |= run_scenarios(parts)
    print("check_static:", "PASS" if rc == 0 else "FAIL")
    return rc


if __name__ == "__main__":
    sys.exit(main())

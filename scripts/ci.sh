#!/usr/bin/env bash
# Tier-1 CI gate (mirrors ROADMAP.md): the full suite must pass.
#
#   ./scripts/ci.sh            # tier-1: pytest -x -q
#   ./scripts/ci.sh --bench    # additionally run the serving benchmark
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

if [[ "${1:-}" == "--bench" ]]; then
    python benchmarks/serving_bench.py --quick
fi

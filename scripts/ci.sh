#!/usr/bin/env bash
# Tier-1 CI gate (mirrors ROADMAP.md): the full suite must pass, then the
# serving path is exercised end-to-end (continuous scheduler + static serve
# under open-loop Poisson arrivals, the paged-KV shared-prefix point, which
# asserts the >=30% KV-footprint saving and live/LRU-cached/free block-pool
# occupancy partition, a chunked-prefill point, and a mixed-class
# priority+preemption point that asserts critical-class p99 beats the FIFO
# baseline and replays the ledger exactly against the stepwise oracle, and
# a chaos point — seeded NaN-logit faults + an allocator drought + a flush
# stall + client cancellations — that asserts zero leaked pool blocks,
# >=1 quarantine + precision-fallback recovery, and token-identity of the
# recovered request vs a clean accuracy-critical run, a crash-restart
# point — write-ahead journal + live-state checkpoints, a hard kill at a
# mid-run boundary, recovery into a fresh scheduler — that asserts every
# post-restart stream is token-identical to the uninterrupted twin, a
# committed pre-crash checkpoint, and zero leaked pool blocks, and a
# speculative decoding point — draft/verify windows on a
# predictable-continuation trace — that asserts token identity against
# both the greedy scheduler and the solo-generate oracle, zero leaked
# blocks, and >=1.2x closed-loop decode throughput), then the
# paged-attention kernel gate (token identity vs the gather path +
# strictly fewer bytes per decode step), and finally the docs gate
# smoke-executes every README/docs code snippet and checks markdown links.
#
#   ./scripts/ci.sh            # tier-1: pytest -x -q + serving smoke + docs
#   ./scripts/ci.sh --bench    # additionally run the full serving benchmark
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

# hot-path discipline gate: AST lint over src/repro (zero unallowlisted
# findings), segment jaxpr budgets at the BENCH_4/BENCH_6 reference points
# (aval-byte ceilings + no-gather-view), and the runtime scenario audit
# (single _segment executable, <=2 prefill waves/round, no retrace, zero
# stepwise-_decode dispatches)
python scripts/check_static.py

python benchmarks/serving_bench.py --smoke --paranoid

# paged-attention kernel gate: kernel/gather token identity on a real
# decode_segment at kv16/kv8/packed-kv4 + strictly fewer per-decode-step
# bytes than the gather path (kv4 additionally: fewer kernel bytes/step
# than kv8 and >=1.5x pool token capacity at equal block count)
python benchmarks/kernel_bench.py --smoke

# packed-int4 + precision-policy point: search a per-layer KV schedule on
# the smoke model and serve through it at kv4 end to end (the searched
# schedule rides the jitted decode as data; profile 0 pins the all-high row)
python benchmarks/precision_frontier.py --arch granite-3-2b \
    --max-drop 0.05 --json /tmp/ci_precision_policy.json
python -m repro.launch.serve --arch granite-3-2b --requests 4 --max-new 6 \
    --kv-bits 4 --continuous --paged-backend pallas \
    --precision-policy /tmp/ci_precision_policy.json

python scripts/check_docs.py README.md docs/serving.md docs/analysis.md

if [[ "${1:-}" == "--bench" ]]; then
    python benchmarks/serving_bench.py --quick
fi
